"""Quickstart: the paper's algorithm end-to-end in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Sort a word list with the paper's pipeline (bucket by length -> parallel
   comparator sort -> shortlex order).
2. Same comparator network as a Pallas TPU kernel (interpret mode on CPU).
3. The technique inside an LM: sort-based MoE dispatch on a tiny model.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketed_sort_words, pack_words, unpack_words
from repro.data import synthetic_words
from repro.kernels import sort_rows, sort_rows_ref
from repro.configs import get_smoke_config
from repro.models import forward, init_lm
from repro.parallel.sharding import Rules


def demo_paper_pipeline():
    words = synthetic_words(2_000, seed=0)
    out = bucketed_sort_words(words, algorithm="oets")
    expect = sorted(words, key=lambda w: (len(w), w))
    assert out == expect
    print(f"[1] bucketed OETS sorted {len(words)} words "
          f"({len(set(len(w) for w in words))} length buckets) -> shortlex OK")


def demo_pallas_kernel():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 10**6, (8, 256)).astype(np.int32))
    out = sort_rows(x, algorithm="oets")          # Pallas kernel (interpret on CPU)
    ref = sort_rows_ref(x)
    assert (np.asarray(out) == np.asarray(ref)).all()
    print("[2] Pallas OETS kernel == jnp oracle on (8,256) rows OK")


def demo_moe_lm():
    cfg = get_smoke_config("granite-moe-1b-a400m")  # MoE arch, sort dispatch
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}
    logits, aux, _ = forward(cfg, params, batch, Rules())
    print(f"[3] granite-moe forward with sort-based dispatch: "
          f"logits {tuple(logits.shape)}, aux-loss {float(aux):.4f} OK")


if __name__ == "__main__":
    demo_paper_pipeline()
    demo_pallas_kernel()
    demo_moe_lm()
    print("quickstart complete")
