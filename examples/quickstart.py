"""Quickstart: the paper's algorithm end-to-end in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Sort a word list with the paper's pipeline (on-device bucketize ->
   parallel comparator sort -> shortlex order; the distribute step is a
   Pallas kernel, not a host loop).
2. Same comparator network as a Pallas TPU kernel (interpret mode on CPU).
3. Chunked ingest: stream words through fixed-size launches as sorted runs
   combined by a k-way lex merge (inputs beyond one launch).
4. The technique inside an LM: sort-based MoE dispatch on a tiny model.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketed_sort_words, pack_words, unpack_words
from repro.data import synthetic_words
from repro.kernels import bucketize, sort_rows, sort_rows_ref
from repro.pipeline import chunked_sort_words
from repro.configs import get_smoke_config
from repro.models import forward, init_lm
from repro.parallel.sharding import Rules


def demo_paper_pipeline():
    words = synthetic_words(2_000, seed=0)
    out = bucketed_sort_words(words, algorithm="oets")
    expect = sorted(words, key=lambda w: (len(w), w))
    assert out == expect
    n_buckets = int((bucketize(jnp.asarray(pack_words(words)))[1] > 0).sum())
    print(f"[1] bucketed OETS sorted {len(words)} words "
          f"({n_buckets} device-built length buckets) -> shortlex OK")


def demo_pallas_kernel():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 10**6, (8, 256)).astype(np.int32))
    out = sort_rows(x, algorithm="oets")          # Pallas kernel (interpret on CPU)
    ref = sort_rows_ref(x)
    assert (np.asarray(out) == np.asarray(ref)).all()
    print("[2] Pallas OETS kernel == jnp oracle on (8,256) rows OK")


def demo_chunked_pipeline():
    words = synthetic_words(600, seed=2)
    chunk = 128  # one lane tile wide -> the fused program stays in the OETS tier
    out = chunked_sort_words(words, chunk_size=chunk)
    assert out == sorted(words, key=lambda w: (len(w), w))
    n_runs = -(-len(words) // chunk)
    print(f"[3] chunked ingest: {len(words)} words -> {n_runs} sorted runs "
          f"(chunk={chunk}) -> merge-path combine -> shortlex OK")


def demo_moe_lm():
    cfg = get_smoke_config("granite-moe-1b-a400m")  # MoE arch, sort dispatch
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}
    logits, aux, _ = forward(cfg, params, batch, Rules())
    print(f"[4] granite-moe forward with sort-based dispatch: "
          f"logits {tuple(logits.shape)}, aux-loss {float(aux):.4f} OK")


if __name__ == "__main__":
    demo_paper_pipeline()
    demo_pallas_kernel()
    demo_chunked_pipeline()
    demo_moe_lm()
    print("quickstart complete")
