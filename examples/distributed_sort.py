"""The paper's algorithm at mesh scale: the multi-engine distributed sort
subsystem (``core/distributed``) on 8 fake host devices — odd-even block
sort (bubble sort over the interconnect), splitter sample sort (the paper's
distribute step as ONE all_to_all), and the multi-host word pipeline:
bucketize by length -> shard -> distributed lex sort -> shortlex concat.

    PYTHONPATH=src python examples/distributed_sort.py

Sets up 8 host devices via XLA_FLAGS (must run as a script, not imported
after jax is initialized)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from repro.parallel.compat import AxisType, make_mesh  # noqa: E402

from repro.core import packing  # noqa: E402
from repro.core.distributed import (choose_engine, distributed_sort,  # noqa: E402
                                    distributed_sort_lex)
from repro.kernels import sort_lex  # noqa: E402


def engines_demo(mesh):
    """Both mesh engines against the jnp.sort oracle — including a
    non-divisible size (8 devices, n % 8 != 0: pad-and-slice, no error)."""
    rng = np.random.default_rng(0)
    for n in (8 * 4096, 10_001):
        x = jnp.asarray(rng.integers(0, 10**9, n), dtype=jnp.int32)
        want = np.sort(np.asarray(x))
        for merge in ("resort", "bitonic", "take"):
            out = distributed_sort(x, mesh, engine="odd_even", merge=merge)
            ok = bool((np.asarray(out) == want).all())
            print(f"odd-even  n={n:7d} merge={merge:8s}: "
                  f"{'OK' if ok else 'FAIL'}")
            assert ok
        out = distributed_sort(x, mesh, engine="sample")
        ok = bool((np.asarray(out) == want).all())
        print(f"sample    n={n:7d} one all_to_all  : {'OK' if ok else 'FAIL'}")
        assert ok
    print(f"choose_engine: P=2 -> {choose_engine(2, 4096)}, "
          f"P=8 -> {choose_engine(8, 4096)}")


def word_pipeline_demo(mesh):
    """The paper's whole pipeline across the mesh: words bucketize by length
    (the length becomes lex lane 0), pack into big-endian uint32 lanes
    (``core/packing``), shard over 8 devices, and ONE distributed lex sort
    returns shortlex order — distribute-into-sub-arrays and in-bucket
    alphabetic sort collapse into a single mesh-wide splitter exchange."""
    rng = np.random.default_rng(7)
    alphabet = np.array(list("abcdefghij"))
    words = ["".join(rng.choice(alphabet, rng.integers(1, 8)))
             for _ in range(1003)]  # non-divisible on purpose

    packed = packing.pack_words(words)             # (n, lanes) uint32
    length = jnp.asarray([len(w) for w in words], jnp.int32)
    lanes = [length] + [jnp.asarray(packed[:, l])
                        for l in range(packed.shape[1])]
    out = distributed_sort_lex(lanes, mesh, engine="sample")
    got = packing.unpack_words(np.stack([np.asarray(o) for o in out[1:]],
                                        axis=1))
    want = sorted(words, key=lambda w: (len(w), w))
    ok = got == want
    print(f"word pipeline: {len(words)} words -> distributed shortlex over "
          f"8 devices: {'OK' if ok else 'FAIL'}")
    assert ok


def kv_demo(mesh):
    """Payload lanes ride the splitter exchange: sort (key, row-id) pairs so
    the permutation can gather any satellite data afterwards."""
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.integers(0, 50, 999), dtype=jnp.int32)
    v = jnp.arange(999, dtype=jnp.int32)
    (ok_,), ov = distributed_sort_lex((k,), mesh, vals=v, engine="sample")
    good = sorted(zip(np.asarray(k).tolist(), np.asarray(v).tolist())) == \
        list(zip(np.asarray(ok_).tolist(), np.asarray(ov).tolist()))
    print(f"kv payload through the exchange protocol: "
          f"{'OK' if good else 'FAIL'}")
    assert good


def lex_demo():
    """64-bit keys as (hi, lo) uint32 lanes through single-host ``sort_lex``
    — the same variadic engine the distributed tier runs per device."""
    rng = np.random.default_rng(1)
    full = rng.integers(0, 1 << 63, 250, dtype=np.uint64)
    hi = jnp.asarray((full >> 32).astype(np.uint32))
    lo = jnp.asarray((full & 0xFFFFFFFF).astype(np.uint32))
    shi, slo = sort_lex([hi, lo])
    got = (np.asarray(shi).astype(np.uint64) << 32) | np.asarray(slo)
    ok = bool((got == np.sort(full)).all())
    print(f"sort_lex over 2 x uint32 lanes == uint64 sort:   "
          f"{'OK' if ok else 'FAIL'}")
    assert ok


def main():
    mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    engines_demo(mesh)
    word_pipeline_demo(mesh)
    kv_demo(mesh)
    lex_demo()
    print("distributed_sort complete")


if __name__ == "__main__":
    main()
