"""The paper's algorithm at mesh scale: odd-even block sort across 8
devices (bubble sort over the interconnect), plus the lexicographic kernel
front-end on wide keys (the paper's multi-character words as packed lanes).

    PYTHONPATH=src python examples/distributed_sort.py

Sets up 8 host devices via XLA_FLAGS (must run as a script, not imported
after jax is initialized)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from repro.parallel.compat import AxisType, make_mesh  # noqa: E402

from repro.core.distributed import distributed_sort  # noqa: E402
from repro.kernels import sort_lex  # noqa: E402


def lex_demo():
    """64-bit keys as (hi, lo) uint32 lanes through ``sort_lex`` — the same
    variadic engine that sorts the word-bucket pipeline's packed lanes."""
    rng = np.random.default_rng(1)
    full = rng.integers(0, 1 << 63, 250, dtype=np.uint64)
    hi = jnp.asarray((full >> 32).astype(np.uint32))
    lo = jnp.asarray((full & 0xFFFFFFFF).astype(np.uint32))
    shi, slo = sort_lex([hi, lo])
    got = (np.asarray(shi).astype(np.uint64) << 32) | np.asarray(slo)
    ok = bool((got == np.sort(full)).all())
    print(f"sort_lex over 2 x uint32 lanes == uint64 sort:   "
          f"{'OK' if ok else 'FAIL'}")
    assert ok


def main():
    mesh = make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 10**9, 8 * 4096), dtype=jnp.int32)

    for merge in ("resort", "bitonic", "take"):
        out = distributed_sort(x, mesh, axis="data", merge=merge)
        ok = bool((out == jnp.sort(x)).all())
        print(f"odd-even block sort over 8 devices, merge={merge:8s}: "
              f"{'OK' if ok else 'FAIL'}")
        assert ok

    lex_demo()

    print("distributed_sort complete")


if __name__ == "__main__":
    main()
