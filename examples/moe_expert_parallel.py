"""The paper's technique at mesh scale inside a model: explicit
expert-parallel MoE dispatch (sort -> bucket -> ONE all_to_all -> local
experts -> return) across 8 devices, checked against the single-device
GSPMD implementation.

    PYTHONPATH=src python examples/moe_expert_parallel.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.parallel.compat import AxisType, make_mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.moe import init_moe, moe  # noqa: E402
from repro.models.moe_ep import ep_moe  # noqa: E402
from repro.models.param import Builder, finalize  # noqa: E402
from repro.parallel.sharding import Rules  # noqa: E402


def main():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, n_experts=8, top_k=2, capacity_factor=8.0, n_shared=0))

    b = Builder(jax.random.PRNGKey(0), dtype=jnp.float32)
    params, _ = finalize(init_moe(b, cfg))
    tokens = 64
    x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, cfg.d_model))

    y_ref, _ = moe(cfg, params, x, Rules())  # GSPMD reference

    mesh = make_mesh((8,), ("ep",), axis_types=(AxisType.Auto,))
    y_ep, _ = ep_moe(cfg, mesh, "ep", x.reshape(tokens, cfg.d_model),
                     params["router"], params["w_in"], params["w_out"])

    err = float(jnp.max(jnp.abs(y_ep.reshape(1, tokens, -1) - y_ref)))
    print(f"8-way expert-parallel dispatch (1 expert/device, sort-bucketed, "
          f"one all_to_all each way)\nmax |EP - GSPMD| = {err:.2e}")
    assert err < 2e-4
    print("moe_expert_parallel complete")


if __name__ == "__main__":
    main()
