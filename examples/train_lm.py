"""End-to-end driver: train a small LM for a few hundred steps with
checkpointing and a simulated mid-run node failure (elastic recovery).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch glm4-9b]

Uses the reduced (smoke) variant of the chosen arch so it runs on CPU; the
full configs are exercised by the dry-run (python -m repro.launch.dryrun).
"""

import argparse
import tempfile

from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.training import Hyper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=150,
                    help="simulate a node failure at this step (-1 = off)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        fail = (args.fail_at,) if args.fail_at >= 0 else ()
        params, losses, events = train_loop(
            cfg, steps=args.steps, batch=8, seq=32,
            ckpt_dir=ckpt_dir, ckpt_every=50, fail_at=fail,
            hyper=Hyper(lr=1e-3, warmup=20, total_steps=args.steps),
        )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    for e in events:
        print(f"recovered at step {e.step}: {e.devices_before} -> {e.devices_after} devices")
    assert losses[-1] < losses[0], "training failed to reduce loss"
    print("train_lm complete")


if __name__ == "__main__":
    main()
