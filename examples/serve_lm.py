"""Serve a small model with batched requests through the paper's
length-bucketed admission scheduler.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_lm
from repro.parallel.sharding import Rules
from repro.serve import BucketedScheduler, Engine, Request


def main():
    cfg = get_smoke_config("minicpm3-4b")  # MLA: compressed-latent decode
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, Rules(), max_seq=96)
    sched = BucketedScheduler(engine, batch_size=8, bounds=[8, 16, 32, 48])

    rng = np.random.default_rng(0)
    reqs = [Request(f"req-{i}",
                    list(rng.integers(1, cfg.vocab_size, int(rng.integers(3, 48)))),
                    max_new=8)
            for i in range(24)]

    stats = BucketedScheduler.padding_stats(reqs, bounds=[8, 16, 32, 48])
    print(f"padding waste: global-batch {stats['global_waste']:.1%} -> "
          f"bucketed {stats['bucketed_waste']:.1%}")

    t0 = time.time()
    results = sched.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU smoke model)")
    sample = results[0]
    print(f"sample {sample.request_id}: {sample.tokens}")
    print("serve_lm complete")


if __name__ == "__main__":
    main()
