"""Deterministic synthetic data.

``synthetic_words`` approximates the paper's corpus statistics (Hamlet,
word lengths 1..~16, Zipf-ish frequency) without network access — the
benchmark harness uses it to reproduce Tables 1-4 at matched element counts.
``TokenStream`` generates LM training batches.
"""

from __future__ import annotations

import re

import numpy as np

__all__ = ["synthetic_words", "TokenStream", "clean_text", "words_from_text"]

_ALPHA = np.array(list("abcdefghijklmnopqrstuvwxyz"))
# empirical English word-length distribution (1..15+), renormalized
_LEN_P = np.array([0.03, 0.17, 0.21, 0.16, 0.11, 0.09, 0.08, 0.06,
                   0.04, 0.025, 0.015, 0.01, 0.005, 0.003, 0.002])


def synthetic_words(n: int, seed: int = 0, max_len: int = 15) -> list:
    """n pseudo-English words with realistic length distribution."""
    rng = np.random.default_rng(seed)
    p = _LEN_P[:max_len] / _LEN_P[:max_len].sum()
    lengths = rng.choice(np.arange(1, max_len + 1), size=n, p=p)
    # letter frequencies roughly english-like via Zipf over the alphabet
    letter_p = 1.0 / np.arange(1, 27)
    letter_p /= letter_p.sum()
    out = []
    for ln in lengths:
        out.append("".join(rng.choice(_ALPHA, size=ln, p=letter_p)))
    return out


def clean_text(text: str) -> str:
    """Paper pre-processing phase 1: strip special characters."""
    return re.sub(r"[^A-Za-z \n]", " ", text).lower()


def words_from_text(text: str) -> list:
    return [w for w in clean_text(text).split() if w]


class TokenStream:
    """Deterministic infinite stream of (tokens, labels) LM batches.

    Labels are next-token shifted; the final position is masked (-1).
    Per-host sharding: pass ``shard_index``/``num_shards`` so each host
    reads a disjoint stream (multi-pod data loading).
    """

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 shard_index: int = 0, num_shards: int = 1):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self):
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self._step) * self.num_shards + self.shard_index
        )
        self._step += 1
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int32)
        labels = toks[:, 1:].copy()
        labels[:, -1] = -1
        return {"tokens": toks[:, :-1], "labels": labels}
