"""Prefetching host loader: background thread keeps a bounded queue of
ready batches so host data work overlaps device compute."""

from __future__ import annotations

import queue
import threading

__all__ = ["ShardedLoader"]


class ShardedLoader:
    def __init__(self, iterator, prefetch: int = 2):
        self._it = iterator
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
