"""Length-bucketed batching — the paper's decomposition as a data-pipeline
and serving-admission stage.

The paper buckets words by character count so equal-length items process
together; an LM system buckets *sequences* by token count so batch padding
is minimized. ``plan_buckets`` chooses boundaries from a length histogram
(the paper: "sizes decided by the number of elements with the same
length"); the batcher groups items and emits dense padded batches. The
histogram/assignment statistic itself is shared with the serving admission
layer through ``repro.pipeline.histogram`` — one phase-1 count, every
consumer.
"""

from __future__ import annotations

import bisect
from typing import Iterable, List, Sequence

import numpy as np

from ..pipeline.histogram import quantile_bounds

__all__ = ["plan_buckets", "LengthBucketedBatcher", "padding_waste"]


def plan_buckets(lengths: Sequence[int], n_buckets: int = 8) -> List[int]:
    """Quantile-based bucket upper bounds covering the observed lengths.
    Empty input plans no buckets (``[]``) instead of raising."""
    return quantile_bounds(lengths, n_buckets)


def padding_waste(lengths: Sequence[int], batch_seq: int) -> float:
    """Fraction of padded tokens when batching to a fixed length."""
    ls = np.asarray(lengths)
    return float(1.0 - ls.sum() / (len(ls) * batch_seq))


class LengthBucketedBatcher:
    """Groups variable-length items into per-bucket batches.

    Items are (id, sequence). A batch is emitted when a bucket fills to
    ``batch_size`` (or on flush). Padding is to the bucket bound, not the
    global max — the waste reduction is measured in benchmarks/bench_serving.
    """

    def __init__(self, bounds: Sequence[int], batch_size: int, pad_value: int = 0):
        self.bounds = list(bounds)
        if any(lo > hi for lo, hi in zip(self.bounds, self.bounds[1:])):
            raise ValueError(f"bucket bounds must be ascending, got {self.bounds}")
        self.batch_size = batch_size
        self.pad_value = pad_value
        self._pending: dict[int, list] = {i: [] for i in range(len(self.bounds))}

    def _bucket_of(self, length: int) -> int:
        # same first-bound->bucket statistic as pipeline.histogram's
        # assign_buckets, but per-item on the add() hot path — bisect over
        # the (validated-in-__init__) bounds instead of numpy array round
        # trips; lengths beyond the largest planned bound stay rejected
        i = bisect.bisect_left(self.bounds, length)
        if i == len(self.bounds):
            raise ValueError(
                f"length {length} exceeds largest bucket {self.bounds[-1]}")
        return i

    def add(self, item_id, seq) -> list:
        """Add one item; returns zero or more ready batches."""
        b = self._bucket_of(len(seq))
        self._pending[b].append((item_id, seq))
        if len(self._pending[b]) >= self.batch_size:
            return [self._emit(b)]
        return []

    def flush(self) -> list:
        out = [self._emit(b) for b in list(self._pending) if self._pending[b]]
        return out

    def _emit(self, b: int):
        items = self._pending[b]
        self._pending[b] = []
        bound = self.bounds[b]
        ids = [i for i, _ in items]
        arr = np.full((len(items), bound), self.pad_value, dtype=np.int32)
        lens = np.zeros((len(items),), np.int32)
        for r, (_, seq) in enumerate(items):
            arr[r, : len(seq)] = np.asarray(seq, np.int32)
            lens[r] = len(seq)
        return {"ids": ids, "tokens": arr, "lengths": lens, "bucket_bound": bound}
