"""Data substrate: synthetic corpora, the paper's length-bucketed batching
as a pipeline stage, and a sharded prefetching host loader."""

from .synthetic import synthetic_words, TokenStream, clean_text, words_from_text
from .bucketing import LengthBucketedBatcher, plan_buckets
from .loader import ShardedLoader

__all__ = [
    "synthetic_words", "TokenStream", "clean_text", "words_from_text",
    "LengthBucketedBatcher", "plan_buckets", "ShardedLoader",
]
