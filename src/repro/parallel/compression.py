"""Gradient compression for cross-pod all-reduce: int8 quantization with
error feedback.

Cross-pod (DCN) bandwidth is the scarcest link in a multi-pod job; 4x
compression of the gradient all-reduce is a standard distributed-optimization
trick. Error feedback (Karimireddy et al. 2019) accumulates the quantization
residual locally and adds it to the next step's gradient, so the *average*
update stays unbiased and SGD converges at the uncompressed rate.

``compressed_psum`` is built for shard_map bodies; the pure quantize /
dequantize pair is property-tested in tests/test_parallel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .compat import axis_size

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum", "ef_init", "ef_compress"]


def quantize_int8(x):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_init(tree):
    return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), tree)


def ef_compress(grads, residual):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed (q, scale) tree, new_residual). The transmitted value
    is dequantize(q, scale); residual carries what was rounded away."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        sent = dequantize_int8(q, s)
        return (q, s), corrected - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_res = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return comp, new_res


def compressed_psum(x, axis_name, residual):
    """int8-compressed all-reduce with error feedback (shard_map body use).

    Quantizes locally, all-reduces the int32-widened payload (the wire format
    a real deployment would ship), dequantizes with the max scale. Returns
    (mean-reduced value, new residual)."""
    corrected = x.astype(jnp.float32) + residual
    # agree on one scale first (one fp32 pmax) so the int sum is exact
    amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_residual = corrected - q.astype(jnp.float32) * scale
    # wire: int8 payload; reduce widened to int32 to avoid overflow
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = axis_size(axis_name)
    mean = total.astype(jnp.float32) * scale / n
    return mean, new_residual
