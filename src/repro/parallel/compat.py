"""jax version compatibility for the mesh APIs the sharding layer uses.

The codebase targets the current mesh API (``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``, ``axis_types=`` on
mesh constructors); older jaxlib pins (this container ships 0.4.37) predate
all four. Everything else in the repo imports the modern spelling from here
so the fallback logic lives in exactly one place.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["AxisType", "make_mesh", "mesh_from_devices", "set_mesh",
           "get_abstract_mesh", "shard_map", "shard_map_norep", "axis_size"]

try:
    shard_map = jax.shard_map
except AttributeError:  # pre-0.6 spelling
    from jax.experimental.shard_map import shard_map


def shard_map_norep(f, **kw):
    """``shard_map`` with the replication checker disabled — required when
    the body contains ops without a replication rule (``pallas_call``, the
    interpret-mode local sorts of ``core/distributed``). The flag was
    renamed ``check_rep`` -> ``check_vma`` across jax versions; try both."""
    for flag in ("check_rep", "check_vma"):
        try:
            return shard_map(f, **kw, **{flag: False})
        except TypeError:
            continue
    return shard_map(f, **kw)


def axis_size(axis_name):
    """``lax.axis_size`` with a psum(1) fallback for jax versions without it
    (inside collectives the sum of ones is constant-folded to the size)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)

try:
    from jax.sharding import AxisType
    _HAS_AXIS_TYPES = True
except ImportError:  # pre-explicit-sharding jax
    import enum

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPES = False


def make_mesh(axis_shapes, axis_names, axis_types=None):
    """``jax.make_mesh`` that tolerates jax versions without axis_types."""
    if _HAS_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


def mesh_from_devices(devices, axis_names, axis_types=None):
    """``jax.sharding.Mesh`` from an explicit device array, same tolerance."""
    if _HAS_AXIS_TYPES and axis_types is not None:
        return Mesh(devices, axis_names, axis_types=axis_types)
    return Mesh(devices, axis_names)


def set_mesh(mesh):
    """Context manager activating ``mesh`` as the ambient mesh."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh  # pre-0.5: Mesh is itself the thread-resources context


def get_abstract_mesh():
    """The ambient (abstract) mesh; ``.empty`` when none is active."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as mesh_lib
    return mesh_lib.thread_resources.env.physical_mesh
