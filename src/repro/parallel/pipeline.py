"""Pipeline parallelism over a mesh axis via collective_permute.

GPipe-style forward schedule: P stages live on P devices of the ``pipe``
axis; microbatches stream through with activations hopping stage-to-stage by
``lax.ppermute`` each tick. M microbatches finish in M + P - 1 ticks (bubble
fraction (P-1)/(M+P-1)).

In this framework PP is an *optional* plan: the production mesh uses the
``pod`` axis for data parallelism by default, but the same axis can be
repurposed as a 2-stage pipeline for models whose layers do not fit a pod
(launch/mesh.py). The schedule below is the mechanism; stage_fn is any
per-stage closure (e.g. half the layer stack).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .compat import axis_size
from jax import lax

__all__ = ["pipeline_forward"]


def pipeline_forward(stage_fn, stage_params, x_all, axis_name: str):
    """Run microbatches through P pipeline stages (inside shard_map).

    stage_fn: (stage_params, x) -> y, same shape (stages must be
    shape-preserving, as transformer stacks are).
    stage_params: this device's stage parameters.
    x_all: (M, ...) all microbatch inputs (meaningful on stage 0).
    Returns (M, ...) outputs (meaningful on the last stage).
    """
    n = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    m = x_all.shape[0]
    mb_shape = x_all.shape[1:]
    perm = [(i, i + 1) for i in range(n - 1)]  # chain, not ring

    buf = jnp.zeros(mb_shape, x_all.dtype)
    outs = jnp.zeros_like(x_all)
    # the loop carries become device-varying after the first ppermute; mark
    # the zero-init values varying so the scan carry types match
    if hasattr(lax, "pcast"):
        buf = lax.pcast(buf, (axis_name,), to="varying")
        outs = lax.pcast(outs, (axis_name,), to="varying")

    def tick(t, carry):
        buf, outs = carry
        # stage 0 injects microbatch t
        idx_in = jnp.clip(t, 0, m - 1)
        x0 = lax.dynamic_index_in_dim(x_all, idx_in, 0, keepdims=False)
        cur = jnp.where((me == 0) & (t < m), x0, buf)
        y = stage_fn(stage_params, cur)
        # last stage retires microbatch t - (n-1)
        ridx = t - (n - 1)
        safe = jnp.clip(ridx, 0, m - 1)
        prev = lax.dynamic_index_in_dim(outs, safe, 0, keepdims=False)
        rec = jnp.where((me == n - 1) & (ridx >= 0), y, prev)
        outs = lax.dynamic_update_index_in_dim(outs, rec, safe, 0)
        # activations hop to the next stage
        buf = lax.ppermute(y, axis_name, perm)
        return buf, outs

    _, outs = lax.fori_loop(0, m + n - 1, tick, (buf, outs))
    return outs
