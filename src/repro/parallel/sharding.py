"""Logical-axis sharding rules (the framework's parallelism plan).

Every parameter/activation dimension is annotated with a *logical* axis name;
a ``Rules`` table maps logical names to mesh axes. Changing the parallelism
strategy (pure DP, FSDP x TP, EP, sequence-sharded KV cache...) is a table
edit, not a model edit — this is what makes the perf hillclimb in
EXPERIMENTS.md §Perf a config sweep.

Conventions:
  params:      embed/heads/kv_heads/mlp/vocab/expert/... dimensions
  activations: batch/seq/act_embed/act_heads/...
  caches:      cache_batch/cache_seq/kv_heads

GSPMD handles non-divisible dimension/axis pairs by padding, so rules may map
e.g. 8 KV heads onto a 16-way ``model`` axis; where that wastes memory the
per-arch config overrides the rule (see configs/).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
from jax.sharding import PartitionSpec as P

from .compat import get_abstract_mesh

__all__ = ["Rules", "DEFAULT_RULES", "constrain", "spec_for"]


# FSDP (params sharded over `data`) x TP (`model`) x DP over pods — the
# baseline plan for all dry-run cells.
DEFAULT_RULES: Mapping[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "res_seq": None,          # residual-region sequence axis: set to "model"
                              # for Megatron-style sequence parallelism (SP)
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_expert": "model",
    "act_vocab": "model",
    # parameters
    "layers": None,
    "embed": "data",          # FSDP: gather per layer inside the scan
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",        # expert parallelism
    "expert_mlp": None,
    "q_lora": None,
    "kv_lora": None,
    "state": None,
    "conv": None,
    # kv / ssm caches
    "cache_batch": ("data",),
    "cache_seq": None,
    "cache_kv_heads": "model",
}


@dataclasses.dataclass(frozen=True)
class Rules:
    """Immutable logical->mesh mapping with helpers."""

    table: Mapping[str, object] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )

    def override(self, **kw) -> "Rules":
        t = dict(self.table)
        t.update(kw)
        return Rules(t)

    def spec(self, axes) -> P:
        """PartitionSpec for a tuple of logical axis names (None = replicated)."""
        entries = []
        for a in axes:
            if a is None:
                entries.append(None)
            else:
                entries.append(self.table.get(a))
        return P(*entries)

    def mesh_spec(self, axes, mesh_axis_names) -> P:
        """Like :meth:`spec` but drops mesh axes absent from the active mesh
        (so the same rules work on 1-device test meshes and 512-chip pods)."""
        entries = []
        for a in axes:
            m = None if a is None else self.table.get(a)
            if m is None:
                entries.append(None)
            elif isinstance(m, (tuple, list)):
                kept = tuple(x for x in m if x in mesh_axis_names)
                # normalize 1-tuples to the bare axis (newer jax does this in
                # PartitionSpec itself; older versions keep the tuple)
                entries.append(None if not kept else
                               kept[0] if len(kept) == 1 else kept)
            else:
                entries.append(m if m in mesh_axis_names else None)
        return P(*entries)

    def shape_spec(self, axes, shape, mesh_axis_sizes) -> P:
        """Divisibility-aware spec: for each dim keep the longest prefix of
        mapped mesh axes whose size product divides the dim (jit argument
        shardings must divide exactly — e.g. 8 KV heads cannot shard over a
        16-way ``model`` axis and fall back to replication). A mesh axis is
        used at most once per spec (first logical axis wins), so rule
        overrides like sequence parallelism cannot produce invalid specs."""
        entries = []
        used: set = set()
        for a, dim in zip(axes, shape):
            m = None if a is None else self.table.get(a)
            if m is None:
                entries.append(None)
                continue
            cand = (m,) if isinstance(m, str) else tuple(m)
            cand = [x for x in cand if x in mesh_axis_sizes and x not in used]
            kept, prod = [], 1
            for x in cand:
                if dim % (prod * mesh_axis_sizes[x]) == 0:
                    kept.append(x)
                    prod *= mesh_axis_sizes[x]
                else:
                    break
            used.update(kept)
            if not kept:
                entries.append(None)
            elif len(kept) == 1:
                entries.append(kept[0])
            else:
                entries.append(tuple(kept))
        return P(*entries)


def spec_for(rules: Rules, axes, mesh=None) -> P:
    names = mesh.axis_names if mesh is not None else None
    if names is None:
        am = get_abstract_mesh()
        names = () if am.empty else am.axis_names
    return rules.mesh_spec(axes, names)


def constrain(x, rules: Rules, *axes):
    """``with_sharding_constraint`` against the ambient mesh; no-op when no
    mesh is active (CPU unit tests) or no referenced axis exists.
    Divisibility-aware, so partially-shardable dims degrade to replication."""
    am = get_abstract_mesh()
    if am.empty:
        return x
    sizes = dict(am.shape)
    spec = rules.shape_spec(axes, x.shape, sizes)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)
