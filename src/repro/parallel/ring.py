"""Ring collectives built from lax.ppermute — the explicit-schedule variant
of psum used when the compiler's default all-reduce must be overlapped
manually (e.g. interleaving gradient reduction with the backward pass).

reduce-scatter (P-1 steps) + all-gather (P-1 steps) over the ICI ring: each
step moves 1/P of the buffer, so link utilization is flat (no incast), which
is exactly why rings are the default at pod scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .compat import axis_size
from jax import lax

__all__ = ["ring_all_reduce", "ring_all_gather"]


def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def ring_all_reduce(x, axis_name: str):
    """Sum x across ``axis_name`` with an explicit reduce-scatter + all-gather
    ring. x's leading dim must be divisible by the axis size."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = lax.axis_index(axis_name)
    if x.size % n:
        raise ValueError(f"buffer size {x.size} not divisible by ring size {n}")
    chunks = x.reshape(n, -1)

    # reduce-scatter: after P-1 steps, chunk (me+1) % n holds the full sum
    def rs_step(i, chunks):
        # chunk index this rank accumulates into at step i
        idx = (me - i + n) % n
        send = jnp.take(chunks, ((me - i + 1) + n) % n, axis=0)
        recv = lax.ppermute(send, axis_name, _ring_perm(n))
        return chunks.at[idx].add(recv)

    chunks = lax.fori_loop(1, n, lambda i, c: rs_step(i, c), chunks)

    # all-gather: circulate the completed chunks (rank r finished (r+1)%n)
    def ag_step(i, chunks):
        idx_send = (me + 2 - i + n) % n
        send = jnp.take(chunks, idx_send, axis=0)
        recv = lax.ppermute(send, axis_name, _ring_perm(n))
        idx_recv = (me + 1 - i + n) % n
        return chunks.at[idx_recv].set(recv)

    chunks = lax.fori_loop(1, n, lambda i, c: ag_step(i, c), chunks)
    return chunks.reshape(x.shape)


def ring_all_gather(x, axis_name: str):
    """Concatenate x blocks from every rank along a new leading axis."""
    n = axis_size(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    me = lax.axis_index(axis_name)
    out = lax.dynamic_update_slice(out, x[None], (me,) + (0,) * x.ndim)

    def step(i, state):
        out, buf = state
        buf = lax.ppermute(buf, axis_name, _ring_perm(n))
        src = (me - i + n) % n
        out = lax.dynamic_update_slice(out, buf[None], (src,) + (0,) * x.ndim)
        return out, buf

    out, _ = lax.fori_loop(1, n, step, (out, x))
    return out
