"""Distribution substrate: logical-axis sharding rules, remat policies,
gradient compression, ring collectives, pipeline parallelism."""

from .sharding import Rules, DEFAULT_RULES, constrain, spec_for

__all__ = ["Rules", "DEFAULT_RULES", "constrain", "spec_for"]
