import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero real allocation:
  * a compiled executable for the production mesh (proves the sharding plan
    is coherent: no mismatched collectives, no impossible layouts),
  * memory_analysis() -> per-device HBM demand (proves it fits / flags what
    doesn't and why),
  * cost_analysis() FLOPs/bytes + a collective-bytes breakdown parsed from
    the partitioned HLO -> the three §Roofline terms.

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>.json and are the
single source for EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, cells_for, get_config
from ..models.model import decode_step, forward
from ..parallel.compat import set_mesh
from ..parallel.sharding import Rules
from ..training.steps import Hyper, make_train_step
from . import hw
from .analytics import cell_analytics, hbm_capacity_check
from .mesh import make_production_mesh
from .specs import count_params, input_specs

# Per-arch microbatch accumulation for train_4k: chosen so layer-boundary
# activations fit HBM (see EXPERIMENTS.md §Dry-run memory table).
TRAIN_ACCUM = {
    "llama3-405b": 32,
    "nemotron-4-340b": 32,
    "deepseek-v2-236b": 8,
    "glm4-9b": 4,
    "minicpm3-4b": 2,
    "musicgen-large": 2,
    "zamba2-1.2b": 2,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum output bytes per collective kind from a partitioned HLO module."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        out.setdefault(op, {"count": 0, "bytes": 0})
        out[op]["count"] += 1
        out[op]["bytes"] += _shape_bytes(shape_str)
    return out


def roofline_terms(flops, hbm_bytes, collectives):
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / hw.HBM_BW
    coll_bytes_eff = sum(
        v["bytes"] * hw.COLLECTIVE_MULTIPLIER[k] for k, v in collectives.items()
    )
    collective_s = coll_bytes_eff / hw.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms


def build_step(cfg, cell, rules: Rules, accum: int = 1):
    if cell.kind == "train":
        hyper = Hyper(accum=accum)
        return make_train_step(cfg, rules, hyper)
    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, _, cache = forward(cfg, params, batch, rules, return_cache=True)
            return logits, cache
        return prefill_step
    def serve_step(params, cache, tok, cur):
        return decode_step(cfg, params, cache, tok, cur, rules)
    return serve_step


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             rules: Rules | None = None, accum: int | None = None,
             extra_tag: str = "", cfg_overrides: dict | None = None):
    cfg = get_config(arch_id)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    cell = SHAPES[shape_name]
    rules = rules or Rules()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if accum is None:
        accum = TRAIN_ACCUM.get(arch_id, 1) if cell.kind == "train" else 1

    step = build_step(cfg, cell, rules, accum)
    args, shardings = input_specs(cfg, cell, rules, mesh)
    donate = (0, 1) if cell.kind == "train" else ((1,) if cell.kind == "decode" else ())

    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(
            step, in_shardings=shardings, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        cost, flops, hbm_bytes = {"error": str(e)}, 0.0, 0.0

    collectives = parse_collectives(compiled.as_text())
    terms = roofline_terms(flops, hbm_bytes, collectives)
    analytic = cell_analytics(cfg, cell, multi_pod, accum)
    capacity = hbm_capacity_check(cfg, cell, multi_pod, accum)

    total_p, active_p = count_params(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    model_flops_global = mult * active_p * tokens
    n_dev = mesh.size
    model_flops_per_dev = model_flops_global / n_dev
    useful_ratio = model_flops_per_dev / flops if flops else None

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "accum": accum,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collectives": collectives,
        "roofline": terms,          # HLO-derived (scan bodies counted once!)
        "analytic": analytic,       # closed-form, primary for §Roofline
        "hbm_capacity": capacity,
        "params_total": total_p,
        "params_active": active_p,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": useful_ratio,
        "memory_analysis": mem_info,
        "tag": extra_tag,
    }
    return record


def artifact_path(record, out_dir="artifacts/dryrun"):
    d = os.path.join(out_dir, record["mesh"])
    os.makedirs(d, exist_ok=True)
    tag = f"__{record['tag']}" if record["tag"] else ""
    return os.path.join(d, f"{record['arch']}__{record['shape']}{tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        cells = cells_for(cfg)
        for cell in cells:
            if args.shape != "all" and cell.name != args.shape:
                continue
            for mp in meshes:
                tagp = f"{arch} x {cell.name} x {'2x16x16' if mp else '16x16'}"
                probe = {"arch": arch, "shape": cell.name,
                         "mesh": "2x16x16" if mp else "16x16", "tag": ""}
                path = artifact_path(probe, args.out)
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tagp}")
                    continue
                try:
                    rec = run_cell(arch, cell.name, mp)
                    with open(artifact_path(rec, args.out), "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"[ok]   {tagp}: compile={rec['compile_s']}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"bottleneck={r['bottleneck']}")
                except Exception as e:
                    failures.append((tagp, str(e)))
                    print(f"[FAIL] {tagp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for t, e in failures:
            print(" -", t, e.splitlines()[0] if e else "")
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
