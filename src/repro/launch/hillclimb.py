import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede every other import (same contract as dryrun.py)

"""§Perf hillclimb runner: re-lower the three selected cells under
optimization variants and record hypothesis -> change -> before -> after.

Selected cells (from the baseline roofline table, see EXPERIMENTS.md §Perf):
  1. llama3-405b  x train_4k   — worst HBM capacity + huge FSDP gather term
  2. deepseek-v2  x train_4k   — the paper-technique cell (sort MoE dispatch,
                                 EP all_to_all); most collective-bound train
  3. glm4-9b      x decode_32k — collective-bound decode; weights-resident
                                 serving plan

Each variant is BOTH re-lowered on the production mesh (proving the plan
compiles and measuring HLO/memory effects) AND evaluated with the analytic
model (launch/analytics.py) which is immune to the XLA while-body-once
costing limitation.
"""

import argparse
import json

from ..configs import SHAPES, get_config
from ..parallel.sharding import Rules
from .analytics import cell_analytics, hbm_capacity_check
from .dryrun import artifact_path, run_cell

VARIANTS = {
    # cell 1: llama3-405b train — hypothesis: SP shards saved residuals 16x,
    # letting accum drop 32 -> 8 -> 4, which cuts FSDP all-gather traffic
    # proportionally (the dominant term).
    "llama3-405b/train_4k": [
        dict(tag="baseline", accum=32, sp=False),
        dict(tag="sp_accum32", accum=32, sp=True),
        dict(tag="sp_accum8", accum=8, sp=True),
        dict(tag="sp_accum4", accum=4, sp=True),
        # int8+EF activation all-reduce: mechanism in parallel/compression.py
        # (property-tested); modeled analytically, lowering unchanged.
        dict(tag="sp_accum8_int8ar", accum=8, sp=True, int8=True,
             analytic_only=True),
    ],
    # cell 2: deepseek-v2 train — same SP+accum lever; EP a2a stays constant
    # (payload is real tokens, the paper's sort dispatch keeps it compact).
    "deepseek-v2-236b/train_4k": [
        dict(tag="baseline", accum=8, sp=False),
        dict(tag="sp_accum4", accum=4, sp=True),
        dict(tag="sp_accum2", accum=2, sp=True),
        dict(tag="sp_accum1", accum=1, sp=True),
        dict(tag="sp_accum1_int8ar", accum=1, sp=True, int8=True,
             analytic_only=True),
    ],
    # cell 3: glm4-9b decode — hypothesis: params TP-resident (no FSDP
    # gather per step) turns the step collective term into pure activation
    # all-reduces.
    "glm4-9b/decode_32k": [
        dict(tag="baseline", accum=1, sp=False),
        dict(tag="resident", accum=1, sp=False, weights_resident=True),
    ],
    # bonus cell: nemotron prefill — hypothesis: the (T,S) score buffers in
    # the non-streaming path dominate the compiled temp memory; chunked
    # streaming attention removes them. Verified directly on the compiled
    # artifact's memory_analysis (temp bytes), not just the analytic model.
    "nemotron-4-340b/prefill_32k": [
        dict(tag="baseline", accum=1, sp=False),
        dict(tag="chunked_attn", accum=1, sp=False,
             cfg_overrides={"attn_kv_chunk": 2048}),
    ],
}


def rules_for(variant) -> Rules:
    r = Rules()
    if variant.get("sp"):
        r = r.override(res_seq="model")
    if variant.get("weights_resident"):
        r = r.override(embed=None)  # params shard over `model` only
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/hillclimb")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    results = []
    for cell_key, variants in VARIANTS.items():
        if args.only and args.only not in cell_key:
            continue
        arch, shape = cell_key.split("/")
        cfg = get_config(arch)
        cell = SHAPES[shape]
        for v in variants:
            if v.get("analytic_only"):
                # the optimization does not change the lowered graph (e.g.
                # int8 collectives replace the AR implementation, not the
                # program structure) — record analytics only.
                rec = {"arch": arch, "shape": shape, "mesh": "16x16",
                       "kind": cell.kind, "accum": v["accum"],
                       "compile_s": 0.0, "tag": v["tag"]}
            else:
                rules = rules_for(v)
                rec = run_cell(arch, shape, multi_pod=False, rules=rules,
                               accum=v["accum"], extra_tag=v["tag"],
                               cfg_overrides=v.get("cfg_overrides"))
            # re-derive analytics with the variant's levers
            rec["analytic"] = cell_analytics(
                cfg, cell, multi_pod=False, accum=v["accum"],
                sp=v.get("sp", False),
                weights_resident=v.get("weights_resident", False),
                int8_collectives=v.get("int8", False))
            rec["hbm_capacity"] = hbm_capacity_check(
                cfg, cell, multi_pod=False, accum=v["accum"],
                sp=v.get("sp", False),
                weights_resident=v.get("weights_resident", False))
            rec["variant"] = v
            path = os.path.join(args.out, f"{arch}__{shape}__{v['tag']}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            a = rec["analytic"]
            print(f"[{cell_key} :: {v['tag']}] compile={rec['compile_s']}s "
                  f"dominant={a['roofline']['bottleneck']} "
                  f"bound={a['step_time_bound_s']:.3f}s "
                  f"rooffrac={a['roofline_fraction']:.3f} "
                  f"hbm={rec['hbm_capacity']['total_gib']:.1f}GiB "
                  f"fits={rec['hbm_capacity']['fits']}")
            results.append(rec)
    print(f"\n{len(results)} variants recorded in {args.out}")


if __name__ == "__main__":
    main()
