"""Closed-form per-device FLOPs / HBM-bytes / collective-bytes accounting.

Why this exists: XLA's ``cost_analysis()`` on the CPU backend counts each
``while``-loop body ONCE, so for scan-over-layers models the reported FLOPs
are low by ~n_layers (verified: a 10-iteration scan of 128x128 matmuls
reports the FLOPs of one). The dry-run therefore records *both* the HLO
numbers (cross-check, correct for non-loop collectives) and these analytic
terms (primary §Roofline source). All formulas below are standard
transformer accounting; assumptions are explicit per function.

Sharding assumptions mirror parallel/sharding.DEFAULT_RULES:
  batch over (pod, data); TP over model (heads/mlp/vocab/experts);
  FSDP over data (params gathered per layer inside the scan);
  gradients reduce-scattered over data, all-reduced over pod.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from ..configs import ShapeCell
from ..models.config import ModelConfig
from . import hw

__all__ = ["cell_analytics", "hbm_capacity_check"]


def _param_count(cfg: ModelConfig) -> tuple[int, int]:
    from .specs import count_params
    return count_params(cfg)


def _attn_flops_per_layer(cfg: ModelConfig, tokens: int, ctx: float, decode: bool) -> float:
    d = cfg.d_model
    if cfg.attn == "mla":
        m = cfg.mla
        h = cfg.n_heads
        q_proj = 2 * tokens * (d * m.q_lora + m.q_lora * h * (m.qk_nope + m.qk_rope)) \
            if m.q_lora else 2 * tokens * d * h * (m.qk_nope + m.qk_rope)
        kv_a = 2 * tokens * d * (m.kv_lora + m.qk_rope)
        if decode:
            # absorbed path: scores/ctx run in the latent space
            absorb = 2 * tokens * h * m.qk_nope * m.kv_lora
            scores = 2 * tokens * ctx * h * (m.kv_lora + m.qk_rope)
            ctx_f = 2 * tokens * ctx * h * m.kv_lora
            up_v = 2 * tokens * h * m.kv_lora * m.v_head
            o = 2 * tokens * h * m.v_head * d
            return q_proj + kv_a + absorb + scores + ctx_f + up_v + o
        kv_b = 2 * tokens * m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_head)
        scores = 2 * tokens * ctx * h * (m.qk_nope + m.qk_rope)
        av = 2 * tokens * ctx * h * m.v_head
        o = 2 * tokens * h * m.v_head * d
        return q_proj + kv_a + kv_b + scores + av + o
    # GQA
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * tokens * d * (h * dh + 2 * kh * dh + h * dh)
    scores_av = 2 * tokens * ctx * h * dh * 2
    return proj + scores_av


def _mlp_flops(cfg, tokens, d_ff) -> float:
    mult = 3 if cfg.mlp_gated else 2
    return 2 * tokens * cfg.d_model * d_ff * mult


def _moe_flops_per_layer(cfg, tokens) -> float:
    m = cfg.moe
    routed = 2 * tokens * m.top_k * cfg.d_model * m.d_expert * (3 if cfg.mlp_gated else 2)
    shared = _mlp_flops(cfg, tokens, m.n_shared * m.d_shared) if m.n_shared else 0.0
    router = 2 * tokens * cfg.d_model * m.n_experts
    # sort-based dispatch: O(Tk log Tk) comparator work, negligible FLOPs
    return routed + shared + router


def _mamba_flops_per_layer(cfg, tokens) -> float:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.headdim
    conv_dim = di + 2 * s.n_groups * s.d_state
    in_proj = 2 * tokens * cfg.d_model * (2 * di + 2 * s.n_groups * s.d_state + nh)
    conv = 2 * tokens * s.d_conv * conv_dim
    # SSD: intra-chunk quadratic (chunk Q) + state path, both O(T Q di) / O(T di N)
    q = min(s.chunk, max(tokens, 1))
    ssd = 2 * tokens * q * di + 4 * tokens * di * s.d_state
    out = 2 * tokens * di * cfg.d_model
    return in_proj + conv + ssd + out


def _layer_flops(cfg: ModelConfig, tokens: int, ctx: float, decode: bool) -> float:
    """Forward FLOPs of ONE layer (attention/moe/mamba per family)."""
    if cfg.family in ("ssm", "hybrid"):
        f = _mamba_flops_per_layer(cfg, tokens)
        return f
    attn = _attn_flops_per_layer(cfg, tokens, ctx, decode)
    if cfg.family == "moe":
        return attn + _moe_flops_per_layer(cfg, tokens)
    return attn + _mlp_flops(cfg, tokens, cfg.d_ff)


def _forward_flops_global(cfg: ModelConfig, cell: ShapeCell) -> float:
    decode = cell.kind == "decode"
    tokens = cell.global_batch * (1 if decode else cell.seq_len)
    ctx = float(cell.seq_len) if decode else cell.seq_len / 2.0  # causal avg
    total = cfg.n_layers * _layer_flops(cfg, tokens, ctx, decode)
    if cfg.family == "hybrid":
        n_apps = -(-cfg.n_layers // cfg.hybrid_period)
        total += n_apps * (_attn_flops_per_layer(cfg, tokens, ctx, decode)
                           + _mlp_flops(cfg, tokens, cfg.d_ff))
    total += 2 * tokens * cfg.d_model * cfg.vocab_size  # lm head
    return total


@dataclasses.dataclass
class MeshModel:
    pod: int
    data: int
    model: int

    @property
    def devices(self):
        return self.pod * self.data * self.model

    @property
    def batch_shards(self):
        return self.pod * self.data


def cell_analytics(cfg: ModelConfig, cell: ShapeCell, multi_pod: bool,
                   accum: int = 1, sp: bool = False,
                   weights_resident: bool = False,
                   int8_collectives: bool = False) -> Dict:
    """Per-device roofline terms for one cell.

    Variants (the §Perf hillclimb levers):
      sp                 Megatron sequence parallelism: residual activations
                         sharded over `model`; activation HBM and saved-residual
                         memory drop ~16x; the TP all-reduce becomes
                         reduce-scatter + all-gather (same bytes).
      weights_resident   inference plan: params sharded over `model` only and
                         resident (no per-step FSDP gather); valid when
                         P_bytes/model fits HBM alongside the cache.
      int8_collectives   activation all-reduces quantized int8 with error
                         feedback (parallel/compression.py): halves the bf16
                         TP/pod payload. Modeled here; the collective itself
                         is implemented and property-tested in shard_map form.
    """
    mesh = MeshModel(2 if multi_pod else 1, 16, 16)
    bytes_per_param = 2 if cfg.param_dtype == "bfloat16" else 4
    total_p, active_p = _param_count(cfg)
    p_bytes = total_p * bytes_per_param

    decode = cell.kind == "decode"
    train = cell.kind == "train"
    tokens_global = cell.global_batch * (1 if decode else cell.seq_len)
    tokens_loc = tokens_global / mesh.batch_shards

    fwd = _forward_flops_global(cfg, cell)
    if train:
        # bwd = 2x fwd; full remat recomputes the forward once more
        mult_f = 4.0 if cfg.remat == "full" else 3.0
    else:
        mult_f = 1.0
    flops_global = fwd * mult_f
    flops_dev = flops_global / mesh.devices

    # ---- HBM bytes per device ----
    # weights: gathered per layer => each device streams the full TP shard
    # of every layer (fwd + bwd) per microbatch; optimizer touches the local
    # FSDP shard only.
    act_bytes_elem = 2 if cfg.compute_dtype == "bfloat16" else 4
    w_stream = (p_bytes / mesh.model) * (2 * accum if train else 1)
    opt_touch = (p_bytes / (mesh.model * mesh.data)) * (6 if train else 0)
    act_shard = mesh.model if sp else 1
    act_traffic = 10.0 * tokens_loc * cfg.d_model * act_bytes_elem * cfg.n_layers \
        * (3.0 if train else 1.0) / act_shard
    logits_traffic = 3.0 * tokens_loc * (cfg.vocab_size / mesh.model) * 4
    cache_traffic = 0.0
    if decode:
        cache_traffic = _cache_bytes_global(cfg, cell) / mesh.devices
    hbm_dev = w_stream + opt_touch + act_traffic + logits_traffic + cache_traffic

    # ---- collective bytes per device (payload; multipliers in hw) ----
    coll = {}
    # TP all-reduce of activations: 2 per layer fwd (+2 bwd when training).
    # Under SP the AR becomes RS+AG with identical total payload.
    ars_per_layer = 4 if train else 2
    coll["tp_all_reduce"] = (cfg.n_layers * ars_per_layer
                             * tokens_loc * cfg.d_model * act_bytes_elem)
    # FSDP all-gather of params (per microbatch, fwd+bwd) over data axis
    fsdp_frac = (mesh.data - 1) / mesh.data
    if weights_resident and not train:
        coll["fsdp_all_gather"] = 0.0   # params live TP-sharded, no gather
    else:
        coll["fsdp_all_gather"] = (p_bytes / mesh.model) * fsdp_frac \
            * ((2 * accum) if train else 1)
    if train:
        # grad reduce-scatter over data + all-reduce over pods (DCN)
        coll["grad_reduce_scatter"] = (p_bytes / mesh.model) * fsdp_frac
        if mesh.pod > 1:
            coll["pod_grad_all_reduce"] = p_bytes / (mesh.model * mesh.data)
    if cfg.family == "moe":
        k = cfg.moe.top_k
        a2a = tokens_loc * k * cfg.d_model * act_bytes_elem * 2  # there+back
        coll["ep_all_to_all"] = a2a * (3.0 if train else 1.0)
    coll_bytes = sum(coll.values())

    compute_s = flops_dev / hw.PEAK_FLOPS_BF16
    memory_s = hbm_dev / hw.HBM_BW
    act_coll_scale = 0.5 if int8_collectives else 1.0  # bf16 -> int8 payload
    collective_s = (
        coll["tp_all_reduce"] * 2.0 * act_coll_scale
        + coll.get("fsdp_all_gather", 0.0)
        + coll.get("grad_reduce_scatter", 0.0)
        + coll.get("pod_grad_all_reduce", 0.0) * 2.0 * act_coll_scale
        + coll.get("ep_all_to_all", 0.0)
    ) / hw.ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mult = 6 if train else 2
    model_flops_dev = mult * active_p * tokens_global / mesh.devices
    return {
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": hbm_dev,
        "collective_bytes_per_device": coll_bytes,
        "collective_breakdown": coll,
        "roofline": dict(terms, bottleneck=dominant),
        "useful_flops_ratio": model_flops_dev / flops_dev if flops_dev else None,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": compute_s / max(terms.values()) if max(terms.values()) else 0.0,
    }


def _cache_bytes_global(cfg: ModelConfig, cell: ShapeCell) -> float:
    b, s = cell.global_batch, cell.seq_len
    elem = 2 if cfg.compute_dtype == "bfloat16" else 4
    if cfg.family == "ssm":
        st = cfg.ssm
        di = st.expand * cfg.d_model
        nh = di // st.headdim
        conv_dim = di + 2 * st.n_groups * st.d_state
        per_layer = b * ((st.d_conv - 1) * conv_dim * elem
                         + nh * st.headdim * st.d_state * 4)
        return cfg.n_layers * per_layer
    if cfg.family == "hybrid":
        st = cfg.ssm
        di = st.expand * cfg.d_model
        nh = di // st.headdim
        conv_dim = di + 2 * st.n_groups * st.d_state
        mamba = cfg.n_layers * b * ((st.d_conv - 1) * conv_dim * elem
                                    + nh * st.headdim * st.d_state * 4)
        n_apps = -(-cfg.n_layers // cfg.hybrid_period)
        attn = n_apps * b * s * 2 * cfg.n_kv_heads * cfg.head_dim * elem
        return mamba + attn
    if cfg.attn == "mla":
        return cfg.n_layers * b * s * (cfg.mla.kv_lora + cfg.mla.qk_rope) * elem
    return cfg.n_layers * b * s * 2 * cfg.n_kv_heads * cfg.head_dim * elem


def hbm_capacity_check(cfg: ModelConfig, cell: ShapeCell, multi_pod: bool,
                       accum: int = 1, sp: bool = False,
                       weights_resident: bool = False) -> Dict:
    """Static per-device HBM demand vs the 16 GiB v5e budget."""
    mesh = MeshModel(2 if multi_pod else 1, 16, 16)
    bpp = 2 if cfg.param_dtype == "bfloat16" else 4
    bpo = 2 if cfg.optim_dtype == "bfloat16" else 4
    total_p, _ = _param_count(cfg)
    # params: FSDP x TP sharded, or TP-only when resident for inference
    shard = mesh.model if weights_resident else mesh.model * mesh.data
    params = total_p * bpp / shard
    train = cell.kind == "train"
    opt = total_p * 2 * bpo / (mesh.model * mesh.data) if train else 0.0
    grads = total_p * bpp / (mesh.model * mesh.data) if train else 0.0
    act_elem = 2 if cfg.compute_dtype == "bfloat16" else 4
    act_shard = mesh.model if sp else 1
    if train:
        tokens_loc = cell.global_batch * cell.seq_len / (mesh.batch_shards * accum)
        # residual saved per layer boundary (full remat inside layers)
        acts = tokens_loc * cfg.d_model * act_elem * cfg.n_layers / act_shard
        logits = tokens_loc * cfg.vocab_size / mesh.model * 4
    else:
        tokens_loc = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len) \
            / mesh.batch_shards
        acts = tokens_loc * cfg.d_model * act_elem * 4 / act_shard
        logits = tokens_loc * cfg.vocab_size / mesh.model * 4
    cache = _cache_bytes_global(cfg, cell) / mesh.devices if cell.kind != "train" else 0.0
    total = params + opt + grads + acts + logits + cache
    return {
        "params_gib": params / 2**30,
        "opt_gib": opt / 2**30,
        "grads_gib": grads / 2**30,
        "activations_gib": acts / 2**30,
        "logits_gib": logits / 2**30,
        "cache_gib": cache / 2**30,
        "total_gib": total / 2**30,
        "budget_gib": hw.HBM_BYTES / 2**30,
        "fits": total <= hw.HBM_BYTES,
    }
