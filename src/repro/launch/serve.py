"""Serving driver: bring up an Engine + the paper's length-bucketed
scheduler on synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models.model import init_lm
from ..parallel.sharding import Rules
from ..serve import BucketedScheduler, Engine, Request

__all__ = ["main"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.input_kind != "tokens":
        raise SystemExit("serving driver targets token archs (frontend stubs "
                         "provide embeddings, not token streams)")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, Rules(), max_seq=args.max_seq)
    sched = BucketedScheduler(engine, batch_size=8)

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, list(rng.integers(1, cfg.vocab_size, rng.integers(4, 48))),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = sched.run(reqs)
    dt = time.time() - t0
    gen = sum(len(r.tokens) for r in results)
    print(f"{len(results)} requests, {gen} tokens in {dt:.2f}s "
          f"({gen / dt:.1f} tok/s)")
    stats = BucketedScheduler.padding_stats(
        reqs, bounds=[8, 16, 32, 48])
    print("padding waste:", stats)


if __name__ == "__main__":
    main()
