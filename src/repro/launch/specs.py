"""ShapeDtypeStruct input stand-ins + shardings for every dry-run cell.

``input_specs`` builds the exact argument pytrees (abstract, zero
allocation) that ``train_step`` / ``prefill_step`` / ``serve_step`` are
lowered against, together with matching NamedShardings derived from the
logical-axis rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import ShapeCell
from ..models.config import ModelConfig
from ..models.model import init_cache, init_lm
from ..models.param import tree_specs
from ..optim import init_opt_state, opt_state_axes
from ..parallel.sharding import Rules

__all__ = ["input_specs", "abstract_state", "shardings_for", "count_params"]


def _dt(name):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def shardings_for(axes_tree, rules: Rules, mesh, value_tree=None):
    specs = tree_specs(axes_tree, rules, mesh, value_tree)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def abstract_state(cfg: ModelConfig, rules: Rules, mesh, with_opt: bool = True):
    """(params_sds, params_shardings[, opt_sds, opt_shardings])."""
    params, axes = init_lm(cfg, abstract=True)
    p_shard = shardings_for(axes, rules, mesh, params)
    if not with_opt:
        return params, p_shard
    opt = init_opt_state(params, moment_dtype=_dt(cfg.optim_dtype), abstract=True)
    o_axes = opt_state_axes(axes)
    o_shard = shardings_for(o_axes, rules, mesh, opt)
    return params, p_shard, opt, o_shard


def _ns(mesh, rules: Rules, axes, shape):
    return NamedSharding(mesh, rules.shape_spec(axes, shape, dict(mesh.shape)))


def _batch_specs(cfg: ModelConfig, cell: ShapeCell, rules: Rules, mesh, with_labels: bool):
    b, s = cell.global_batch, cell.seq_len
    tree, shard = {}, {}
    if cfg.input_kind == "tokens":
        tree["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        shard["tokens"] = _ns(mesh, rules, ("batch", "seq"), (b, s))
    else:
        tree["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), _dt(cfg.compute_dtype))
        shard["frames"] = _ns(mesh, rules, ("batch", "seq", "act_embed"), (b, s, cfg.d_model))
    if cfg.rope_kind == "mrope":
        tree["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        shard["positions"] = _ns(mesh, rules, ("batch", "seq", None), (b, s, 3))
    if with_labels:
        tree["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        shard["labels"] = _ns(mesh, rules, ("batch", "seq"), (b, s))
    return tree, shard


def input_specs(cfg: ModelConfig, cell: ShapeCell, rules: Rules, mesh):
    """Returns (args_sds, args_shardings) for the cell's step function.

    train:   (params, opt_state, batch, step)
    prefill: (params, batch)
    decode:  (params, cache, tok, cur_index)
    """
    if cell.kind == "train":
        params, p_sh, opt, o_sh = abstract_state(cfg, rules, mesh)
        batch, b_sh = _batch_specs(cfg, cell, rules, mesh, with_labels=True)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        s_sh = NamedSharding(mesh, jax.sharding.PartitionSpec())
        return (params, opt, batch, step), (p_sh, o_sh, b_sh, s_sh)

    if cell.kind == "prefill":
        params, p_sh = abstract_state(cfg, rules, mesh, with_opt=False)
        batch, b_sh = _batch_specs(cfg, cell, rules, mesh, with_labels=False)
        return (params, batch), (p_sh, b_sh)

    if cell.kind == "decode":
        params, p_sh = abstract_state(cfg, rules, mesh, with_opt=False)
        cache, c_axes = init_cache(cfg, cell.global_batch, cell.seq_len, abstract=True)
        c_sh = shardings_for(c_axes, rules, mesh, cache)
        if cfg.input_kind == "tokens":
            tok = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
            t_sh = _ns(mesh, rules, ("cache_batch", None), tok.shape)
        else:
            tok = jax.ShapeDtypeStruct((cell.global_batch, 1, cfg.d_model), _dt(cfg.compute_dtype))
            t_sh = _ns(mesh, rules, ("cache_batch", None, None), tok.shape)
        cur = jax.ShapeDtypeStruct((), jnp.int32)
        cur_sh = NamedSharding(mesh, jax.sharding.PartitionSpec())
        return (params, cache, tok, cur), (p_sh, c_sh, t_sh, cur_sh)

    raise ValueError(f"unknown cell kind {cell.kind!r}")


def count_params(cfg: ModelConfig):
    """(total, active) parameter counts from the abstract tree."""
    params, _ = init_lm(cfg, abstract=True)
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    total = 0
    expert = 0
    for path, leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = jax.tree_util.keystr(path)
        if "moe" in keys and ("w_in" in keys or "w_out" in keys):
            expert += n
    active = total - expert
    if cfg.moe is not None and expert:
        active += expert * cfg.moe.top_k // cfg.moe.n_experts
    return total, active
