"""Mesh construction. Functions, never module-level constants — importing
this module must not touch jax device state (the dry-run sets
XLA_FLAGS before its first jax call; tests run on 1 device)."""

from __future__ import annotations

import jax

from ..parallel.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_elastic_mesh", "make_test_mesh"]


def _mk(shape, axes):
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_elastic_mesh(n_devices: int, model_parallel: int = 1):
    """Largest (data, model) mesh the surviving devices can form —
    the ElasticSupervisor rebuilds with this after a failure."""
    model = model_parallel
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model
    return _mk((data, model), ("data", "model"))


def make_test_mesh():
    """Whatever this host has (1 device in CI, 8 with XLA_FLAGS)."""
    n = len(jax.devices())
    if n >= 4:
        return _mk((n // 2, 2), ("data", "model"))
    return _mk((n, 1), ("data", "model"))
