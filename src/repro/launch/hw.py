"""Target-hardware constants (TPU v5e) used by the roofline analysis.

This container executes on CPU; these numbers parameterize the *model* of
the machine the dry-run compiles for. Sources: assignment spec.
"""

PEAK_FLOPS_BF16 = 197e12     # per chip, bf16
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~)
HBM_BYTES = 16 * 1024**3     # 16 GiB per chip

# effective bytes moved per element of collective *output*, ring algorithms:
#   all-reduce = reduce-scatter + all-gather  -> ~2x payload over the slowest link
#   all-gather / reduce-scatter / all-to-all / collective-permute -> ~1x
COLLECTIVE_MULTIPLIER = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
