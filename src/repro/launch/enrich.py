"""Post-hoc enrichment: add the analytic roofline + HBM-capacity blocks to
dry-run artifacts produced before analytics existed (no recompilation).

    PYTHONPATH=src python -m repro.launch.enrich [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from ..configs import SHAPES, get_config
from .analytics import cell_analytics, hbm_capacity_check


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    n = 0
    for path in glob.glob(os.path.join(args.dir, "*", "*.json")):
        with open(path) as f:
            rec = json.load(f)
        cfg = get_config(rec["arch"])
        cell = SHAPES[rec["shape"]]
        multi_pod = rec["mesh"] == "2x16x16"
        rec["analytic"] = cell_analytics(cfg, cell, multi_pod, rec.get("accum", 1))
        rec["hbm_capacity"] = hbm_capacity_check(cfg, cell, multi_pod, rec.get("accum", 1))
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"enriched {n} artifacts")


if __name__ == "__main__":
    main()
