"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --smoke --steps 50

``--smoke`` trains the reduced config on this host (the path CI exercises);
the full config path builds the production mesh and is exercised by the
dry-run. Fault tolerance: periodic async checkpoints, ElasticSupervisor
around the step loop, simulated failure injection via --fail-at, straggler
monitor on step times.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke_config
from ..data import ShardedLoader, TokenStream
from ..models.model import init_lm
from ..optim import init_opt_state
from ..parallel.sharding import Rules
from ..runtime import ElasticSupervisor, FailureInjector, StragglerMonitor
from ..training import Hyper, make_train_step

__all__ = ["train_loop", "main"]


def _make_batch_iter(cfg, batch, seq, seed=0):
    if cfg.input_kind == "tokens":
        return iter(TokenStream(cfg.vocab_size, batch, seq, seed=seed))

    def frames():
        rng = np.random.default_rng(seed)
        while True:
            f = rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)
            l = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32)
            yield {"frames": f, "labels": l}

    return frames()


def train_loop(cfg, steps: int = 20, batch: int = 4, seq: int = 32,
               ckpt_dir: str | None = None, ckpt_every: int = 10,
               fail_at=(), hyper: Hyper | None = None, verbose: bool = True):
    """Single-host training loop with checkpoint/restart + failure recovery.

    Returns (final_params, losses, recovery_events)."""
    rules = Rules()
    hyper = hyper or Hyper(lr=1e-3, warmup=5, total_steps=steps)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, rules, hyper), donate_argnums=(0, 1))

    ckpt = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    injector = FailureInjector(fail_at)
    monitor = StragglerMonitor()
    losses = []
    # host-side copy of the initial params: device buffers get donated into
    # the step, so a cold restart must not touch them
    init_host = jax.tree.map(lambda x: np.asarray(x), params)

    def run_segment(state, start_step, devices):
        params, opt = state
        data = ShardedLoader(_make_batch_iter(cfg, batch, seq), prefetch=2)
        try:
            for step in range(start_step, steps):
                t0 = time.time()
                injector.check(step)
                b = next(data)
                params, opt, metrics = step_fn(
                    params, opt, jax.tree.map(jnp.asarray, b), jnp.int32(step))
                loss = float(metrics["loss"])
                losses.append(loss)
                monitor.record(step, time.time() - t0)
                if ckpt and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, {"params": params, "opt": opt})
                if verbose and (step % max(1, steps // 10) == 0):
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
        finally:
            data.close()
        return params, opt

    if ckpt is None:
        out = run_segment((params, opt), 0, 1)
        return out[0], losses, []

    def remesh(devices):
        # single-host recovery: restore the latest snapshot (on a real pod
        # this also rebuilds the mesh via make_elastic_mesh + reshards).
        # No snapshot yet => cold restart from the initial state.
        fresh = jax.tree.map(jnp.asarray, init_host)
        target = {"params": jax.tree.map(lambda x: x, fresh),
                  "opt": init_opt_state(fresh)}
        step, state = ckpt.restore_latest(target)
        if step is None:
            return 0, (fresh, init_opt_state(fresh))
        return step, (state["params"], state["opt"])

    # single-host: a "failed" device is the restarted process itself, so the
    # world size never shrinks (restartable recovery, not an elastic shrink)
    sup = ElasticSupervisor(ckpt, initial_devices=len(jax.devices()),
                            restartable=True)
    out = sup.run(run_segment, remesh, (params, opt), 0)
    return out[0], losses, sup.events


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config on this host")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, losses, events = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, fail_at=tuple(args.fail_at))
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f}); "
          f"{len(events)} recoveries")


if __name__ == "__main__":
    main()
