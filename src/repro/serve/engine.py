"""Batched generation engine.

Requests are right-padded to their bucket bound; every request tracks its own
``cur_index`` so a batch decodes continuously even with heterogeneous prompt
lengths (per-row cache writes + per-row attention masks — see
models/attention.py ``_cache_write``/``_decode_mask``). SSM archs mask dt at
padded prefill positions so states stop exactly at each prompt's end.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.model import decode_step, forward, init_cache
from ..parallel.sharding import Rules

__all__ = ["Engine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    request_id: object
    tokens: List[int]


def _pad_cache_to(cache, axes, target_seq: int):
    """Grow every 'cache_seq' dimension to the decode capacity."""

    def pad(leaf, ax):
        if "cache_seq" not in ax:
            return leaf
        dim = ax.index("cache_seq")  # axes tuples include the stacked 'layers' dim
        pad_widths = [(0, 0)] * leaf.ndim
        pad_widths[dim] = (0, target_seq - leaf.shape[dim])
        return jnp.pad(leaf, pad_widths)

    return jax.tree.map(
        pad, cache, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


class Engine:
    """Prefill + synchronized continuous decode for one model."""

    def __init__(self, cfg: ModelConfig, params, rules: Optional[Rules] = None,
                 max_seq: int = 256, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.rules = rules or Rules()
        self.max_seq = max_seq
        self.eos_id = eos_id

        @jax.jit
        def _prefill(params, tokens, seq_mask):
            logits, _, cache = forward(
                cfg, params, {"tokens": tokens, "seq_mask": seq_mask},
                self.rules, return_cache=True,
            )
            return logits, cache

        @jax.jit
        def _decode(params, cache, tok, cur):
            logits, cache = decode_step(cfg, params, cache, tok, cur, self.rules)
            return logits[:, 0], cache

        self._prefill = _prefill
        self._decode = _decode

    def generate(self, prompts: List[List[int]], max_new: int = 16,
                 greedy: bool = True, seed: int = 0) -> List[List[int]]:
        """Generate for a batch of variable-length prompts (one bucket)."""
        cfg = self.cfg
        bsz = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        bound = int(lens.max())
        toks = np.zeros((bsz, bound), np.int32)
        mask = np.zeros((bsz, bound), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
            mask[i, : len(p)] = 1

        logits, cache = self._prefill(self.params, jnp.asarray(toks), jnp.asarray(mask))
        cache_axes = init_cache(cfg, bsz, bound, abstract=True)[1]
        cache = _pad_cache_to(cache, cache_axes, self.max_seq)

        # next token comes from each prompt's *last real* logits row
        last = jnp.asarray(lens - 1)
        cur_logits = jnp.take_along_axis(
            logits, last[:, None, None].astype(jnp.int32), axis=1
        )[:, 0]

        out = [[] for _ in range(bsz)]
        cur = jnp.asarray(lens)  # position to write the next token
        key = jax.random.PRNGKey(seed)
        done = np.zeros((bsz,), bool)
        for step in range(max_new):
            if greedy:
                nxt = jnp.argmax(cur_logits, axis=-1).astype(jnp.int32)
            else:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, cur_logits).astype(jnp.int32)
            nxt_np = np.asarray(nxt)
            for i in range(bsz):
                if not done[i]:
                    out[i].append(int(nxt_np[i]))
                    if self.eos_id is not None and nxt_np[i] == self.eos_id:
                        done[i] = True
            if done.all() or step == max_new - 1:
                break
            cur_logits, cache = self._decode(self.params, cache, nxt[:, None], cur)
            cur = cur + 1
        return out
