"""Length-bucketed admission scheduler — the paper's technique at the
serving layer.

Identical statistic to the paper's pre-processing: requests are distributed
into buckets by prompt length; each bucket forms dense batches that decode
together (padding only up to the bucket bound, not the global max). Within a
bucket, requests are ordered length-then-alphabetic through the lexicographic
kernel front-end (``repro.kernels.ops.sort_lex``: length lane + prompt-prefix
token lanes), so each fixed-size chunk groups near-equal lengths — shrinking
intra-batch padding — and equal-length prompts admit in token order for
prefix locality. The measured padding-waste reduction vs naive FIFO batching is the
serving benchmark (benchmarks/bench_serving.py).

Queues too deep for one device can shard the admission sort across a mesh:
pass ``admission_mesh`` and the ordering routes through
``repro.core.distributed.distributed_sort_lex`` (same lane layout, same
shortlex order, engine picked by ``core.distributed.choose_engine``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..data.bucketing import plan_buckets
from ..kernels.ops import sort_lex
from ..pipeline.histogram import assign_buckets
from .engine import Engine, GenerationResult

__all__ = ["Request", "BucketedScheduler"]


@dataclasses.dataclass
class Request:
    request_id: object
    prompt: List[int]
    max_new: int = 16


class BucketedScheduler:
    """Batches requests by prompt-length bucket and runs them through an
    Engine. ``bounds=None`` plans quantile buckets from the first wave."""

    def __init__(self, engine: Engine, batch_size: int = 8,
                 bounds: Optional[Sequence[int]] = None, n_buckets: int = 4,
                 admission_mesh=None, admission_axis: str = "data"):
        self.engine = engine
        self.batch_size = batch_size
        self.bounds = list(bounds) if bounds else None
        self.n_buckets = n_buckets
        # optional: shard the admission sort over a mesh axis for queues
        # beyond one device (core/distributed engines; None = single device)
        self.admission_mesh = admission_mesh
        self.admission_axis = admission_axis

    def run(self, requests: List[Request]) -> List[GenerationResult]:
        if not requests:
            return []
        lengths = [len(r.prompt) for r in requests]
        bounds = self.bounds or plan_buckets(lengths, self.n_buckets)

        # shared phase-1 statistic (pipeline.histogram): one vectorized
        # searchsorted assigns every request, over-long prompts clamp to the
        # last bucket — the same utility data.bucketing plans with
        buckets: dict[int, list] = {i: [] for i in range(len(bounds))}
        for r, b in zip(requests, assign_buckets(lengths, bounds, clamp=True)):
            buckets[int(b)].append(r)

        results = []
        for i, rs in buckets.items():
            rs = self._order_by_length(rs, mesh=self.admission_mesh,
                                       axis=self.admission_axis)
            for start in range(0, len(rs), self.batch_size):
                chunk = rs[start : start + self.batch_size]
                outs = self.engine.generate(
                    [r.prompt for r in chunk],
                    max_new=max(r.max_new for r in chunk),
                )
                for r, toks in zip(chunk, outs):
                    results.append(GenerationResult(r.request_id, toks[: r.max_new]))
        return results

    # Prefix tokens folded into the admission key after the length lane:
    # enough to group shared prefixes inside one equal-length run, few enough
    # to keep the lex compare a handful of VPU ops per phase.
    _PREFIX_LANES = 2

    @staticmethod
    def _order_by_length(rs: List[Request], mesh=None,
                         axis: str = "data") -> List[Request]:
        """Length-then-alphabetic batch ordering via the lexicographic kernel
        sort: lane 0 = prompt length, lanes 1..k = the first prompt tokens,
        payload = request index (the paper's shortlex order applied to the
        admission queue). Equal-length prompts thus admit ordered by their
        first _PREFIX_LANES tokens, so chunks group shared prefixes
        adjacently (prefix-cache locality); prompts identical through those
        tokens fall back to queue order (the index payload tie-break).

        The queue is padded to a power-of-two length so a long-running server
        compiles O(log max_queue) kernel shapes rather than one per distinct
        request count (jit caches are shape-keyed); padding sorts to the tail
        (all-sentinel lex tuples) and is sliced off.

        ``mesh``: optional — shard the sort over mesh ``axis`` through
        ``core.distributed.distributed_sort_lex`` (identical lane layout and
        order) when the queue outgrows one device."""
        n = len(rs)
        if n < 2:
            return rs
        n_pad = max(128, 1 << (n - 1).bit_length())
        maxi = np.iinfo(np.int32).max
        lanes = np.full((1 + BucketedScheduler._PREFIX_LANES, n_pad), maxi,
                        np.int32)
        lanes[0, :n] = [len(r.prompt) for r in rs]
        for k in range(BucketedScheduler._PREFIX_LANES):
            # -1 for absent positions: shorter prompts already order first on
            # the length lane, so this only pins a total order deterministically
            lanes[1 + k, :n] = [r.prompt[k] if len(r.prompt) > k else -1
                                for r in rs]
        idx = np.arange(n_pad, dtype=np.int32)
        if mesh is not None:
            from ..core.distributed import distributed_sort_lex
            _, perm = distributed_sort_lex([jnp.asarray(l) for l in lanes],
                                           mesh, axis=axis,
                                           vals=jnp.asarray(idx))
        else:
            _, perm = sort_lex([jnp.asarray(l) for l in lanes],
                               vals=jnp.asarray(idx))
        return [rs[int(j)] for j in np.asarray(perm)[:n]]

    @staticmethod
    def padding_stats(requests: List[Request], bounds: Sequence[int]):
        """Padded-token fraction under bucketing vs one global batch.

        A request longer than every bound lands in the last bucket and pads
        *nothing* (it decodes at its own length there) — ``bound - l`` would
        be negative for it and silently understate the bucketed waste, so
        the contribution is clamped at zero."""
        lens = np.array([len(r.prompt) for r in requests])
        global_waste = 1.0 - lens.sum() / (len(lens) * lens.max())
        bound_arr = np.asarray(bounds)[assign_buckets(lens, bounds, clamp=True)]
        padded = np.maximum(bound_arr - lens, 0).sum()
        bucket_waste = padded / (padded + lens.sum())
        return {"global_waste": float(global_waste), "bucketed_waste": float(bucket_waste)}
