"""Serving engine: jit'd prefill/decode, KV/SSM cache management, and the
paper's length-bucketed admission scheduler."""

from .engine import Engine, GenerationResult
from .scheduler import BucketedScheduler, Request

__all__ = ["Engine", "GenerationResult", "BucketedScheduler", "Request"]
