"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE. [arXiv:2409.12191]

Backbone only: the dynamic-resolution ViT frontend is a stub —
``input_specs()`` provides precomputed patch embeddings plus (t,h,w)
M-RoPE position ids."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    attn="gqa",
    mlp_act="silu",
    mlp_gated=True,
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # t/h/w frequency sections (sum = 64 pairs)
    input_kind="frames",           # precomputed patch embeddings
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="M-RoPE over patch embeddings; ViT frontend stubbed per assignment.",
)
