"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H d_ff=8192 vocab=32000,
ssm_state=64; Mamba2 backbone + shared attention block. [arXiv:2411.15242]

Hybrid layout: one *shared* transformer block (single param set) applied
before every 6-layer group of Mamba2 blocks. Runs the long_500k cell: the
mamba state is O(1) and only the 7 shared-block applications keep KV.
"""

from ..models.config import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,                  # shared block MLP width
    vocab_size=32_000,
    attn="gqa",                 # the shared block's attention
    mlp_act="gelu",
    mlp_gated=True,
    ssm=SSMCfg(d_state=64, expand=2, headdim=64, chunk=256, d_conv=4, n_groups=1),
    hybrid_period=6,
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="shared attn+MLP block every 6 mamba layers (7 applications).",
)
