"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552, partial RoPE. [hf:THUDM/glm-4-9b]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab_size=151_552,
    attn="gqa",
    mlp_act="silu",
    mlp_gated=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    rope_pct=0.5,               # GLM partial rotary
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="GQA kv=2 (extreme KV compression); partial RoPE 50%.",
)
