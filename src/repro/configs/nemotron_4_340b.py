"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP. [arXiv:2402.16819]

Paper technique is indirect here (no routing): length-bucketed data pipeline
and serving admission only — see DESIGN.md §6."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    attn="gqa",
    mlp_act="relu2",            # squared ReLU, ungated
    mlp_gated=False,
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optim_dtype="bfloat16",
    remat="full",               # 96 x d18432: activations dominate; full remat
    notes="GQA kv=8; squared-ReLU; 256k vocab.",
)
