"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

Paper-technique hook: sort-based MoE token dispatch (models/moe.py)."""

from ..models.config import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    attn="gqa",
    mlp_act="silu",
    mlp_gated=True,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512, impl="sort"),
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optim_dtype="float32",
    remat="dots",
    notes="32e top-8; every layer MoE; GQA kv=8.",
)
