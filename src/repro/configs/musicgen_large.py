"""musicgen-large [audio] — 48L d_model=2048 32H d_ff=8192 vocab=2048,
decoder-only over EnCodec tokens. [arXiv:2306.05284]

Backbone only: the EnCodec frontend is a stub — ``input_specs()`` provides
precomputed frame embeddings; the head predicts one codebook (vocab 2048)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,              # full MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    attn="gqa",
    mlp_act="gelu",
    mlp_gated=False,
    rope_kind="none",           # musicgen uses learned sinusoidal; stubbed as none
    norm_kind="layernorm",
    input_kind="frames",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="decoder over EnCodec frames; frontend stubbed per assignment.",
)
