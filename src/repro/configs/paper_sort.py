"""The paper's own workload as a config: dataset scales, bucket policy and
algorithm selection for the bucketed parallel sort.

The paper's two datasets are matched by word count (190 KB / 1.38 MB of
cleaned Shakespeare); `algorithm` picks the in-bucket comparator network
('oets' = paper-faithful parallel bubble sort) and `merge` the device-level
exchange strategy of the distributed sort.
"""

import dataclasses

__all__ = ["SortConfig", "DS1", "DS2", "CONFIG"]


@dataclasses.dataclass(frozen=True)
class SortConfig:
    name: str
    n_words: int              # corpus size (paper: ~30k / ~230k words)
    max_word_len: int = 15
    algorithm: str = "oets"   # oets (paper) | bitonic (beyond-paper) | xla
    merge: str = "bitonic"    # device-level merge: resort | bitonic | take
    devices: int = 8          # distributed-sort width for the example
    seed: int = 0


DS1 = SortConfig(name="ds1-190KB", n_words=30_000)
DS2 = SortConfig(name="ds2-1.38MB", n_words=230_000)

# default experiment config (the paper's headline comparison runs both)
CONFIG = DS1
