"""mamba2-370m [ssm] — 48L d_model=1024 attention-free, ssm_state=128,
vocab=50280. SSD (state-space duality). [arXiv:2405.21060]

Runs the long_500k cell: decode state is O(1) in context length."""

from ..models.config import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,                # d_inner / headdim (derived; unused by attn)
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,                    # no MLP: the mamba mixer is the whole block
    vocab_size=50_280,
    attn=None,
    ssm=SSMCfg(d_state=128, expand=2, headdim=64, chunk=256, d_conv=4, n_groups=1),
    rope_kind="none",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="pure SSM; decode cache = conv window + (H,P,N) state per layer.",
)
