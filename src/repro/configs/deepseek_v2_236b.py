"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6. [arXiv:2405.04434]

Paper-technique hook: sort-based MoE dispatch with expert parallelism over
the `model` mesh axis (160 experts / 16-way EP = 10 per chip)."""

from ..models.config import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,            # MLA: per-head kv materialized from the latent
    head_dim=128,
    d_ff=1536,                 # routed expert width
    vocab_size=102_400,
    attn="mla",
    mla=MLACfg(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    mlp_act="silu",
    mlp_gated=True,
    moe=MoECfg(
        n_experts=160, top_k=6, d_expert=1536,
        n_shared=2, d_shared=1536,
        first_dense=1, dense_d_ff=12_288,
        impl="sort",
    ),
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optim_dtype="bfloat16",    # 236B params: bf16 moments to fit the pod
    remat="dots",
    notes="MLA compressed KV cache (kv_lora+qk_rope per token); layer 0 dense.",
)
