"""Architecture registry: ``--arch <id>`` resolves here.

Each module holds one assigned architecture with its exact published
dimensions; ``get_config(id)`` accepts the dashed public ids. ``SHAPES``
defines the per-arch input-shape cells (train / prefill / decode / long),
and ``cells_for(cfg)`` applies the long_500k sub-quadratic eligibility rule
(see DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig, smoke_variant

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "SHAPES", "cells_for", "ShapeCell"]

ARCH_IDS = [
    "granite-moe-1b-a400m",
    "deepseek-v2-236b",
    "nemotron-4-340b",
    "minicpm3-4b",
    "glm4-9b",
    "llama3-405b",
    "mamba2-370m",
    "qwen2-vl-2b",
    "musicgen-large",
    "zamba2-1.2b",
]

_MODULES = {i: i.replace("-", "_").replace(".", "_") for i in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return smoke_variant(get_config(arch_id))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cells_for(cfg: ModelConfig):
    """Shape cells applicable to an arch: long_500k only for sub-quadratic
    (SSM/hybrid) families — full-attention archs skip it (DESIGN.md §8)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return [SHAPES[c] for c in cells]
