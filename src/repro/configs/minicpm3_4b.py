"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B]"""

from ..models.config import MLACfg, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73_448,
    attn="mla",
    mla=MLACfg(q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64),
    mlp_act="silu",
    mlp_gated=True,
    rope_kind="rope",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    notes="MLA with q_lora=768/kv_lora=256.",
)
