"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    attn="gqa",
    mlp_act="silu",
    mlp_gated=True,
    rope_kind="rope",
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    optim_dtype="bfloat16",     # 405B: bf16 moments to fit 512 x 16GB
    remat="full",
    notes="GQA kv=8; 128k vocab; rope theta 500k.",
)
