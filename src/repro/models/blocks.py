"""Block-level composition: transformer blocks (attn + MLP/MoE), Mamba2
blocks, and the Zamba2-style shared-attention hybrid group."""

from __future__ import annotations

import jax.numpy as jnp

from ..parallel.sharding import Rules, constrain
from .attention import attention, init_attention
from .config import ModelConfig
from .layers import init_mlp, init_norm, mlp, norm
from .moe import init_moe, moe
from .param import Builder
from .ssm import init_mamba, mamba_decode, mamba_train

__all__ = [
    "init_transformer_block", "transformer_block",
    "init_mamba_block", "mamba_block",
]


def init_transformer_block(b: Builder, cfg: ModelConfig, ffn: str, d_ff: int | None = None):
    """ffn: 'dense' or 'moe'."""
    p = {
        "ln1": init_norm(b, cfg.d_model, cfg.norm_kind),
        "attn": init_attention(b, cfg),
        "ln2": init_norm(b, cfg.d_model, cfg.norm_kind),
    }
    if ffn == "moe":
        p["moe"] = init_moe(b, cfg)
    else:
        p["mlp"] = init_mlp(b, cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_gated)
    return p


def transformer_block(cfg: ModelConfig, p, x, cos, sin, rules: Rules,
                      cache=None, cur_index=None, return_cache=False,
                      sort_impl: str = "xla"):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    h, new_cache = attention(
        cfg, p["attn"], norm(p["ln1"], x, cfg.norm_eps, cfg.norm_kind),
        cos, sin, rules, cache, cur_index, return_cache,
    )
    x = x + h
    # residual-region constraint: seq-sharded under sequence parallelism
    x = constrain(x, rules, "batch", "res_seq", "act_embed")
    aux = jnp.zeros((), jnp.float32)
    h2 = norm(p["ln2"], x, cfg.norm_eps, cfg.norm_kind)
    if "moe" in p:
        h2, aux = moe(cfg, p["moe"], h2, rules, sort_impl)
    else:
        h2 = mlp(p["mlp"], h2, cfg.mlp_act, cfg.mlp_gated, rules)
    return x + h2, new_cache, aux


def init_mamba_block(b: Builder, cfg: ModelConfig):
    return {
        "ln": init_norm(b, cfg.d_model, cfg.norm_kind),
        "mixer": init_mamba(b, cfg),
    }


def mamba_block(cfg: ModelConfig, p, x, rules: Rules,
                cache=None, return_cache=False, seq_mask=None):
    """Returns (x, new_cache)."""
    h = norm(p["ln"], x, cfg.norm_eps, cfg.norm_kind)
    if cache is not None:
        h, new_cache = mamba_decode(cfg, p["mixer"], h, cache, rules)
    else:
        h, new_cache = mamba_train(cfg, p["mixer"], h, rules, return_cache, seq_mask)
    return x + h, new_cache
