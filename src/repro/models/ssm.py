"""Mamba2 (state-space duality) block: chunked training path + O(1)-state
recurrent decode path.

Training uses the SSD chunked algorithm (arXiv:2405.21060): the sequence is
split into chunks; within a chunk the output is a masked quadratic form
(attention-like, MXU-friendly); across chunks a small recurrence over chunk
states carries the SSM state. The chunked path is equivalence-tested against
the naive O(T) recurrence in tests/test_models.py.

Decode keeps a constant-size cache per layer: the depthwise-conv window and
the (H, P, N) SSM state — this is why the long_500k cell runs for SSM/hybrid
archs only: the "KV cache" does not grow with context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import Rules, constrain
from .config import ModelConfig
from .param import Builder

__all__ = ["init_mamba", "mamba_train", "mamba_decode", "init_ssm_cache", "ssd_reference"]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.headdim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nheads, conv_dim


def init_mamba(b: Builder, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    dm = cfg.d_model
    return {
        # order: [z (gate), xBC (conv'd), dt]
        "w_in": b.param((dm, 2 * d_inner + 2 * s.n_groups * s.d_state + nheads), ("embed", "mlp")),
        "conv_w": b.param((s.d_conv, conv_dim), ("conv", "mlp"), scale=s.d_conv ** -0.5),
        "conv_b": b.param((conv_dim,), ("mlp",), init="zeros"),
        "A_log": b.param((nheads,), ("state",), init="ssm_a"),
        "D": b.param((nheads,), ("state",), init="ones"),
        "dt_bias": b.param((nheads,), ("state",), init="zeros"),
        "norm_w": b.param((d_inner,), ("mlp",), init="ones"),
        "w_out": b.param((d_inner, dm), ("mlp", "embed")),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * s.n_groups * s.d_state], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg, xbc):
    s = cfg.ssm
    d_inner, _, _ = _dims(cfg)
    x, bb, cc = jnp.split(xbc, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)
    return x, bb, cc


def _gated_norm(p, y, z, eps):
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    dt = y.dtype
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, axis=-1, keepdims=True) + eps)
    return (yf * p["norm_w"].astype(jnp.float32)).astype(dt)


def _causal_conv_train(p, xbc):
    """Depthwise causal conv over time. xbc (B,T,C); conv_w (K,C)."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):  # K is 4: unrolled shift-multiply beats conv_general here
        out = out + pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i]
    return jax.nn.silu(out + p["conv_b"])


# ---------------- chunked SSD (training / prefill) ----------------

def _ssd_chunked(x, dt, A, B_, C_, chunk):
    """x (B,T,H,P); dt (B,T,H) post-softplus; A (H,) negative;
    B_/C_ (B,T,G,N). Returns y (B,T,H,P) and final state (B,H,P,N)."""
    b, t, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    assert t % chunk == 0, "sequence must be chunk-padded"
    nc, q = t // chunk, chunk
    hpg = h // g  # heads per group

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = B_.reshape(b, nc, q, g, n)
    cc = C_.reshape(b, nc, q, g, n)

    da = dtc * A  # (b,nc,q,h)
    cs = jnp.cumsum(da, axis=2)
    xdt = xc * dtc[..., None]

    b_heads = jnp.repeat(bc, hpg, axis=3)                             # (b,nc,q,h,n)
    c_heads = jnp.repeat(cc, hpg, axis=3)

    # intra-chunk: masked attention-like quadratic form (MXU-friendly)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", c_heads, b_heads)
    # decay[q,k] = exp(cs[q] - cs[k]) for q >= k. Mask BEFORE the exp: the
    # upper triangle has cs[q] - cs[k] > 0 which can overflow exp in fp32,
    # and `where(mask, exp(diff), 0)` then back-propagates inf*0 = NaN.
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]                # (b,nc,q,k,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", cb * decay, xdt)

    # chunk states: S_c = sum_k exp(cs[-1]-cs[k]) * B_k (x dt)_k
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                     # (b,nc,q,h)
    s_c = jnp.einsum("bcqhn,bcqhp->bchpn", b_heads * decay_to_end[..., None], xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cs[:, :, -1, :])                            # (b,nc,h)

    def scan_fn(state, inp):
        s_chunk, dec = inp  # (b,h,p,n), (b,h)
        new = state * dec[:, :, None, None] + s_chunk
        return new, state  # emit the state *entering* this chunk

    init = jnp.zeros((b, h, p, n), x.dtype)
    final_state, s_prev = jax.lax.scan(
        scan_fn, init, (s_c.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    s_prev = s_prev.swapaxes(0, 1)                                    # (b,nc,h,p,n)

    c_heads = jnp.repeat(cc, hpg, axis=3)                             # (b,nc,q,h,n)
    decay_from_start = jnp.exp(cs)                                    # (b,nc,q,h)
    y_inter = jnp.einsum(
        "bcqhn,bchpn->bcqhp", c_heads * decay_from_start[..., None], s_prev
    )

    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y, final_state


def ssd_reference(x, dt, A, B_, C_):
    """Naive O(T) recurrence oracle (tests only)."""
    b, t, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    hpg = h // g
    b_heads = jnp.repeat(B_, hpg, axis=2)
    c_heads = jnp.repeat(C_, hpg, axis=2)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        dec = jnp.exp(dtt * A)  # (b,h)
        state = state * dec[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", bt, xt * dtt[..., None]
        )
        y = jnp.einsum("bhn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, ys = jax.lax.scan(
        step,
        init,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), b_heads.swapaxes(0, 1), c_heads.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), final


# ---------------- public paths ----------------

def mamba_train(cfg: ModelConfig, p, x, rules: Rules, return_cache: bool = False,
                seq_mask=None):
    """Full-sequence path. x (B,T,d_model) -> (y, cache|None).

    ``seq_mask`` (B,T) marks valid positions for right-padded variable-length
    prefill: dt at padded positions is forced to ~0, so the SSM state neither
    decays nor absorbs input there — the final state equals the state at each
    request's true length."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    dt_x = x.dtype
    bsz, t, _ = x.shape

    proj = jnp.einsum("btd,dk->btk", x, p["w_in"].astype(dt_x))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    if seq_mask is not None:
        dt_raw = jnp.where(seq_mask[:, :, None] > 0, dt_raw, -30.0)
    xbc = _causal_conv_train(p, xbc).astype(dt_x)
    xs, bb, cc = _split_xbc(cfg, xbc)

    xh = xs.reshape(bsz, t, nheads, s.headdim)
    xh = constrain(xh, rules, "batch", "seq", "act_heads", None)
    bg = bb.reshape(bsz, t, s.n_groups, s.d_state)
    cg = cc.reshape(bsz, t, s.n_groups, s.d_state)
    dt_pos = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    pad = (-t) % s.chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_pos = jnp.pad(dt_pos, ((0, 0), (0, pad), (0, 0)))

    y, final_state = _ssd_chunked(
        xh.astype(jnp.float32), dt_pos, a, bg.astype(jnp.float32),
        cg.astype(jnp.float32), s.chunk,
    )
    y = y[:, :t].astype(dt_x) + xh[:, :t].astype(dt_x) * p["D"].astype(dt_x)[None, None, :, None]
    y = y.reshape(bsz, t, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, p["w_out"].astype(dt_x))

    cache = None
    if return_cache:
        k = p["conv_w"].shape[0]
        _, xbc_raw, _ = _split_proj(cfg, proj)  # pre-conv xBC rows
        if seq_mask is not None:
            # conv window must end at each request's true length
            lens = jnp.sum(seq_mask.astype(jnp.int32), axis=1)
            tail = jax.vmap(
                lambda rows, l: jax.lax.dynamic_slice_in_dim(rows, l - (k - 1), k - 1, axis=0)
            )(xbc_raw, lens)
        elif t >= k - 1:
            tail = xbc_raw[:, -(k - 1):, :]
        else:
            tail = jnp.pad(xbc_raw, ((0, 0), (k - 1 - t, 0), (0, 0)))
        cache = {"conv": tail.astype(dt_x), "ssm": final_state.astype(jnp.float32)}
    return out, cache


def mamba_decode(cfg: ModelConfig, p, x, cache, rules: Rules):
    """Single-token recurrent path. x (B,1,d_model), cache {conv, ssm}."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    dt_x = x.dtype
    bsz = x.shape[0]

    proj = jnp.einsum("btd,dk->btk", x, p["w_in"].astype(dt_x))
    z, xbc_new, dt_raw = _split_proj(cfg, proj)

    # conv window update: cache['conv'] holds the last (K-1) pre-activation
    # xBC rows; convolve the refreshed window.
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(dt_x)
    new_conv = window[:, 1:, :]

    xs, bb, cc = _split_xbc(cfg, xbc)
    xh = xs.reshape(bsz, nheads, s.headdim)
    bg = bb.reshape(bsz, s.n_groups, s.d_state)
    cg = cc.reshape(bsz, s.n_groups, s.d_state)
    hpg = nheads // s.n_groups
    b_heads = jnp.repeat(bg, hpg, axis=1).astype(jnp.float32)
    c_heads = jnp.repeat(cg, hpg, axis=1).astype(jnp.float32)

    dt_pos = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt_pos * a)  # (B,H)

    state = cache["ssm"] * dec[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhpn", b_heads, xh.astype(jnp.float32) * dt_pos[..., None]
    )
    y = jnp.einsum("bhn,bhpn->bhp", c_heads, state).astype(dt_x)
    y = y + xh * p["D"].astype(dt_x)[None, :, None]
    y = y.reshape(bsz, 1, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, p["w_out"].astype(dt_x))
    return out, {"conv": new_conv, "ssm": state}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    """Per-layer decode cache shapes (constant in context length)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "conv": ((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": ((batch, nheads, s.headdim, s.d_state), jnp.float32),
    }
