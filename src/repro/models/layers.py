"""Shared primitive layers: norms, rotary embeddings (RoPE / M-RoPE /
partial), dense MLPs. Pure functions over explicit parameter pytrees."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import Rules, constrain
from .param import Builder

__all__ = [
    "rmsnorm", "layernorm", "norm", "init_norm",
    "rope_angles", "apply_rope", "mrope_angles",
    "init_mlp", "mlp",
]


# ---------------- norms ----------------

def init_norm(b: Builder, d: int, kind: str = "rmsnorm"):
    p = {"w": b.param((d,), ("act_embed",), init="ones")}
    if kind == "layernorm":
        p["b"] = b.param((d,), ("act_embed",), init="zeros")
    return p


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["w"].astype(jnp.float32)).astype(dt)


def layernorm(p, x, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["w"].astype(jnp.float32) + p.get("b", 0.0)).astype(dt)


def norm(p, x, eps: float, kind: str):
    return rmsnorm(p, x, eps) if kind == "rmsnorm" else layernorm(p, x, eps)


# ---------------- rotary embeddings ----------------

def rope_angles(positions, rot_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., rot_dim//2), fp32."""
    half = rot_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions, rot_dim: int, theta: float, sections):
    """Multimodal RoPE (qwen2-vl): positions (B, S, 3) = (t, h, w) ids.

    The rot_dim//2 frequency slots are partitioned into ``sections``
    (t/h/w); each section takes its angle from the matching position channel.
    """
    half = rot_dim // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to {half}")
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_all = positions[..., None].astype(jnp.float32) * freq  # (B,S,3,half)
    parts = []
    start = 0
    for ch, width in enumerate(sections):
        parts.append(ang_all[..., ch, start : start + width])
        start += width
    ang = jnp.concatenate(parts, axis=-1)  # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rope_pct: float = 1.0):
    """x (..., S, H, D); cos/sin (..., S, half). Half-split (NeoX) convention.
    ``rope_pct < 1`` rotates only the leading fraction of D (glm4)."""
    d = x.shape[-1]
    rot = int(d * rope_pct)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    x1, x2 = xr[..., :half], xr[..., half:]
    c = cos[..., None, :half].astype(jnp.float32)
    s = sin[..., None, :half].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------- dense MLP ----------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron-4 squared ReLU
}


def init_mlp(b: Builder, d_model: int, d_ff: int, gated: bool):
    w_in_cols = 2 * d_ff if gated else d_ff
    return {
        "w_in": b.param((d_model, w_in_cols), ("embed", "mlp")),
        "w_out": b.param((d_ff, d_model), ("mlp", "embed")),
    }


def mlp(p, x, act: str, gated: bool, rules: Rules):
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(x.dtype))
    if gated:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * _ACTS[act](g)
    else:
        h = _ACTS[act](h)
    h = constrain(h, rules, "batch", "seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(x.dtype))
