"""Mixture-of-Experts layer with *sort-based* token dispatch — the paper's
bucketing technique in the forward pass.

Routing is exactly the paper's problem: distribute elements (tokens) into
sub-arrays (experts) and process every sub-array in parallel. The ``sort``
implementation buckets by sorting the flat (token, expert) assignment list by
expert id — the same bucket-then-parallel-process structure as the paper's
phase 2+3 — then computes all experts batched. The ``einsum`` implementation
is the GSPMD one-hot dispatch baseline the sort variant is benchmarked
against (benchmarks/bench_moe_dispatch.py).

``sort_impl`` selects the sorting engine: 'xla' (production, O(n log n)),
'oets' (paper-faithful comparator network; used at test scale), 'bitonic',
or 'pallas' — the unified kernel front-end (``repro.kernels.ops.sort_kv``)
whose cost model auto-picks OETS / bitonic / tiled blocksort from the
assignment-list length, so dispatch scales past one VMEM block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.bitonic import bitonic_sort_kv
from ..core.oets import oets_sort_kv
from ..kernels.ops import sort_kv as kernel_sort_kv
from ..parallel.sharding import Rules, constrain
from .config import ModelConfig
from .layers import _ACTS, init_mlp, mlp
from .param import Builder

__all__ = ["init_moe", "moe", "capacity"]


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # sublane-aligned


def init_moe(b: Builder, cfg: ModelConfig):
    m = cfg.moe
    dm = cfg.d_model
    w_in_cols = 2 * m.d_expert if cfg.mlp_gated else m.d_expert
    p = {
        "router": b.param((dm, m.n_experts), ("embed", "expert"), scale=dm ** -0.5),
        "w_in": b.param((m.n_experts, dm, w_in_cols), ("expert", "embed", "expert_mlp")),
        "w_out": b.param((m.n_experts, m.d_expert, dm), ("expert", "expert_mlp", "embed")),
    }
    if m.n_shared:
        p["shared"] = init_mlp(b, dm, m.n_shared * m.d_shared, cfg.mlp_gated)
    return p


def _route(cfg, p, xf):
    """Router logits -> (top-k probs, top-k expert ids, aux load-balance loss)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    if m.router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch-style load-balance aux: E * sum_e (token_frac_e * prob_mass_e)
    t = xf.shape[0]
    token_frac = jnp.zeros((m.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * m.top_k)
    prob_mass = jnp.mean(probs, axis=0)
    aux = m.aux_alpha * m.n_experts * jnp.sum(token_frac * prob_mass)
    return top_p, top_e, aux


def _expert_ffn(cfg, p, buf):
    """buf (E, C, d) -> (E, C, d), batched over experts."""
    dt = buf.dtype
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(dt))
    if cfg.mlp_gated:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * _ACTS[cfg.mlp_act](g)
    else:
        h = _ACTS[cfg.mlp_act](h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(dt))


def _sort_assignments(flat_e, flat_payload, impl: str):
    if impl == "xla":
        order = jnp.argsort(flat_e, stable=True)
        return flat_e[order], flat_payload[order]
    if impl == "oets":
        return oets_sort_kv(flat_e, flat_payload)
    if impl == "bitonic":
        return bitonic_sort_kv(flat_e, flat_payload)
    if impl == "pallas":
        return kernel_sort_kv(flat_e, flat_payload)
    raise ValueError(f"unknown sort impl {impl!r}")


def _dispatch_sort(cfg, p, xf, rules, sort_impl):
    """Paper-technique dispatch: bucket tokens by expert via a key-value sort."""
    m = cfg.moe
    t, dm = xf.shape
    cap = capacity(cfg, t)
    top_p, top_e, aux = _route(cfg, p, xf)

    n = t * m.top_k
    flat_e = top_e.reshape(n).astype(jnp.int32)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), m.top_k)
    flat_p = top_p.reshape(n)

    # bucket boundary bookkeeping (the paper's "sizes decided by the
    # histogram"): sort assignments by expert, rank within bucket, drop
    # overflow beyond capacity. One kv-sort of (expert_id -> assignment idx)
    # yields the full bucketing permutation.
    sorted_e, perm = _sort_assignments(flat_e, jnp.arange(n, dtype=jnp.int32), sort_impl)
    sorted_t = flat_t[perm]
    counts = jnp.bincount(flat_e, length=m.n_experts)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(n, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, m.n_experts * cap)

    buf = jnp.zeros((m.n_experts * cap + 1, dm), xf.dtype).at[slot].set(xf[sorted_t])
    buf = buf[: m.n_experts * cap].reshape(m.n_experts, cap, dm)
    buf = constrain(buf, rules, "act_expert", None, "act_embed")

    out = _expert_ffn(cfg, p, buf)
    out_flat = jnp.concatenate(
        [out.reshape(m.n_experts * cap, dm), jnp.zeros((1, dm), out.dtype)], axis=0
    )
    contrib = out_flat[slot]  # (n, d); overflow slots read zeros

    # gate weights follow the same bucketing permutation as the assignments
    gates = flat_p[perm]
    y = jnp.zeros((t, dm), xf.dtype).at[sorted_t].add(contrib * gates[:, None].astype(xf.dtype))
    return y, aux


def _dispatch_einsum(cfg, p, xf, rules):
    """GSPMD-style one-hot dispatch baseline (no sort)."""
    m = cfg.moe
    t, dm = xf.shape
    cap = capacity(cfg, t)
    top_p, top_e, aux = _route(cfg, p, xf)

    # position of each assignment within its expert bucket
    onehot = jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.int32)  # (t,k,E)
    pos = jnp.cumsum(onehot.reshape(t * m.top_k, m.n_experts), axis=0).reshape(
        t, m.top_k, m.n_experts
    ) * onehot - 1
    within_cap = (pos >= 0) & (pos < cap)
    combine = (top_p[..., None] * within_cap).astype(jnp.float32)        # (t,k,E)
    disp = jax.nn.one_hot(jnp.where(within_cap, pos, cap), cap + 1, dtype=xf.dtype)[
        ..., :cap
    ] * within_cap[..., None].astype(xf.dtype)                           # (t,k,E,C)

    buf = jnp.einsum("td,tkec->ecd", xf, disp)
    buf = constrain(buf, rules, "act_expert", None, "act_embed")
    out = _expert_ffn(cfg, p, buf)
    y = jnp.einsum("tkec,ecd->td", (combine[..., None] * disp).astype(xf.dtype), out)
    return y, aux


def moe(cfg: ModelConfig, p, x, rules: Rules, sort_impl: str = "xla"):
    """x (B, T, d) -> (y (B, T, d), aux_loss scalar)."""
    m = cfg.moe
    b, t, dm = x.shape
    xf = x.reshape(b * t, dm)
    if m.impl == "sort":
        y, aux = _dispatch_sort(cfg, p, xf, rules, sort_impl)
    elif m.impl == "einsum":
        y, aux = _dispatch_einsum(cfg, p, xf, rules)
    else:
        raise ValueError(f"unknown moe impl {m.impl!r}")
    if m.n_shared:
        y = y + mlp(p["shared"], xf, cfg.mlp_act, cfg.mlp_gated, rules)
    return y.reshape(b, t, dm), aux
