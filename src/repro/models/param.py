"""Parameter construction with paired sharding metadata.

Each leaf is created once with both its initializer *and* its logical axes,
so the parameter pytree and the PartitionSpec pytree can never drift apart.
``abstract=True`` builds ShapeDtypeStruct leaves — that is how the dry-run
lowers a 405B-parameter train step without allocating a single byte.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["PLeaf", "Builder", "finalize", "tree_specs"]


@dataclasses.dataclass
class PLeaf:
    value: Any          # jax.Array (concrete) or ShapeDtypeStruct (abstract)
    axes: Tuple         # logical axis names, len == ndim


def _is_pleaf(x):
    return isinstance(x, PLeaf)


class Builder:
    """Creates PLeaf parameters with deterministic per-leaf RNG."""

    def __init__(self, key, abstract: bool = False, dtype=jnp.float32):
        self._key = key
        self.abstract = abstract
        self.dtype = dtype
        self._count = 0

    def _next_key(self):
        self._count += 1
        return jax.random.fold_in(self._key, self._count)

    def param(self, shape, axes, init: str = "normal", scale: float | None = None,
              dtype=None) -> PLeaf:
        if len(axes) != len(shape):
            raise ValueError(f"axes {axes} do not match shape {shape}")
        dtype = dtype or self.dtype
        if self.abstract:
            self._count += 1  # keep RNG stream aligned with concrete builds
            return PLeaf(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))
        k = self._next_key()
        if init == "normal":
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = fan_in ** -0.5
            v = (scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
        elif init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "ssm_a":  # mamba A_log: log of Uniform[1, 16]
            v = jnp.log(
                jax.random.uniform(k, shape, jnp.float32, minval=1.0, maxval=16.0)
            ).astype(dtype)
        else:
            raise ValueError(f"unknown init {init!r}")
        return PLeaf(v, tuple(axes))


def finalize(tree):
    """Split a PLeaf tree into (params, specs-as-logical-axes) trees."""
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=_is_pleaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=_is_pleaf)
    return params, axes


def tree_specs(axes_tree, rules, mesh, value_tree=None):
    """Logical-axes tree -> PartitionSpec tree for a concrete mesh.

    With ``value_tree`` (arrays or ShapeDtypeStructs of matching structure)
    the specs are divisibility-aware per leaf shape (required for jit
    argument shardings)."""
    is_axes = lambda x: isinstance(x, tuple)
    if value_tree is None:
        names = mesh.axis_names
        return jax.tree.map(lambda a: rules.mesh_spec(a, names), axes_tree, is_leaf=is_axes)
    sizes = dict(mesh.shape)
    return jax.tree.map(
        lambda a, v: rules.shape_spec(a, v.shape, sizes),
        axes_tree, value_tree, is_leaf=is_axes,
    )
