"""Composable model zoo: every assigned architecture is assembled from the
same primitive set (attention variants, MoE with sort-based dispatch, Mamba2
SSD, hybrid groups) driven purely by ModelConfig."""

from .config import MLACfg, ModelConfig, MoECfg, SSMCfg, smoke_variant
from .model import decode_step, forward, init_cache, init_lm, lm_loss

__all__ = [
    "ModelConfig", "MoECfg", "MLACfg", "SSMCfg", "smoke_variant",
    "init_lm", "forward", "lm_loss", "decode_step", "init_cache",
]
