"""LM assembly: parameter init (concrete or abstract), train/prefill forward,
and single-token decode — for every family in the architecture pool.

Structural choices that matter at scale:
  * scan-over-layers with stacked params keeps HLO size O(1) in depth
    (a 126-layer llama3-405b train step lowers as a single scanned block);
  * hybrid (zamba2) runs a static python loop over shared-attention groups,
    each group = shared transformer block + a scanned slice of Mamba2 layers
    — no lax.cond in the hot path and the shared KV cache stays compact
    (n_apps entries, not n_layers);
  * every parameter/cache leaf carries logical sharding axes (param.py), so
    dry-run in_shardings are derived, never hand-written.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.sharding import Rules, constrain
from .blocks import (
    init_mamba_block,
    init_transformer_block,
    mamba_block,
    transformer_block,
)
from .config import ModelConfig
from .layers import init_norm, mrope_angles, norm, rope_angles
from .param import Builder, finalize
from .ssm import init_ssm_cache
from .attention import init_attn_cache

__all__ = [
    "init_lm", "forward", "lm_loss", "decode_step", "init_cache",
    "default_positions", "hybrid_groups",
]


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


class _StackedBuilder:
    """Prepends a layer axis to every parameter (for lax.scan stacking)."""

    def __init__(self, inner: Builder, n: int):
        self._inner = inner
        self._n = n

    def param(self, shape, axes, **kw):
        return self._inner.param((self._n,) + tuple(shape), ("layers",) + tuple(axes), **kw)


def hybrid_groups(cfg: ModelConfig):
    """[(start, end)] mamba-layer slices; a shared attn block precedes each."""
    period = cfg.hybrid_period
    return [(s, min(s + period, cfg.n_layers)) for s in range(0, cfg.n_layers, period)]


def _plan(cfg: ModelConfig):
    """[(stack_name, n_layers, kind)] where kind in dense|moe|mamba."""
    if cfg.family in ("dense", "vlm", "audio"):
        return [("blocks", cfg.n_layers, "dense")]
    if cfg.family == "moe":
        fd = cfg.moe.first_dense
        plan = []
        if fd:
            plan.append(("first", fd, "dense"))
        plan.append(("blocks", cfg.n_layers - fd, "moe"))
        return plan
    if cfg.family in ("ssm", "hybrid"):
        return [("blocks", cfg.n_layers, "mamba")]
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------- init ----------------

def init_lm(cfg: ModelConfig, key=None, abstract: bool = False):
    """Returns (params, logical_axes) pytrees. ``abstract=True`` builds
    ShapeDtypeStruct leaves — zero allocation (dry-run path)."""
    b = Builder(key if key is not None else jax.random.PRNGKey(0),
                abstract=abstract, dtype=_dtype(cfg.param_dtype))
    tree: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        tree["embed"] = b.param((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                                scale=cfg.d_model ** -0.5)
    for name, n, kind in _plan(cfg):
        sb = _StackedBuilder(b, n)
        if kind == "mamba":
            tree[name] = init_mamba_block(sb, cfg)
        elif kind == "moe":
            tree[name] = init_transformer_block(sb, cfg, ffn="moe")
        else:
            d_ff = cfg.moe.dense_d_ff if (cfg.family == "moe" and cfg.moe.dense_d_ff) else cfg.d_ff
            tree[name] = init_transformer_block(sb, cfg, ffn="dense", d_ff=d_ff)
    if cfg.family == "hybrid":
        tree["shared"] = init_transformer_block(b, cfg, ffn="dense")
    tree["final_norm"] = init_norm(b, cfg.d_model, cfg.norm_kind)
    if not cfg.tie_embeddings:
        tree["head"] = b.param((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return finalize(tree)


# ---------------- shared helpers ----------------

def default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0):
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim == 1:
        off = off[:, None]  # per-request offsets (continuous batching)
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + off
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos[:, :, None], (batch, seq, 3))  # text: t=h=w
    return pos


def _rope(cfg: ModelConfig, positions):
    if cfg.attn is None and cfg.family != "hybrid":
        return None, None
    if cfg.attn == "mla":
        rot = cfg.mla.qk_rope
    else:
        rot = int(cfg.head_dim * cfg.rope_pct)
        rot -= rot % 2
    if cfg.rope_kind == "none":
        # degenerate angles = identity rotation
        z = jnp.zeros(positions.shape[:2] + (rot // 2,), jnp.float32)
        return jnp.cos(z), jnp.sin(z)
    if cfg.rope_kind == "mrope":
        return mrope_angles(positions, rot, cfg.rope_theta, cfg.mrope_sections)
    return rope_angles(positions, rot, cfg.rope_theta)


def _embed(cfg, params, batch, rules: Rules):
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["frames"]
    x = x.astype(_dtype(cfg.compute_dtype))
    # res_seq is None by default; set to "model" in the rules for
    # Megatron-style sequence parallelism of the residual stream.
    return constrain(x, rules, "batch", "res_seq", "act_embed")


def _head(cfg, params, x, rules: Rules):
    x = norm(params["final_norm"], x, cfg.norm_eps, cfg.norm_kind)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype))
    return constrain(logits, rules, "batch", "seq", "act_vocab")


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat {remat!r}")


# ---------------- forward (train / prefill) ----------------

def forward(cfg: ModelConfig, params, batch, rules: Rules,
            sort_impl: str = "xla", return_cache: bool = False,
            remat: Optional[str] = None):
    """Full-sequence forward. Returns (logits, aux_loss, cache|None)."""
    remat = cfg.remat if remat is None else remat
    x = _embed(cfg, params, batch, rules)
    bsz, seq = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, bsz, seq)
    cos, sin = _rope(cfg, positions)

    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}
    seq_mask = batch.get("seq_mask")

    if cfg.family == "hybrid":
        x, caches, aux_total = _hybrid_forward(
            cfg, params, x, cos, sin, rules, return_cache, remat, seq_mask)
    else:
        for name, n, kind in _plan(cfg):
            stack = params[name]
            if kind == "mamba":
                def body(h, lp):
                    h, c = mamba_block(cfg, lp, h, rules,
                                       return_cache=return_cache, seq_mask=seq_mask)
                    return h, (c, jnp.zeros((), jnp.float32))
            else:
                def body(h, lp):
                    h, c, aux = transformer_block(
                        cfg, lp, h, cos, sin, rules,
                        return_cache=return_cache, sort_impl=sort_impl)
                    return h, (c, aux)
            x, (stack_cache, auxs) = lax.scan(_maybe_remat(body, remat), x, stack)
            aux_total = aux_total + jnp.sum(auxs)
            if return_cache:
                caches[name] = stack_cache

    logits = _head(cfg, params, x, rules)
    return logits, aux_total, (caches if return_cache else None)


def _hybrid_forward(cfg, params, x, cos, sin, rules, return_cache, remat,
                    seq_mask=None):
    """Zamba2: [shared attn block; period x mamba] groups, shared params."""
    aux_total = jnp.zeros((), jnp.float32)
    shared_caches = []
    mamba_caches = []

    def body(h, lp):
        h, c = mamba_block(cfg, lp, h, rules,
                           return_cache=return_cache, seq_mask=seq_mask)
        return h, c

    body = _maybe_remat(body, remat)
    for start, end in hybrid_groups(cfg):
        x, sc, _ = transformer_block(
            cfg, params["shared"], x, cos, sin, rules, return_cache=return_cache)
        grp = jax.tree.map(lambda a: a[start:end], params["blocks"])
        x, gc = lax.scan(body, x, grp)
        if return_cache:
            shared_caches.append(sc)
            mamba_caches.append(gc)

    caches = {}
    if return_cache:
        caches["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *shared_caches)
        caches["blocks"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *mamba_caches)
    return x, caches, aux_total


# ---------------- loss ----------------

def lm_loss(cfg: ModelConfig, params, batch, rules: Rules, sort_impl: str = "xla"):
    """Mean next-token CE (labels < 0 masked) + MoE aux. Returns (loss, metrics)."""
    logits, aux, _ = forward(cfg, params, batch, rules, sort_impl=sort_impl)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((lse - ll) * mask) / denom
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------- decode ----------------

def decode_step(cfg: ModelConfig, params, cache, tokens_or_frames, cur_index,
                rules: Rules, sort_impl: str = "xla"):
    """One-token decode against a cache. Returns (logits (B,1,V), new_cache)."""
    if cfg.input_kind == "tokens":
        batch = {"tokens": tokens_or_frames}
    else:
        batch = {"frames": tokens_or_frames}
    x = _embed(cfg, params, batch, rules)
    bsz = x.shape[0]
    positions = default_positions(cfg, bsz, 1, offset=cur_index)
    cos, sin = _rope(cfg, positions)

    new_cache: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(cfg, params, x, cos, sin, cache, cur_index, rules)
    else:
        for name, n, kind in _plan(cfg):
            stack = params[name]
            stack_cache = cache[name]
            if kind == "mamba":
                def body(h, inp):
                    lp, lc = inp
                    h, c = mamba_block(cfg, lp, h, rules, cache=lc)
                    return h, c
            else:
                def body(h, inp):
                    lp, lc = inp
                    h, c, _ = transformer_block(
                        cfg, lp, h, cos, sin, rules,
                        cache=lc, cur_index=cur_index, sort_impl=sort_impl)
                    return h, c
            x, updated = lax.scan(body, x, (stack, stack_cache))
            new_cache[name] = updated

    logits = _head(cfg, params, x, rules)
    return logits, new_cache


def _hybrid_decode(cfg, params, x, cos, sin, cache, cur_index, rules):
    shared_caches = []
    mamba_caches = []

    def body(h, inp):
        lp, lc = inp
        h, c = mamba_block(cfg, lp, h, rules, cache=lc)
        return h, c

    for gi, (start, end) in enumerate(hybrid_groups(cfg)):
        sc_in = jax.tree.map(lambda a: a[gi], cache["shared"])
        x, sc, _ = transformer_block(
            cfg, params["shared"], x, cos, sin, rules,
            cache=sc_in, cur_index=cur_index)
        grp = jax.tree.map(lambda a: a[start:end], params["blocks"])
        gc_in = jax.tree.map(lambda a: a[start:end], cache["blocks"])
        x, gc = lax.scan(body, x, (grp, gc_in))
        shared_caches.append(sc)
        mamba_caches.append(gc)

    new_cache = {
        "shared": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *shared_caches),
        "blocks": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *mamba_caches),
    }
    return x, new_cache


# ---------------- cache construction ----------------

def init_cache(cfg: ModelConfig, batch: int, seq: int, abstract: bool = False):
    """Decode-cache pytree + logical axes. ``seq`` is the context capacity.

    SSM caches are O(1) in ``seq`` — that is the sub-quadratic story that
    qualifies ssm/hybrid archs for the long_500k cell."""
    dtype = _dtype(cfg.compute_dtype)

    def build(shapes_axes):
        tree, axes = {}, {}
        for k, ((shape, dt), ax) in shapes_axes.items():
            tree[k] = jax.ShapeDtypeStruct(shape, dt) if abstract else jnp.zeros(shape, dt)
            axes[k] = ax
        return tree, axes

    def attn_entry(n_layers_stack):
        spec = init_attn_cache(cfg, batch, seq, dtype)
        if cfg.attn == "mla":
            ax = {"ckv": ("layers", "cache_batch", "cache_seq", None),
                  "kr": ("layers", "cache_batch", "cache_seq", None)}
        else:
            ax = {"k": ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None),
                  "v": ("layers", "cache_batch", "cache_seq", "cache_kv_heads", None)}
        return {
            k: (((n_layers_stack,) + shape, dt), ax[k])
            for k, (shape, dt) in spec.items()
        }

    def ssm_entry(n_layers_stack):
        spec = init_ssm_cache(cfg, batch, dtype)
        ax = {"conv": ("layers", "cache_batch", None, "act_mlp"),
              "ssm": ("layers", "cache_batch", "act_heads", None, None)}
        return {
            k: (((n_layers_stack,) + shape, dt), ax[k])
            for k, (shape, dt) in spec.items()
        }

    cache: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        n_apps = len(hybrid_groups(cfg))
        cache["shared"], axes["shared"] = build(attn_entry(n_apps))
        cache["blocks"], axes["blocks"] = build(ssm_entry(cfg.n_layers))
    elif cfg.family == "ssm":
        cache["blocks"], axes["blocks"] = build(ssm_entry(cfg.n_layers))
    else:
        for name, n, kind in _plan(cfg):
            cache[name], axes[name] = build(attn_entry(n))
    return cache, axes
