"""Model configuration schema for the architecture zoo.

One frozen dataclass tree describes every assigned architecture; configs/
instantiates them with the exact published dimensions. ``smoke_variant``
derives the reduced CPU-testable configuration mandated for per-arch smoke
tests (full configs are exercised only via the dry-run's ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoECfg", "MLACfg", "SSMCfg", "ModelConfig", "smoke_variant"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN width
    n_shared: int = 0              # shared (always-on) experts
    d_shared: int = 0              # width of the shared expert FFN
    capacity_factor: float = 1.25
    impl: str = "sort"             # 'sort' (paper technique) | 'einsum' (baseline)
    router_renorm: bool = True     # renormalize top-k probs
    first_dense: int = 0           # leading layers with a dense FFN instead
    dense_d_ff: int = 0
    aux_alpha: float = 0.01        # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int        # query low-rank dim (0 = full-rank queries)
    kv_lora: int       # compressed KV latent dim (this IS the decode cache)
    qk_nope: int       # non-rotary per-head qk dim
    qk_rope: int       # rotary per-head qk dim (single shared key head)
    v_head: int        # per-head value dim


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    expand: int = 2
    headdim: int = 64
    chunk: int = 256
    d_conv: int = 4
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    attn: Optional[str] = "gqa"    # gqa | mla | None (attention-free)
    mlp_act: str = "silu"          # silu | relu2 | gelu
    mlp_gated: bool = True
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid_period: int = 0         # zamba2: shared attn block every N ssm layers
    rope_kind: str = "rope"        # rope | mrope | none
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0          # glm4: partial rotary
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    attn_kv_chunk: int = 0         # >0: streaming (flash-style) attention over
                                   # KV chunks of this size — bounds prefill
                                   # memory to O(S*chunk) instead of O(S^2)
    input_kind: str = "tokens"     # tokens | frames (modality-frontend stub)
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    optim_dtype: str = "float32"   # AdamW moment dtype (bf16 = memory trick)
    remat: str = "none"            # none | dots | full
    tie_embeddings: bool = False
    notes: str = ""

    # ---- derived ----
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM state or hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab. Preserves every structural feature (GQA ratio,
    MLA, MoE routing, hybrid period, M-RoPE sections...)."""
    kw: dict = dict(
        n_layers=4 if cfg.hybrid_period else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(2, cfg.n_kv_heads)),
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        param_dtype="float32",
        compute_dtype="float32",
        optim_dtype="float32",
        remat="none",
    )
    if cfg.hybrid_period:
        kw["hybrid_period"] = 2
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_expert=32,
            d_shared=32 if cfg.moe.n_shared else 0,
            dense_d_ff=64 if cfg.moe.first_dense else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLACfg(
            q_lora=32 if cfg.mla.q_lora else 0,
            kv_lora=16, qk_nope=8, qk_rope=8, v_head=16,
        )
        kw["head_dim"] = 16  # unused by MLA path but kept consistent
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, headdim=16, chunk=8, n_groups=1
        )
    if cfg.rope_kind == "mrope":
        kw["mrope_sections"] = (2, 3, 3)  # sums to head_dim//2 = 8
    return cfg.replace(**kw)
