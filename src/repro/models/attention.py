"""Attention variants: GQA (llama3/glm4/nemotron/...) and MLA
(deepseek-v2/minicpm3), with training, prefill (cache-building) and decode
(cache-consuming) paths.

MLA decode uses the weight-absorption trick: queries are projected into the
KV latent space so attention runs directly against the compressed cache
(kv_lora + qk_rope per token) — the production reason MLA exists. The naive
and absorbed paths are equivalence-tested in tests/test_models.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import Rules, constrain
from .config import ModelConfig
from .layers import apply_rope, init_norm, rmsnorm
from .param import Builder

__all__ = ["init_attention", "attention", "init_attn_cache"]


def _softmax_attend(scores, mask, dtype):
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    return jax.nn.softmax(scores, axis=-1).astype(dtype)


def _causal_mask(t: int, s: int):
    # queries occupy the last t positions of an s-length context
    q_pos = jnp.arange(t)[:, None] + (s - t)
    return q_pos >= jnp.arange(s)[None, :]


def _decode_mask(s: int, cur_index, extra_dims: int):
    """Valid-context mask for one-token decode: positions <= cur_index.

    ``cur_index`` scalar (synchronized decode) or (B,) (continuous batching:
    each request sits at its own position). Shaped (B|1, 1*extra, 1, s) so it
    broadcasts against (B, ..., T=1, s) score tensors."""
    cur = jnp.asarray(cur_index)
    if cur.ndim == 0:
        m = jnp.arange(s) <= cur                        # (s,)
        return m.reshape((1,) * (extra_dims + 1) + (s,))
    m = jnp.arange(s)[None, :] <= cur[:, None]          # (B, s)
    return m.reshape((m.shape[0],) + (1,) * extra_dims + (s,))


def _cache_write(cache_arr, new, cur_index):
    """Write a one-token entry at cur_index (scalar or per-row (B,))."""
    new = new.astype(cache_arr.dtype)
    cur = jnp.asarray(cur_index)
    if cur.ndim == 0:
        idx = (jnp.zeros((), jnp.int32), cur) + (jnp.zeros((), jnp.int32),) * (cache_arr.ndim - 2)
        return jax.lax.dynamic_update_slice(cache_arr, new, idx)
    s = cache_arr.shape[1]
    onehot = jnp.arange(s)[None, :] == cur[:, None]     # (B, s)
    oh = onehot.reshape(onehot.shape + (1,) * (cache_arr.ndim - 2))
    return jnp.where(oh, new, cache_arr)


# ---------------- GQA ----------------

def _gqa_chunked(q, keys, vals, scale, chunk, dt):
    """Streaming-softmax attention over KV chunks (flash-attention pattern).

    Never materializes the (T, S) score matrix: running max/normalizer/
    accumulator are corrected per chunk. q (B,T,kh,g,d); keys/vals (B,S,kh,d).
    Causal. Returns ctx (B,T,kh,g,d).
    """
    b, t, kh, g, d = q.shape
    s = keys.shape[1]
    nc = s // chunk
    q_pos = jnp.arange(t)[:, None] + (s - t)

    m0 = jnp.full((b, kh, g, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kh, g, t), jnp.float32)
    a0 = jnp.zeros((b, t, kh, g, d), jnp.float32)

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(keys, i * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vals, i * chunk, chunk, axis=1)
        sc = jnp.einsum("btkgd,bskd->bkgts", q, ks).astype(jnp.float32) * scale
        col = i * chunk + jnp.arange(chunk)
        sc = jnp.where((q_pos >= col[None, :])[None, None, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        finite = jnp.isfinite(m_new)
        corr = jnp.where(finite, jnp.exp(m - m_new), 1.0)
        p = jnp.where(finite[..., None], jnp.exp(sc - m_new[..., None]), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgts,bskd->btkgd", p.astype(dt), vs).astype(jnp.float32)
        acc = acc * jnp.moveaxis(corr, 3, 1)[..., None] + pv
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    out = acc / jnp.maximum(jnp.moveaxis(l, 3, 1)[..., None], 1e-30)
    return out.astype(dt)


def _init_gqa(b: Builder, cfg: ModelConfig):
    dm, h, k, d = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": b.param((dm, h, d), ("embed", "heads", None)),
        "wk": b.param((dm, k, d), ("embed", "kv_heads", None)),
        "wv": b.param((dm, k, d), ("embed", "kv_heads", None)),
        "wo": b.param((h, d, dm), ("heads", None, "embed")),
    }


def _gqa(cfg, p, x, cos, sin, rules, cache, cur_index, return_cache):
    B, T = x.shape[:2]
    h, kh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = cfg.q_per_kv
    dt = x.dtype

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if cfg.rope_kind != "none":
        q = apply_rope(q, cos, sin, cfg.rope_pct)
        k = apply_rope(k, cos, sin, cfg.rope_pct)
    q = constrain(q, rules, "batch", "seq", "act_heads", None)

    if cache is not None:
        # decode: T == 1; write the new KV at cur_index, attend to the prefix
        keys = _cache_write(cache["k"], k, cur_index)
        vals = _cache_write(cache["v"], v, cur_index)
        s = keys.shape[1]
        mask = _decode_mask(s, cur_index, extra_dims=3)  # (B|1,1,1,1,s)
        new_cache = {"k": keys, "v": vals}
        keys, vals = keys.astype(dt), vals.astype(dt)
    else:
        keys, vals = k, v
        s = T
        mask = _causal_mask(T, s)
        new_cache = {"k": k, "v": v} if return_cache else None

    qg = q.reshape(B, T, kh, g, d)
    s_len = keys.shape[1]
    chunk = cfg.attn_kv_chunk
    if (cache is None and chunk and T > 1 and s_len > chunk
            and s_len % chunk == 0):
        # streaming attention: O(T*chunk) live scores instead of O(T*S)
        ctx = _gqa_chunked(qg, keys, vals, d ** -0.5, chunk, dt).reshape(B, T, h, d)
    else:
        scores = jnp.einsum("btkgd,bskd->bkgts", qg, keys) * (d ** -0.5)
        probs = _softmax_attend(scores, mask, dt)
        ctx = jnp.einsum("bkgts,bskd->btkgd", probs, vals).reshape(B, T, h, d)
    out = jnp.einsum("bthd,hdm->btm", ctx, p["wo"].astype(dt))
    return out, new_cache


# ---------------- MLA ----------------

def _init_mla(b: Builder, cfg: ModelConfig):
    m = cfg.mla
    dm, h = cfg.d_model, cfg.n_heads
    p = {
        "wkv_a": b.param((dm, m.kv_lora + m.qk_rope), ("embed", "kv_lora")),
        "kv_norm": init_norm(b, m.kv_lora),
        "wkv_b": b.param((m.kv_lora, h, m.qk_nope + m.v_head), ("kv_lora", "heads", None)),
        "wo": b.param((h, m.v_head, dm), ("heads", None, "embed")),
    }
    if m.q_lora:
        p["wq_a"] = b.param((dm, m.q_lora), ("embed", "q_lora"))
        p["q_norm"] = init_norm(b, m.q_lora)
        p["wq_b"] = b.param((m.q_lora, h, m.qk_nope + m.qk_rope), ("q_lora", "heads", None))
    else:
        p["wq"] = b.param((dm, h, m.qk_nope + m.qk_rope), ("embed", "heads", None))
    return p


def _mla_queries(cfg, p, x, cos, sin):
    m = cfg.mla
    dt = x.dtype
    if m.q_lora:
        cq = jnp.einsum("btd,dq->btq", x, p["wq_a"].astype(dt))
        cq = rmsnorm(p["q_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("btq,qhk->bthk", cq, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    qn, qr = q[..., : m.qk_nope], q[..., m.qk_nope :]
    qr = apply_rope(qr, cos, sin)
    return qn, qr


def _mla(cfg, p, x, cos, sin, rules, cache, cur_index, return_cache):
    m = cfg.mla
    B, T = x.shape[:2]
    h = cfg.n_heads
    dt = x.dtype
    scale = (m.qk_nope + m.qk_rope) ** -0.5

    qn, qr = _mla_queries(cfg, p, x, cos, sin)
    qn = constrain(qn, rules, "batch", "seq", "act_heads", None)

    ckv_full = jnp.einsum("btd,dc->btc", x, p["wkv_a"].astype(dt))
    ckv, kr = ckv_full[..., : m.kv_lora], ckv_full[..., m.kv_lora :]
    ckv = rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]  # single shared head

    if cache is not None:
        # --- absorbed decode path: attend in the compressed latent space ---
        ckv_c = _cache_write(cache["ckv"], ckv, cur_index)
        kr_c = _cache_write(cache["kr"], kr, cur_index)
        s = ckv_c.shape[1]
        mask = _decode_mask(s, cur_index, extra_dims=2)  # (B|1,1,1,s) vs (B,h,1,s)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
        ckv_all, kr_all = ckv_c.astype(dt), kr_c.astype(dt)

        w_uk = p["wkv_b"].astype(dt)[..., : m.qk_nope]        # (kvl, h, dn)
        w_uv = p["wkv_b"].astype(dt)[..., m.qk_nope :]        # (kvl, h, dv)
        q_lat = jnp.einsum("bthn,chn->bthc", qn, w_uk)        # queries -> latent
        scores = (
            jnp.einsum("bthc,bsc->bhts", q_lat, ckv_all)
            + jnp.einsum("bthr,bsr->bhts", qr, kr_all)
        ) * scale
        probs = _softmax_attend(scores, mask, dt)
        ctx_lat = jnp.einsum("bhts,bsc->bthc", probs, ckv_all)
        ctx = jnp.einsum("bthc,chv->bthv", ctx_lat, w_uv)
    else:
        # --- naive path (train / prefill): materialize per-head k,v ---
        kv = jnp.einsum("btc,chn->bthn", ckv, p["wkv_b"].astype(dt))
        kn, v = kv[..., : m.qk_nope], kv[..., m.qk_nope :]
        s = T
        mask = _causal_mask(T, s)
        scores = (
            jnp.einsum("bthn,bshn->bhts", qn, kn)
            + jnp.einsum("bthr,bsr->bhts", qr, kr)
        ) * scale
        probs = _softmax_attend(scores, mask, dt)
        ctx = jnp.einsum("bhts,bshv->bthv", probs, v)
        new_cache = {"ckv": ckv, "kr": kr} if return_cache else None

    out = jnp.einsum("bthv,hvm->btm", ctx, p["wo"].astype(dt))
    return out, new_cache


# ---------------- public API ----------------

def init_attention(b: Builder, cfg: ModelConfig):
    return _init_mla(b, cfg) if cfg.attn == "mla" else _init_gqa(b, cfg)


def attention(cfg: ModelConfig, p, x, cos, sin, rules: Rules,
              cache=None, cur_index=None, return_cache: bool = False):
    """Returns (out, new_cache). ``cache`` given => decode (T==1);
    ``return_cache`` => prefill (build cache from this forward)."""
    fn = _mla if cfg.attn == "mla" else _gqa
    return fn(cfg, p, x, cos, sin, rules, cache, cur_index, return_cache)


def init_attn_cache(cfg: ModelConfig, batch: int, seq: int, dtype):
    """Abstract/concrete per-layer cache shapes (without the layer axis)."""
    if cfg.attn == "mla":
        m = cfg.mla
        return {
            "ckv": ((batch, seq, m.kv_lora), dtype),
            "kr": ((batch, seq, m.qk_rope), dtype),
        }
    return {
        "k": ((batch, seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": ((batch, seq, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
