"""Explicit expert-parallel MoE under shard_map — the production EP path.

The GSPMD variant (models/moe.py) lets the partitioner derive the dispatch
collectives; this module writes them out: tokens are bucketed by destination
expert with the paper's sort, packed into per-destination-device capacity
buckets, exchanged with ONE all_to_all over the EP axis, computed against
the device-local expert shard, and returned with a second all_to_all. It is
the mesh-scale rendering of the paper's phase-2/3 (distribute into
sub-arrays -> process each in parallel), with devices as the sub-arrays.

Equivalence-tested against the GSPMD implementation on 8 devices
(tests/test_moe_ep.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..models.config import ModelConfig
from ..models.moe import capacity as _capacity
from ..parallel.compat import axis_size, shard_map

__all__ = ["ep_moe_shard", "ep_moe"]


def ep_moe_shard(cfg: ModelConfig, xf, router_w, w_in_local, w_out_local,
                 axis_name: str):
    """shard_map body. Per device:
      xf            (T_loc, d)      local token shard
      router_w      (d, E)          replicated router
      w_in_local    (E_loc, d, f*)  this device's expert shard
      w_out_local   (E_loc, f, d)
    Returns (y (T_loc, d), aux-loss scalar shaped (1,)).
    """
    m = cfg.moe
    p = axis_size(axis_name)
    t_loc, dm = xf.shape
    e, e_loc = m.n_experts, m.n_experts // p
    cap = _capacity(cfg, t_loc)  # per (local tokens, global experts)

    # --- route (identical math to the GSPMD path) ---
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, m.top_k)
    if m.router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    token_frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t_loc * m.top_k)
    aux = m.aux_alpha * e * jnp.sum(token_frac * jnp.mean(probs, axis=0))

    # --- paper technique: bucket assignments by (global) expert id ---
    n = t_loc * m.top_k
    flat_e = top_e.reshape(n).astype(jnp.int32)
    flat_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), m.top_k)
    flat_p = top_p.reshape(n)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e, sorted_t, gates = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(flat_e, length=e)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(n, dtype=jnp.int32) - offsets[sorted_e].astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)

    send = jnp.zeros((e * cap + 1, dm), xf.dtype).at[slot].set(xf[sorted_t])
    send = send[: e * cap].reshape(p, e_loc * cap, dm)

    # --- ONE all_to_all out: rows become (source_device, local_expert, cap) ---
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=False)
    buf = recv.reshape(p, e_loc, cap, dm).transpose(1, 0, 2, 3).reshape(e_loc, p * cap, dm)

    # --- local expert compute (batched over the device's experts) ---
    h = jnp.einsum("ecd,edf->ecf", buf, w_in_local.astype(buf.dtype))
    if cfg.mlp_gated:
        u, g = jnp.split(h, 2, axis=-1)
        h = u * jax.nn.silu(g)
    else:
        h = jax.nn.silu(h)
    out = jnp.einsum("ecf,efd->ecd", h, w_out_local.astype(buf.dtype))

    # --- all_to_all back, undo the permutation, combine with gates ---
    back = out.reshape(e_loc, p, cap, dm).transpose(1, 0, 2, 3).reshape(p, e_loc * cap, dm)
    ret = lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0, tiled=False)
    ret_flat = jnp.concatenate(
        [ret.reshape(e * cap, dm), jnp.zeros((1, dm), ret.dtype)], axis=0)
    contrib = ret_flat[slot]
    y = jnp.zeros((t_loc, dm), xf.dtype).at[sorted_t].add(
        contrib * gates[:, None].astype(xf.dtype))
    return y, aux[None]


def ep_moe(cfg: ModelConfig, mesh, axis_name, xf, router_w, w_in, w_out):
    """Host-facing wrapper: tokens and experts sharded over ``axis_name``."""
    from jax.sharding import PartitionSpec as P

    body = functools.partial(ep_moe_shard, cfg, axis_name=axis_name)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name), P(), P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
    )
    y, aux = jax.jit(fn)(xf, router_w, w_in, w_out)
    return y, jnp.sum(aux)
