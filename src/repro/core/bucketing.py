"""Length-bucketed segmented sort — the paper's core decomposition.

"The main idea of the proposed algorithm is distributing the elements of the
input datasets into many additional temporary sub-arrays according to a
number of characters in each word" — buckets are independent, so they sort
in parallel. On CPU the paper assigns one bucket per OpenMP thread; on TPU we
pad buckets to a common capacity and either ``vmap`` the traced comparator
sort across the bucket axis (the 'oets'/'bitonic' algorithms) or — the
production path — hand the whole (num_buckets, capacity, lanes) tensor to
``kernels.ops.segmented_sort`` ('pallas'), one batched lexicographic kernel
launch over all buckets at any lane count and capacity. Both are SPMD
renderings of the same decomposition.

The concatenation of sorted buckets in increasing length order yields
*shortlex* order (length-major, then alphabetic) — exactly the order the
paper's phases 2+3 produce.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import packing
from .bitonic import bitonic_sort
from .oets import oets_sort

__all__ = ["Buckets", "bucketize_words", "sort_buckets", "bucketed_sort_words"]


@dataclass
class Buckets:
    """Dense bucket storage: the paper's 3-D array (bucket, slot, packed lanes)."""

    keys: np.ndarray        # (num_buckets, capacity, lanes) uint32; sentinel padded
    counts: np.ndarray      # (num_buckets,) int32 — real elements per bucket
    lengths: np.ndarray     # (num_buckets,) int32 — word length of each bucket


def bucketize_words(words, capacity: int | None = None) -> Buckets:
    """Phase 2 of the paper's pre-processing: distribute words into
    per-length sub-arrays sized by the length histogram."""
    by_len: dict[int, list] = {}
    for w in words:
        by_len.setdefault(len(w), []).append(w)
    if not by_len:
        return Buckets(
            keys=np.zeros((0, 0, 1), np.uint32),
            counts=np.zeros((0,), np.int32),
            lengths=np.zeros((0,), np.int32),
        )
    lengths = sorted(by_len)
    cap = capacity or max(len(v) for v in by_len.values())
    lanes = packing.lanes_for_width(max(lengths))
    keys = np.full((len(lengths), cap, lanes), packing.SENTINEL_U32, dtype=np.uint32)
    counts = np.zeros((len(lengths),), np.int32)
    for i, ln in enumerate(lengths):
        bucket = by_len[ln]
        if len(bucket) > cap:
            raise ValueError(f"bucket for length {ln} exceeds capacity {cap}")
        keys[i, : len(bucket)] = packing.pack_words(bucket, width=lanes * 4)
        counts[i] = len(bucket)
    return Buckets(keys=keys, counts=counts, lengths=np.asarray(lengths, np.int32))


def sort_buckets(keys: jax.Array, algorithm: str = "oets",
                 counts: jax.Array | None = None) -> jax.Array:
    """Sort every bucket independently (vmap over the bucket axis).

    ``keys``: (num_buckets, capacity, lanes) uint32, sentinel padded.
    ``algorithm``: 'oets' (paper-faithful parallel bubble sort), 'bitonic'
    (beyond-paper network), 'pallas' (the fused ``kernels.ops.segmented_sort``
    pipeline — one batched lex kernel launch over all buckets, any lane
    count and any capacity including the multi-block blocksort tier), or
    'xla' (production baseline). ``counts`` (optional, (num_buckets,)) lets
    the 'pallas' path re-mask slots beyond each bucket's count to the
    sentinel; ``None`` trusts the tensor's existing sentinel padding.
    """
    if algorithm == "oets":
        return jax.vmap(oets_sort)(keys)
    if algorithm == "bitonic":
        return jax.vmap(bitonic_sort)(keys)
    if algorithm == "pallas":
        from ..kernels.ops import segmented_sort
        return segmented_sort(keys, counts)
    if algorithm == "xla":
        # lexicographic sort of multi-lane keys via XLA's variadic sort
        def one(bucket):
            lanes = [bucket[:, l] for l in range(bucket.shape[1])]
            sorted_lanes = jax.lax.sort(lanes, num_keys=len(lanes))
            return jnp.stack(sorted_lanes, axis=1)

        return jax.vmap(one)(keys)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def bucketed_sort_words(words, algorithm: str = "oets") -> list:
    """End-to-end paper pipeline: bucketize -> parallel in-bucket sort ->
    concatenate in length order. Returns words in shortlex order."""
    buckets = bucketize_words(words)
    if buckets.keys.size == 0:
        return []
    sorted_keys = np.asarray(sort_buckets(jnp.asarray(buckets.keys), algorithm,
                                          counts=jnp.asarray(buckets.counts)))
    out = []
    for i in range(sorted_keys.shape[0]):
        cnt = int(buckets.counts[i])
        out.extend(packing.unpack_words(sorted_keys[i, :cnt]))
    return out
