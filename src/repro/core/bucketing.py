"""Length-bucketed segmented sort — the paper's core decomposition.

"The main idea of the proposed algorithm is distributing the elements of the
input datasets into many additional temporary sub-arrays according to a
number of characters in each word" — buckets are independent, so they sort
in parallel. On CPU the paper assigns one bucket per OpenMP thread; on TPU we
pad buckets to a common capacity and either ``vmap`` the traced comparator
sort across the bucket axis (the 'oets'/'bitonic' algorithms) or — the
production path — hand the whole (num_buckets, capacity, lanes) tensor to
``kernels.ops.segmented_sort`` ('pallas'), one batched lexicographic kernel
launch over all buckets at any lane count and capacity. Both are SPMD
renderings of the same decomposition.

The concatenation of sorted buckets in increasing length order yields
*shortlex* order (length-major, then alphabetic) — exactly the order the
paper's phases 2+3 produce.

The distribute step itself (phases 1-2) also runs on device:
``bucketize_packed``/``sorted_packed`` route through
``kernels.ops.distribute``/``bucketize`` — the Pallas length-histogram +
stable-rank pass plus one scatter — so ``bucketed_sort_words`` has **zero
host-side per-word Python loops between packing and unpacking**:
bytes pack in (host ingress), one distribute launch + one jitted
scatter→segmented-sort→compaction program, bytes unpack out (host egress).
``bucketize_words`` below is kept as the host reference implementation the
differential tests compare against. Device buckets are *dense per-length*
(bucket id = byte length, empty lengths hold count 0), whereas the host
reference only materializes lengths that occur; the sorted concatenations
agree exactly.

Chunked ingest of inputs larger than one launch lives one layer up in
``repro.pipeline`` (per-chunk ``sorted_packed`` runs + k-way lex merge).
"""

from __future__ import annotations

import functools
import logging
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import packing
from .bitonic import bitonic_sort
from .oets import oets_sort

__all__ = ["Buckets", "bucketize_words", "bucketize_packed", "sort_buckets",
           "sorted_packed", "bucketed_sort_words"]

log = logging.getLogger("repro.core")


@dataclass
class Buckets:
    """Dense bucket storage: the paper's 3-D array (bucket, slot, packed lanes)."""

    keys: np.ndarray        # (num_buckets, capacity, lanes) uint32; sentinel padded
    counts: np.ndarray      # (num_buckets,) int32 — real elements per bucket
    lengths: np.ndarray     # (num_buckets,) int32 — word length of each bucket
    dropped: int = 0        # elements clipped under on_overflow='clip'


def bucketize_packed(keys, capacity: int | None = None,
                     on_overflow: str = "raise") -> Buckets:
    """Device counterpart of :func:`bucketize_words`: distribute an already
    packed (n, lanes) uint32 word tensor into the dense per-length bucket
    tensor via ``kernels.ops.bucketize`` (Pallas histogram/rank pass + one
    scatter) — no host per-word loop. Bucket ``l`` holds the words of byte
    length ``l`` in arrival order; ``lengths`` is ``arange(4*lanes+1)``.

    ``on_overflow`` — the degrade policy when an explicit ``capacity`` is
    exceeded (``kernels.ops.bucketize`` semantics): ``'raise'`` (default —
    the host reference's contract; raises ``repro.runtime.CapacityOverflow``,
    a ``ValueError``), ``'retry'`` (one exact-count re-scatter at the true
    max, lossless), or ``'clip'`` (keep the static tensor, report the loss
    in ``Buckets.dropped`` and a warning log)."""
    from ..kernels.ops import bucketize  # lazy: core imports kernels
    keys = jnp.asarray(keys, jnp.uint32)
    if keys.ndim != 2:
        raise ValueError("keys must be (n, lanes) packed words")
    bucket_keys, counts, dropped = bucketize(keys, capacity=capacity,
                                             on_overflow=on_overflow)
    return Buckets(keys=bucket_keys, counts=counts,
                   lengths=jnp.arange(bucket_keys.shape[0], dtype=jnp.int32),
                   dropped=dropped)


def bucketize_words(words, capacity: int | None = None) -> Buckets:
    """Phase 2 of the paper's pre-processing: distribute words into
    per-length sub-arrays sized by the length histogram.

    Host reference implementation (the original Python dict loop) — the
    production path is :func:`bucketize_packed` / ``kernels.ops.bucketize``
    on device; the differential tests compare the two. Length is the
    *encoded byte* length (the unit the packed lanes sort by — multi-byte
    UTF-8 words bucket by their byte width), matching the device kernel and
    the tests' byte-shortlex oracle."""
    by_len: dict[int, list] = {}
    for w in words:
        by_len.setdefault(packing.byte_length(w), []).append(w)
    if not by_len:
        return Buckets(
            keys=np.zeros((0, 0, 1), np.uint32),
            counts=np.zeros((0,), np.int32),
            lengths=np.zeros((0,), np.int32),
        )
    lengths = sorted(by_len)
    cap = capacity or max(len(v) for v in by_len.values())
    lanes = packing.lanes_for_width(max(lengths))
    keys = np.full((len(lengths), cap, lanes), packing.SENTINEL_U32, dtype=np.uint32)
    counts = np.zeros((len(lengths),), np.int32)
    for i, ln in enumerate(lengths):
        bucket = by_len[ln]
        if len(bucket) > cap:
            raise ValueError(f"bucket for length {ln} exceeds capacity {cap}")
        keys[i, : len(bucket)] = packing.pack_words(bucket, width=lanes * 4)
        counts[i] = len(bucket)
    return Buckets(keys=keys, counts=counts, lengths=np.asarray(lengths, np.int32))


def sort_buckets(keys: jax.Array, algorithm: str = "oets",
                 counts: jax.Array | None = None) -> jax.Array:
    """Sort every bucket independently (vmap over the bucket axis).

    ``keys``: (num_buckets, capacity, lanes) uint32, sentinel padded.
    ``algorithm``: 'oets' (paper-faithful parallel bubble sort), 'bitonic'
    (beyond-paper network), 'pallas' (the fused ``kernels.ops.segmented_sort``
    pipeline — one batched lex kernel launch over all buckets, any lane
    count and any capacity including the multi-block blocksort tier), or
    'xla' (production baseline). ``counts`` (optional, (num_buckets,)) lets
    the 'pallas' path re-mask slots beyond each bucket's count to the
    sentinel; ``None`` trusts the tensor's existing sentinel padding.
    """
    if algorithm == "oets":
        return jax.vmap(oets_sort)(keys)
    if algorithm == "bitonic":
        return jax.vmap(bitonic_sort)(keys)
    if algorithm == "pallas":
        from ..kernels.ops import segmented_sort
        return segmented_sort(keys, counts)
    if algorithm == "xla":
        # lexicographic sort of multi-lane keys via XLA's variadic sort
        def one(bucket):
            lanes = [bucket[:, l] for l in range(bucket.shape[1])]
            sorted_lanes = jax.lax.sort(lanes, num_keys=len(lanes))
            return jnp.stack(sorted_lanes, axis=1)

        return jax.vmap(one)(keys)
    raise ValueError(f"unknown algorithm {algorithm!r}")


@functools.partial(jax.jit, static_argnames=("capacity", "algorithm"))
def _fused_sort_packed(keys, *, capacity: int, algorithm: str):
    """One jitted program: distribute scatter -> segmented bucket sort ->
    shortlex compaction -> packed rank keys. ``keys`` (n, lanes) uint32 in;
    out come ``(lengths (B*cap,), sorted (B*cap, lanes), counts (B,),
    packed)`` with the real words occupying the leading
    ``min(counts, cap).sum()`` slots in exact shortlex order and sentinel
    fill beyond (the caller slices). ``packed`` is the tuple of 1-2 uint32
    rank-key lanes of the compacted shortlex tuples
    (``kernels.keypack.pack_shortlex`` — a few bit ops fused into the same
    program), which the run-merge tier ranks on instead of re-packing."""
    from ..kernels.keypack import pack_shortlex
    from ..kernels.ops import _scatter_to_buckets, distribute
    n, lanes = keys.shape
    num_buckets = 4 * lanes + 1
    dest, rank, counts = distribute(keys)
    buckets = _scatter_to_buckets(keys, dest, rank, num_buckets=num_buckets,
                                  capacity=capacity)
    counts_c = jnp.minimum(counts, capacity)
    sorted_keys = sort_buckets(buckets, algorithm, counts=counts_c)
    # compaction: bucket b's i-th real word lands at offset[b] + i — the
    # concatenation-in-length-order of the paper's phase 4, as one scatter
    offsets = jnp.cumsum(counts_c) - counts_c
    slot_in = jnp.arange(capacity, dtype=jnp.int32)
    valid = slot_in[None, :] < counts_c[:, None]
    pos = jnp.where(valid, offsets[:, None] + slot_in[None, :],
                    num_buckets * capacity).reshape(-1)
    flat_keys = jnp.full((num_buckets * capacity + 1, lanes),
                         packing.SENTINEL_U32, jnp.uint32
                         ).at[pos].set(sorted_keys.reshape(-1, lanes))
    blen = jnp.broadcast_to(jnp.arange(num_buckets, dtype=jnp.int32)[:, None],
                            (num_buckets, capacity)).reshape(-1)
    flat_lens = jnp.zeros((num_buckets * capacity + 1,), jnp.int32
                          ).at[pos].set(blen)
    m = num_buckets * capacity
    packed = pack_shortlex(flat_lens[:m], flat_keys[:m])
    return flat_lens[:m], flat_keys[:m], counts, tuple(packed.lanes)


def sorted_packed(keys, algorithm: str = "pallas",
                  capacity: int | None = None, return_packed: bool = False,
                  on_overflow: str = "raise"):
    """Shortlex-sort a packed (n, lanes) uint32 word tensor entirely on
    device: distribute -> segmented in-bucket sort -> compact, zero host
    per-word loops. Returns ``(lengths (n,), sorted_keys (n, lanes))``
    device arrays in exact shortlex order (length-major, then byte-wise);
    with ``return_packed`` a third element carries the tuple of packed
    shortlex rank-key lanes (``kernels/keypack.py``) the fused program
    computed during compaction — the merge-ready key the ``repro.pipeline``
    run tier ranks on.

    ``capacity``: per-bucket slots for the fused program (static under jit);
    ``None`` sizes it at the histogram max (one extra distribute launch +
    one scalar sync). ``on_overflow`` — policy for a too-small explicit
    capacity: ``'raise'`` (default; ``repro.runtime.CapacityOverflow``, a
    ``ValueError``), ``'retry'`` (re-run the fused program at the true
    histogram max — lossless, one extra launch), or ``'clip'`` (drop the
    overflow: the outputs shrink to the surviving element count, with a
    warning log). The per-chunk producer of the ``repro.pipeline``
    sorted-run tier."""
    from ..runtime.failure import CapacityOverflow
    if on_overflow not in ("raise", "retry", "clip"):
        raise ValueError(f"unknown on_overflow policy {on_overflow!r}")
    keys = jnp.asarray(keys, jnp.uint32)
    n = keys.shape[0]
    if n == 0:
        lens = jnp.zeros((0,), jnp.int32)
        if not return_packed:
            return lens, keys
        from ..kernels.keypack import pack_shortlex
        return lens, keys, tuple(pack_shortlex(lens, keys).lanes)
    if capacity is None:
        from ..kernels.ops import distribute
        _, _, counts = distribute(keys)
        capacity = max(1, int(jnp.max(counts)))
    flat_lens, flat_keys, counts, packed = _fused_sort_packed(
        keys, capacity=capacity, algorithm=algorithm)
    true_max = int(jnp.max(counts))
    if true_max > capacity:
        ln = int(jnp.argmax(counts))
        dropped = int(jnp.sum(jnp.maximum(counts - capacity, 0)))
        if on_overflow == "raise":
            raise CapacityOverflow(
                f"bucket for length {ln} exceeds capacity {capacity}",
                capacity, required=true_max, dropped=dropped)
        if on_overflow == "retry":
            log.warning("sorted_packed overflow: capacity %d -> %d "
                        "(lossless retry of the fused program)",
                        capacity, true_max)
            flat_lens, flat_keys, counts, packed = _fused_sort_packed(
                keys, capacity=true_max, algorithm=algorithm)
        else:
            log.warning("sorted_packed overflow: dropping %d element(s) "
                        "past capacity %d (bucket for length %d needs %d)",
                        dropped, capacity, ln, true_max)
            n = n - dropped
    if not return_packed:
        return flat_lens[:n], flat_keys[:n]
    return flat_lens[:n], flat_keys[:n], tuple(p[:n] for p in packed)


def bucketed_sort_words(words, algorithm: str = "oets") -> list:
    """End-to-end paper pipeline: pack -> on-device distribute -> parallel
    in-bucket sort -> on-device shortlex compaction -> unpack. Returns words
    in shortlex order. Between ``pack_words`` (ingress) and ``unpack_words``
    (egress) every per-word step runs on device — the host reference
    ``bucketize_words`` is never called (pinned by a mock-patch test)."""
    words = list(words)
    if not words:
        return []
    keys = jnp.asarray(packing.pack_words(words))
    _, sorted_keys = sorted_packed(keys, algorithm=algorithm)
    return packing.unpack_words(np.asarray(sorted_keys))
