"""Core sorting library: the paper's contribution as composable JAX modules.

Layers of the hierarchy (lane -> block -> device):
  packing      fixed-width key packing (paper's dense 3-D array insight)
  oets         odd-even transposition sort = parallel bubble sort (paper-faithful)
  bitonic      O(log^2 n)-phase network sort (beyond-paper hillclimb)
  bucketing    length-bucketed segmented sort (paper's decomposition)
  blocksort    multi-block tiled sort (block-local kernels + odd-even merge)
  distributed  mesh-scale engines: odd-even block sort (bubble sort over ICI)
               + splitter sample sort, behind distributed_sort(engine=...)
"""

from .packing import pack_words, unpack_words, lanes_for_width, SENTINEL_U32
from .oets import oets_sort, oets_sort_kv, oets_argsort, lex_gt
from .bitonic import (bitonic_sort, bitonic_sort_kv, bitonic_merge,
                      bitonic_merge_kv, bitonic_merge_lex)
from .bucketing import (Buckets, bucketize_words, bucketize_packed,
                        sort_buckets, sorted_packed, bucketed_sort_words)
from .blocksort import (block_sort, block_sort_kv, block_sort_lex,
                        default_block_size)
from .distributed import (choose_engine, odd_even_block_sort,
                          odd_even_block_sort_lex, sample_sort,
                          sample_sort_lex, sample_sort_exact,
                          SampleSortResult,
                          distributed_sort, distributed_sort_kv,
                          distributed_sort_lex, local_merge)

__all__ = [
    "pack_words", "unpack_words", "lanes_for_width", "SENTINEL_U32",
    "oets_sort", "oets_sort_kv", "oets_argsort", "lex_gt",
    "bitonic_sort", "bitonic_sort_kv", "bitonic_merge", "bitonic_merge_kv",
    "bitonic_merge_lex",
    "Buckets", "bucketize_words", "bucketize_packed", "sort_buckets",
    "sorted_packed", "bucketed_sort_words",
    "block_sort", "block_sort_kv", "block_sort_lex", "default_block_size",
    "choose_engine", "odd_even_block_sort", "odd_even_block_sort_lex",
    "sample_sort", "sample_sort_lex", "sample_sort_exact",
    "SampleSortResult",
    "distributed_sort", "distributed_sort_kv", "distributed_sort_lex",
    "local_merge",
]
