"""Bitonic sorting network — the beyond-paper upgrade of the comparator sort.

The paper's comparator network (bubble sort / OETS) needs n phases. A bitonic
network sorts in O(log^2 n) phases of the *same* vectorized compare-exchange
primitive, so on a TPU — where a phase is one fused vector op — it is the
natural hillclimb from the paper's baseline. Kept separate so EXPERIMENTS.md
can report paper-faithful (OETS) and beyond-paper (bitonic) numbers
independently.

Also provides ``bitonic_merge`` for merging two sorted blocks in O(log n)
phases — used by the device-level distributed sort instead of a full
re-sort of the concatenation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels.lex import lex_gt_lanes, map_lanes, select_lanes
from .oets import lex_gt, _sentinel

__all__ = ["bitonic_sort", "bitonic_sort_kv", "bitonic_merge",
           "bitonic_merge_kv", "bitonic_merge_lex"]


def _pad_pow2(keys, vals):
    n = keys.shape[0]
    m = 1 << max(0, (n - 1).bit_length())
    if m == n:
        return keys, vals, n
    pad_k = jnp.full((m - n,) + keys.shape[1:], _sentinel(keys.dtype), keys.dtype)
    keys = jnp.concatenate([keys, pad_k], axis=0)
    if vals is not None:
        pad_v = jnp.zeros((m - n,) + vals.shape[1:], vals.dtype)
        vals = jnp.concatenate([vals, pad_v], axis=0)
    return keys, vals, n


def _ce_stage(keys, vals, j, direction_mask):
    """Compare-exchange with partner ``i ^ j``; ascending where mask is True."""
    n = keys.shape[0]
    idx = jnp.arange(n)
    partner = idx ^ j
    pk = keys[partner]
    gt = lex_gt(keys, pk)
    lt = lex_gt(pk, keys)
    is_lower = idx < partner
    # ascending block: lower index keeps the min; descending: keeps the max.
    want_swap = jnp.where(
        direction_mask,
        jnp.where(is_lower, gt, lt),
        jnp.where(is_lower, lt, gt),
    )
    ws_k = want_swap.reshape(want_swap.shape + (1,) * (keys.ndim - 1))
    new_keys = jnp.where(ws_k, pk, keys)
    if vals is None:
        return new_keys, None
    pv = vals[partner]
    ws_v = want_swap.reshape(want_swap.shape + (1,) * (vals.ndim - 1))
    return new_keys, jnp.where(ws_v, pv, vals)


def _bitonic(keys, vals):
    keys, vals, n_orig = _pad_pow2(keys, vals)
    n = keys.shape[0]
    if n <= 1:
        return keys[:n_orig], vals if vals is None else vals[:n_orig]
    idx = jnp.arange(n)
    for stage in range(1, int(math.log2(n)) + 1):
        k = 1 << stage
        direction = (idx & k) == 0  # ascending where bit unset
        for sub in reversed(range(stage)):
            keys, vals = _ce_stage(keys, vals, 1 << sub, direction)
    return keys[:n_orig], vals if vals is None else vals[:n_orig]


def bitonic_sort(keys: jax.Array) -> jax.Array:
    """Sort ascending along axis 0; (n,) or (n, L) lex keys. Any n (padded)."""
    out, _ = _bitonic(keys, None)
    return out


def bitonic_sort_kv(keys: jax.Array, vals: jax.Array):
    out, v = _bitonic(keys, vals)
    return out, v


def _merge_network(keys, vals):
    """Merge phases only (input must be bitonic, e.g. asc ++ desc)."""
    n = keys.shape[0]
    direction = jnp.ones((n,), dtype=bool)  # fully ascending
    sub = n >> 1
    while sub >= 1:
        keys, vals = _ce_stage(keys, vals, sub, direction)
        sub >>= 1
    return keys, vals


def bitonic_merge(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two ascending-sorted blocks of equal pow2 length in O(log n) phases."""
    if a.shape != b.shape:
        raise ValueError("blocks must have equal shapes")
    n = a.shape[0]
    if n & (n - 1):
        raise ValueError("block length must be a power of two")
    keys = jnp.concatenate([a, b[::-1]], axis=0)  # ascending ++ descending = bitonic
    out, _ = _merge_network(keys, None)
    return out


def bitonic_merge_kv(ak, av, bk, bv):
    n = ak.shape[0]
    if n & (n - 1):
        raise ValueError("block length must be a power of two")
    keys = jnp.concatenate([ak, bk[::-1]], axis=0)
    vals = jnp.concatenate([av, bv[::-1]], axis=0)
    return _merge_network(keys, vals)


def _ce_stage_lanes(lanes, j, direction_mask):
    """Tuple compare-exchange with partner ``i ^ j`` over parallel 1-D lanes
    (``kernels/lex.py`` conventions: every lane participates, lane 0 most
    significant, all lanes swap together)."""
    n = lanes[0].shape[0]
    idx = jnp.arange(n)
    partner = idx ^ j
    plane = map_lanes(lambda a: a[partner], lanes)
    gt = lex_gt_lanes(lanes, plane)
    lt = lex_gt_lanes(plane, lanes)
    is_lower = idx < partner
    want_swap = jnp.where(
        direction_mask,
        jnp.where(is_lower, gt, lt),
        jnp.where(is_lower, lt, gt),
    )
    return select_lanes(want_swap, plane, lanes)


def bitonic_merge_lex(a_lanes, b_lanes):
    """Merge two tuple-sorted blocks of equal pow2 length in O(log n) phases.

    ``a_lanes``/``b_lanes``: equal-length lists of same-shape 1-D arrays,
    each block ascending under the full-tuple lex compare. Returns the merged
    lane list (length ``2n``). The key-only/kv merges are the 1-/2-tuple
    special cases of this network."""
    n = a_lanes[0].shape[0]
    if n & (n - 1):
        raise ValueError("block length must be a power of two")
    lanes = [jnp.concatenate([a, b[::-1]], axis=0)  # asc ++ desc = bitonic
             for a, b in zip(a_lanes, b_lanes)]
    direction = jnp.ones((2 * n,), dtype=bool)
    sub = n
    while sub >= 1:
        lanes = _ce_stage_lanes(lanes, sub, direction)
        sub >>= 1
    return lanes
