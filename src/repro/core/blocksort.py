"""Hierarchical multi-block sort — the paper's decomposition at tile scale.

The single-block kernels (``kernels/oets_kernel.py``, ``bitonic_kernel.py``)
pad every row to one VMEM block, so a row wider than a tile either fails or
pays O(n) OETS phases over the whole width. This module is the scale-out:

  1. split each row into ``nb`` blocks of ``block_size`` lanes (the paper's
     "distribute the elements into sub-arrays"),
  2. sort every block locally with the existing OETS/bitonic row kernels —
     one pallas grid over all blocks of all rows at once,
  3. run ``nb`` alternating even/odd rounds of the cross-block merge kernel
     (``kernels/merge_kernel.py``) — odd-even transposition sort lifted from
     lanes to blocks, with compare-exchange generalised to merge-split.

Round r with parity p merges block pairs (2i+p, 2i+p+1); after ``nb`` rounds
the row is globally sorted (the 0-1 principle applied block-wise). Handles
1-D arrays of arbitrary length and (rows, cols) batches whose cols span many
VMEM blocks, key-only and key-value. ``repro.kernels.ops.sort`` picks this
path automatically beyond one block; ``block_size`` is the override knob.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.bitonic_kernel import bitonic_rows_kv_pallas, bitonic_rows_pallas
from ..kernels.merge_kernel import merge_adjacent_kv_pallas, merge_adjacent_pallas
from ..kernels.oets_kernel import oets_rows_kv_pallas, oets_rows_pallas
from ..kernels.ops import (_SUBLANES, _as_rows, _auto_interpret, _next_pow2,
                           _pad_cols)

__all__ = ["block_sort", "block_sort_kv", "default_block_size"]

_MIN_BLOCK = 128          # one lane tile — smallest block the kernels accept
_DEFAULT_MIN_BLOCK = 512
# VMEM cap counts every ref the merge kernel holds: each is (8, 2B) x 4B.
# Key-only merge has 2 refs (in+out) -> 4 MiB at B=32Ki; kv has 4 refs
# (keys+vals, in+out) -> 4 MiB at B=16Ki. Both leave headroom in a 16 MiB
# VMEM core for double buffering.
_MAX_BLOCK = 1 << 15
_MAX_BLOCK_KV = 1 << 14
_TARGET_BLOCKS = 16       # merge rounds = num_blocks; keep that small


def default_block_size(n: int, kv: bool = False) -> int:
    """Cost-model block pick for an n-lane row.

    Per-element phase count is ~log^2(B) (local bitonic) + nb * log(2B)
    (merge rounds, nb = ceil(n/B)), so growing B trades a quadratic-log local
    term against linearly fewer rounds; the VMEM cap bounds B above (kv
    carries twice the refs, so its cap is half). Aim for ~_TARGET_BLOCKS
    blocks, clamped to [512, 32Ki] (key-only) or [512, 16Ki] (kv) lanes."""
    cap = _MAX_BLOCK_KV if kv else _MAX_BLOCK
    b = _next_pow2(max(1, -(-n // _TARGET_BLOCKS)))
    return max(_DEFAULT_MIN_BLOCK, min(cap, b))


def _validate_block(block_size, n, kv=False):
    b = block_size or default_block_size(n, kv=kv)
    if b < _MIN_BLOCK or b & (b - 1):
        raise ValueError(
            f"block_size must be a power of two >= {_MIN_BLOCK}, got {b}")
    return b


def _pad_grid_rows(x):
    """Pad rows so the kernels' row grid tiles exactly; returns (padded, real).

    rows <= 8 runs as a single (rows,)-high block; beyond that the kernels
    tile 8 sublanes at a time, so rows must be a multiple of 8."""
    rows = x.shape[0]
    if rows <= _SUBLANES or rows % _SUBLANES == 0:
        return x, rows
    pad = (-rows) % _SUBLANES
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0), rows


def _merge_rounds(xs, nb, block, interpret, merge_fn):
    """nb alternating even/odd block-pair merge rounds over (rows, nb*block).

    ``xs`` is a tuple (keys,) or (keys, vals); untouched edge blocks (the
    first block on odd rounds, the last on rounds with a dangling block) are
    carried through by concatenation around the merged span."""
    npad = nb * block
    for r in range(nb):
        parity = r % 2
        npairs = (nb - parity) // 2
        if npairs == 0:
            continue
        lo = parity * block
        hi = lo + npairs * 2 * block
        merged = merge_fn(*(a[:, lo:hi] for a in xs), block=block,
                          interpret=interpret)
        if not isinstance(merged, tuple):
            merged = (merged,)
        if lo == 0 and hi == npad:
            xs = merged
        else:
            xs = tuple(
                jnp.concatenate([a[:, :lo], m, a[:, hi:]], axis=1)
                for a, m in zip(xs, merged))
    return xs


@functools.partial(jax.jit, static_argnames=("block_size", "local_algorithm", "interpret"))
def _block_sort_2d(x, *, block_size, local_algorithm, interpret):
    rows, n = x.shape
    nb = -(-n // block_size)
    npad = nb * block_size
    x = _pad_cols(x, npad)

    # local phase: every block of every row is one kernel row
    loc = x.reshape(rows * nb, block_size)
    loc, real = _pad_grid_rows(loc)
    fn = bitonic_rows_pallas if local_algorithm == "bitonic" else oets_rows_pallas
    x = fn(loc, interpret=interpret)[:real].reshape(rows, npad)

    if nb > 1:
        xp, real_rows = _pad_grid_rows(x)
        (xp,) = _merge_rounds((xp,), nb, block_size, interpret,
                              merge_adjacent_pallas)
        x = xp[:real_rows]
    return x[:, :n]


@functools.partial(jax.jit, static_argnames=("block_size", "local_algorithm", "interpret"))
def _block_sort_kv_2d(keys, vals, *, block_size, local_algorithm, interpret):
    rows, n = keys.shape
    nb = -(-n // block_size)
    npad = nb * block_size
    # vals pad with their own sentinel so the padding pair (max key, max val)
    # is the lex maximum under the kernels' (key, val) compare — it can never
    # displace a real payload even when real keys equal the key sentinel.
    keys = _pad_cols(keys, npad)
    vals = _pad_cols(vals, npad)

    lk = keys.reshape(rows * nb, block_size)
    lv = vals.reshape(rows * nb, block_size)
    lk, real = _pad_grid_rows(lk)
    lv, _ = _pad_grid_rows(lv)
    fn = bitonic_rows_kv_pallas if local_algorithm == "bitonic" else oets_rows_kv_pallas
    sk, sv = fn(lk, lv, interpret=interpret)
    keys = sk[:real].reshape(rows, npad)
    vals = sv[:real].reshape(rows, npad)

    if nb > 1:
        kp, real_rows = _pad_grid_rows(keys)
        vp, _ = _pad_grid_rows(vals)
        kp, vp = _merge_rounds((kp, vp), nb, block_size, interpret,
                               merge_adjacent_kv_pallas)
        keys, vals = kp[:real_rows], vp[:real_rows]
    return keys[:, :n], vals[:, :n]


def block_sort(x, *, block_size: int | None = None,
               local_algorithm: str = "bitonic",
               interpret: bool | None = None):
    """Sort a 1-D array or each row of a (rows, cols) array ascending.

    ``block_size``: lanes per block (power of two >= 128); None = cost model.
    ``local_algorithm``: 'bitonic' (default) or 'oets' for the in-block sort.
    """
    if local_algorithm not in ("bitonic", "oets"):
        raise ValueError(f"unknown local algorithm {local_algorithm!r}")
    interpret = _auto_interpret(interpret)
    x2, vec = _as_rows(x)
    if 0 in x2.shape:
        return x
    b = _validate_block(block_size, x2.shape[1])
    out = _block_sort_2d(x2, block_size=b, local_algorithm=local_algorithm,
                         interpret=interpret)
    return out[0] if vec else out


def block_sort_kv(keys, vals, *, block_size: int | None = None,
                  local_algorithm: str = "bitonic",
                  interpret: bool | None = None):
    """Key-value variant of :func:`block_sort`; ``vals`` rides the same
    permutation (equal keys may permute their payloads)."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    if local_algorithm not in ("bitonic", "oets"):
        raise ValueError(f"unknown local algorithm {local_algorithm!r}")
    interpret = _auto_interpret(interpret)
    k2, vec = _as_rows(keys)
    v2, _ = _as_rows(vals)
    if 0 in k2.shape:
        return keys, vals
    b = _validate_block(block_size, k2.shape[1], kv=True)
    ok, ov = _block_sort_kv_2d(k2, v2, block_size=b,
                               local_algorithm=local_algorithm,
                               interpret=interpret)
    return (ok[0], ov[0]) if vec else (ok, ov)
