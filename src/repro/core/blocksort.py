"""Hierarchical multi-block sort — the paper's decomposition at tile scale.

The single-block kernels (``kernels/oets_kernel.py``, ``bitonic_kernel.py``)
pad every row to one VMEM block, so a row wider than a tile either fails or
pays O(n) OETS phases over the whole width. This module is the scale-out:

  1. split each row into ``nb`` blocks of ``block_size`` lanes (the paper's
     "distribute the elements into sub-arrays"),
  2. sort every block locally with the existing OETS/bitonic row kernels —
     one pallas grid over all blocks of all rows at once,
  3. run ``nb`` alternating even/odd rounds of the cross-block merge kernel
     (``kernels/merge_kernel.py``) — odd-even transposition sort lifted from
     lanes to blocks, with compare-exchange generalised to merge-split.

Round r with parity p merges block pairs (2i+p, 2i+p+1); after ``nb`` rounds
the row is globally sorted (the 0-1 principle applied block-wise). Handles
1-D arrays of arbitrary length and (rows, cols) batches whose cols span many
VMEM blocks.

Every entry point is a view over one tuple-based core (``block_sort_lex``):
the kernels compare full lexicographic tuples (``kernels/lex.py``), so
key-only is the 1-tuple, key-value the 2-tuple, and multi-lane word keys any
wider tuple. ``repro.kernels.ops.sort``/``sort_lex`` pick this path
automatically beyond one block; ``block_size`` is the override knob.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.bitonic_kernel import bitonic_rows_lex_pallas
from ..kernels.merge_kernel import merge_adjacent_lex_pallas
from ..kernels.oets_kernel import oets_rows_lex_pallas
from ..kernels.ops import (_SUBLANES, _as_rows, _auto_interpret, _next_pow2,
                           _pad_cols)

__all__ = ["block_sort", "block_sort_kv", "block_sort_lex",
           "default_block_size"]

_MIN_BLOCK = 128          # one lane tile — smallest block the kernels accept
_DEFAULT_MIN_BLOCK = 512
# VMEM cap counts every ref the merge kernel holds: each is (8, 2B) x 4B.
# Key-only merge has 2 refs (in+out) -> 4 MiB at B=32Ki; every further array
# in the tuple (payload or extra key lane) adds 2 refs, halving the cap at
# each doubling: kv (4 refs) -> 4 MiB at B=16Ki. All leave headroom in a
# 16 MiB VMEM core for double buffering.
_MAX_BLOCK = 1 << 15
_TARGET_BLOCKS = 16       # merge rounds = num_blocks; keep that small


def default_block_size(n: int, kv: bool = False, n_arrays: int | None = None) -> int:
    """Cost-model block pick for an n-lane row.

    Per-element phase count is ~log^2(B) (local bitonic) + nb * log(2B)
    (merge rounds, nb = ceil(n/B)), so growing B trades a quadratic-log local
    term against linearly fewer rounds; the VMEM cap bounds B above — each
    array in the sorted tuple carries in+out refs, so the cap halves per
    pow2 tuple width (``kv=True`` is shorthand for ``n_arrays=2``). Aim for
    ~_TARGET_BLOCKS blocks, clamped to [512, 32Ki / pow2(n_arrays)] lanes."""
    t = n_arrays if n_arrays is not None else (2 if kv else 1)
    cap = max(_MIN_BLOCK, _MAX_BLOCK // _next_pow2(t))
    b = _next_pow2(max(1, -(-n // _TARGET_BLOCKS)))
    return max(_DEFAULT_MIN_BLOCK, min(cap, b))


def _validate_block(block_size, n, n_arrays):
    b = block_size or default_block_size(n, n_arrays=n_arrays)
    if b < _MIN_BLOCK or b & (b - 1):
        raise ValueError(
            f"block_size must be a power of two >= {_MIN_BLOCK}, got {b}")
    return b


def _pad_grid_rows(x):
    """Pad rows so the kernels' row grid tiles exactly; returns (padded, real).

    rows <= 8 runs as a single (rows,)-high block; beyond that the kernels
    tile 8 sublanes at a time, so rows must be a multiple of 8."""
    rows = x.shape[0]
    if rows <= _SUBLANES or rows % _SUBLANES == 0:
        return x, rows
    pad = (-rows) % _SUBLANES
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0), rows


def _merge_rounds(xs, nb, block, interpret):
    """nb alternating even/odd block-pair merge rounds over (rows, nb*block).

    ``xs`` is a tuple of lane/payload arrays; untouched edge blocks (the
    first block on odd rounds, the last on rounds with a dangling block) are
    carried through by concatenation around the merged span."""
    npad = nb * block
    for r in range(nb):
        parity = r % 2
        npairs = (nb - parity) // 2
        if npairs == 0:
            continue
        lo = parity * block
        hi = lo + npairs * 2 * block
        merged = merge_adjacent_lex_pallas(
            *(a[:, lo:hi] for a in xs), block=block, interpret=interpret)
        if lo == 0 and hi == npad:
            xs = merged
        else:
            xs = tuple(
                jnp.concatenate([a[:, :lo], m, a[:, hi:]], axis=1)
                for a, m in zip(xs, merged))
    return xs


@functools.partial(jax.jit, static_argnames=("block_size", "local_algorithm", "interpret"))
def _block_sort_tuple_2d(arrs, *, block_size, local_algorithm, interpret):
    """Tuple core: sort each row of same-shape 2-D ``arrs`` by lex compare."""
    rows, n = arrs[0].shape
    nb = -(-n // block_size)
    npad = nb * block_size
    # every array pads with its own dtype sentinel so the padding tuple is
    # the lex maximum under the kernels' full-tuple compare — it can never
    # displace a real payload even when real keys equal the key sentinel.
    arrs = [_pad_cols(a, npad) for a in arrs]

    # local phase: every block of every row is one kernel row
    loc = [a.reshape(rows * nb, block_size) for a in arrs]
    real = loc[0].shape[0]
    loc = [_pad_grid_rows(a)[0] for a in loc]
    fn = (bitonic_rows_lex_pallas if local_algorithm == "bitonic"
          else oets_rows_lex_pallas)
    arrs = [s[:real].reshape(rows, npad)
            for s in fn(*loc, interpret=interpret)]

    if nb > 1:
        padded = [_pad_grid_rows(a)[0] for a in arrs]
        real_rows = rows
        merged = _merge_rounds(tuple(padded), nb, block_size, interpret)
        arrs = [m[:real_rows] for m in merged]
    return tuple(a[:, :n] for a in arrs)


def block_sort_lex(arrs, *, block_size: int | None = None,
                   local_algorithm: str = "bitonic",
                   interpret: bool | None = None):
    """Sort a tuple of same-shape 1-D arrays or (rows, cols) batches as
    lexicographic tuples (lane 0 most significant; trailing arrays are
    payload/tie-break lanes). Returns the sorted tuple.

    ``block_size``: lanes per block (power of two >= 128); None = cost model
    (cap halves per pow2 tuple width — VMEM holds in+out refs per array).
    ``local_algorithm``: 'bitonic' (default) or 'oets' for the in-block sort.
    """
    if local_algorithm not in ("bitonic", "oets"):
        raise ValueError(f"unknown local algorithm {local_algorithm!r}")
    arrs = list(arrs)
    if not arrs:
        raise ValueError("need at least one array to sort")
    if any(a.shape != arrs[0].shape for a in arrs[1:]):
        raise ValueError("all lex arrays must have identical shapes")
    interpret = _auto_interpret(interpret)
    views = [_as_rows(a) for a in arrs]
    vec = views[0][1]
    arrs2 = [v[0] for v in views]
    if 0 in arrs2[0].shape:
        return tuple(arrs)
    b = _validate_block(block_size, arrs2[0].shape[1], len(arrs2))
    out = _block_sort_tuple_2d(tuple(arrs2), block_size=b,
                               local_algorithm=local_algorithm,
                               interpret=interpret)
    return tuple(o[0] for o in out) if vec else out


def block_sort(x, *, block_size: int | None = None,
               local_algorithm: str = "bitonic",
               interpret: bool | None = None):
    """Sort a 1-D array or each row of a (rows, cols) array ascending.

    ``block_size``: lanes per block (power of two >= 128); None = cost model.
    ``local_algorithm``: 'bitonic' (default) or 'oets' for the in-block sort.
    """
    (out,) = block_sort_lex((x,), block_size=block_size,
                            local_algorithm=local_algorithm,
                            interpret=interpret)
    return out


def block_sort_kv(keys, vals, *, block_size: int | None = None,
                  local_algorithm: str = "bitonic",
                  interpret: bool | None = None):
    """Key-value variant of :func:`block_sort`; ``vals`` rides the same
    permutation as the 2nd (tie-break) lex lane."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    return block_sort_lex((keys, vals), block_size=block_size,
                          local_algorithm=local_algorithm,
                          interpret=interpret)
