"""Device-level odd-even block sort: the paper's algorithm, recursed onto the mesh.

OpenMP's ``parallel for`` over buckets has no analogue across TPU pods — there
is no shared memory. But bubble sort itself generalizes: treat each device's
shard as one "element"; neighbouring devices compare-exchange (merge their
sorted blocks and split low/high halves) over the ICI ring via
``lax.ppermute``. P alternating odd/even rounds sort P blocks — this is
odd-even transposition sort at block granularity, i.e. *bubble sort across
the mesh*.

Merge strategies (the hillclimb axis recorded in EXPERIMENTS.md §Perf):
  * 'resort'  — jnp.sort the 2B concatenation (paper-faithful baseline:
                dumb local work, like re-running bubble sort)
  * 'bitonic' — O(log B) bitonic merge of the two sorted blocks
  * 'take'    — merge-path selection via searchsorted (O(B log B) gather)

Communication note: each round sends the full block both ways so the merge
is computed redundantly on both partners — this trades 2x ICI bytes for zero
additional latency-bound round trips, the right trade at 50 GB/s links when
blocks fit VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.compat import axis_size
from .bitonic import bitonic_merge

__all__ = ["local_merge", "odd_even_block_sort", "distributed_sort"]


def _merge_resort(mine, theirs):
    return jnp.sort(jnp.concatenate([mine, theirs], axis=0), axis=0)


def _merge_bitonic(mine, theirs):
    return bitonic_merge(mine, theirs)


def _merge_take(mine, theirs):
    # merge-path: position of each element in the merged output is its rank,
    # rank = own index + count of smaller elements in the other block.
    n = mine.shape[0]
    rank_mine = jnp.arange(n) + jnp.searchsorted(theirs, mine, side="left")
    rank_theirs = jnp.arange(n) + jnp.searchsorted(mine, theirs, side="right")
    out = jnp.zeros((2 * n,), mine.dtype)
    out = out.at[rank_mine].set(mine)
    out = out.at[rank_theirs].set(theirs)
    return out


_MERGES = {"resort": _merge_resort, "bitonic": _merge_bitonic, "take": _merge_take}


def local_merge(mine, theirs, strategy: str = "bitonic"):
    return _MERGES[strategy](mine, theirs)


def odd_even_block_sort(block, axis_name: str, merge: str = "bitonic",
                        local_sort=jnp.sort):
    """Sort values distributed along mesh axis ``axis_name``.

    To be called *inside* ``shard_map``. ``block``: this device's (B,) shard.
    Returns the sorted shard (globally ascending across the axis).
    """
    num = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    block = local_sort(block, axis=0) if local_sort is jnp.sort else local_sort(block)

    def round_body(r, blk):
        # round parity decides pairing: even r -> (0,1)(2,3)..; odd -> (1,2)(3,4)..
        left_of_pair = (me % 2) == (r % 2)
        partner = jnp.where(left_of_pair, me + 1, me - 1)
        has_partner = (partner >= 0) & (partner < num)

        # The pairing depends on the traced round index, so a static perm per
        # round is impossible; exchange with both ring neighbours and select.
        # from_left[j] = block of device j-1; from_right[j] = block of j+1.
        from_left = lax.ppermute(blk, axis_name, [(i, (i + 1) % num) for i in range(num)])
        from_right = lax.ppermute(blk, axis_name, [(i, (i - 1) % num) for i in range(num)])
        theirs = jnp.where(left_of_pair, from_right, from_left)

        merged = _MERGES[merge](blk, theirs)
        keep_low = left_of_pair
        bsz = blk.shape[0]
        low = lax.dynamic_slice_in_dim(merged, 0, bsz, axis=0)
        high = lax.dynamic_slice_in_dim(merged, bsz, bsz, axis=0)
        new = jnp.where(keep_low, low, high)
        return jnp.where(has_partner, new, blk)

    return lax.fori_loop(0, num, round_body, block)


def sample_sort(block, axis_name: str, capacity: int | None = None,
                oversample: int = 8):
    """Splitter-based distributed sort — the paper's *bucketing* idea at mesh
    scale, and the fix for odd-even block sort's O(P)-round scaling wall.

    One shot instead of P rounds: sample splitters globally (all_gather of
    local quantiles), partition every block by splitter bucket (exactly the
    paper's distribute-into-sub-arrays step, keyed by value range instead of
    word length), exchange with ONE all_to_all, sort locally.

    To be called inside ``shard_map``. Returns (values (P*capacity,), count)
    per device: outputs are sentinel-padded because bucket sizes vary —
    ``capacity`` bounds the per-source-per-destination bucket (default: the
    safe worst case B). Elements beyond capacity would be dropped; callers
    needing a hard guarantee keep the default.
    """
    num = axis_size(axis_name)
    b = block.shape[0]
    cap = capacity if capacity is not None else b
    sentinel = jnp.array(jnp.iinfo(block.dtype).max if
                         jnp.issubdtype(block.dtype, jnp.integer) else jnp.inf,
                         block.dtype)

    local = jnp.sort(block)
    # evenly spaced local quantiles -> global splitters
    stride = max(1, b // oversample)
    samples = local[::stride][:oversample]
    all_samples = jnp.sort(lax.all_gather(samples, axis_name).reshape(-1))
    take = [(i + 1) * oversample for i in range(num - 1)]
    splitters = all_samples[jnp.asarray(take, jnp.int32)] if take else all_samples[:0]

    # bucket by splitter (the paper's phase-2 distribution step)
    dest = jnp.searchsorted(splitters, local, side="right") if num > 1 else \
        jnp.zeros((b,), jnp.int32)
    # rank within destination bucket via stable order (local is sorted, so
    # same-destination elements are contiguous)
    counts = jnp.bincount(dest, length=num)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(b) - offsets[dest]
    keep = rank < cap
    slot = jnp.where(keep, dest * cap + rank, num * cap)
    buckets = jnp.full((num * cap + 1,), sentinel, block.dtype).at[slot].set(local)
    buckets = buckets[: num * cap].reshape(num, cap)

    received = lax.all_to_all(buckets, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    flat = received.reshape(-1)
    out = jnp.sort(flat)
    count = jnp.sum(out < sentinel) if jnp.issubdtype(block.dtype, jnp.integer) \
        else jnp.sum(jnp.isfinite(out))
    return out, count


def distributed_sort(x, mesh, axis: str = "data", merge: str = "bitonic"):
    """Sort a 1-D array sharded over ``axis`` of ``mesh``. Host-facing wrapper."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..parallel.compat import shard_map

    fn = shard_map(
        functools.partial(odd_even_block_sort, axis_name=axis, merge=merge),
        mesh=mesh,
        in_specs=P(axis),
        out_specs=P(axis),
    )
    num = mesh.shape[axis]
    if x.shape[0] % num:
        raise ValueError(f"size {x.shape[0]} not divisible by axis size {num}")
    return jax.jit(fn)(x)
