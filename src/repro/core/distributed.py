"""Mesh-scale distributed sort engines: the paper's distribute step across
devices, as a multi-engine subsystem.

OpenMP's ``parallel for`` over buckets has no analogue across TPU pods — there
is no shared memory. But the paper's decomposition generalizes two ways, and
this module ships both behind one front-end (``distributed_sort`` /
``distributed_sort_lex``), mirroring ``kernels.ops.sort``'s engine tiers:

  * ``'odd_even'`` — treat each device's shard as one "element"; neighbouring
    devices compare-exchange (merge their sorted blocks and split low/high
    halves) over the ICI ring via ``lax.ppermute``. P alternating odd/even
    rounds sort P blocks — odd-even transposition at block granularity,
    i.e. *bubble sort across the mesh*. O(P) rounds, O(P·B) bytes/device.
  * ``'sample'`` — splitter-based one-shot (sample sort, the MPI follow-up's
    design, arXiv:1411.5283): sample splitters globally (one ``all_gather``),
    partition every block by splitter bucket — exactly the paper's
    distribute-into-sub-arrays step keyed by value range instead of word
    length — exchange with ONE ``all_to_all``, sort locally. O(1) rounds,
    O(B) bytes/device, independent of P.

``choose_engine(P, B)`` is the cost model: odd_even only wins at P <= 2
(where its <= 2 merge rounds undercut the splitter machinery); sample wins
beyond because its round count does not grow with the mesh.

Both engines are variadic over lexicographic tuples (``kernels/lex.py``
conventions: lane 0 most significant, trailing lanes are payload/tie-break,
all lanes travel through one permutation), so key-only and kv sorting are
the 1-/2-tuple special cases. Device-local sorting routes through
``kernels.ops.sort_lex`` (the Pallas front-end) on TPU and XLA's variadic
sort on other backends (``local_sort='auto'``).

Exact-count exchange protocol (no silent data loss): alongside the data
``all_to_all``, the sample engine ``all_gather``s the *true* per-destination
count vectors (one tiny (P, P) matrix, replicated everywhere), so receivers
know exactly how many real elements arrived from each source — validity is
never inferred from sentinel comparisons (real
``iinfo.max`` ints and sentinel-bit floats count correctly), capacity overflow
is reported in an explicit flag instead of silently dropping, and the
host-facing wrappers always size capacity at the per-source worst case B so
nothing can overflow. Non-divisible inputs are sentinel-padded to the next
multiple of P and sliced back — no caller-visible shape constraint.

Merge strategies for the odd_even engine (the hillclimb axis recorded in
EXPERIMENTS.md §Perf), all full-tuple lex now:
  * 'resort'  — re-sort the 2B concatenation (paper-faithful baseline:
                dumb local work, like re-running bubble sort)
  * 'bitonic' — O(log B) bitonic merge of the two sorted blocks
  * 'take'    — merge-path selection via packed rank-key binary search
                (``kernels/keypack.py``: O(B log B) gathers + one scatter —
                the shared run-merge primitive of the pipeline tier)

Communication note: each odd_even round sends the full block both ways so
the merge is computed redundantly on both partners — this trades 2x ICI
bytes for zero additional latency-bound round trips, the right trade at
50 GB/s links when blocks fit VMEM.
"""

from __future__ import annotations

import functools
import logging
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels.keypack import (lex_searchsorted, merge_take_packed,
                               packed_searchsorted)
from ..kernels.ops import _sentinel
from ..parallel.compat import axis_size
from .bitonic import bitonic_merge, bitonic_merge_lex

__all__ = [
    "choose_engine", "local_merge",
    "odd_even_block_sort", "odd_even_block_sort_lex",
    "sample_sort", "sample_sort_lex", "sample_sort_exact", "SampleSortResult",
    "distributed_sort", "distributed_sort_kv", "distributed_sort_lex",
    "distributed_chunked_sort_lex",
]

log = logging.getLogger("repro.core")


# --------------------------------------------------------------------------
# local sort / merge building blocks
# --------------------------------------------------------------------------

def _local_sort_fn(local_sort):
    """Resolve the device-local tuple sort: 'pallas' (the unified
    ``kernels.ops.sort_lex`` front-end), 'xla' (XLA's variadic sort — the
    same full-tuple compare, compiled), 'auto' (pallas on TPU, where the
    kernels are the point; xla elsewhere, where pallas runs in interpret
    mode), or a callable ``lanes -> lanes``."""
    if callable(local_sort):
        return local_sort
    if local_sort == "auto":
        local_sort = "pallas" if jax.default_backend() == "tpu" else "xla"
    if local_sort == "pallas":
        from ..kernels.ops import sort_lex  # lazy: avoid import-time cycle
        return lambda lanes: list(sort_lex(list(lanes)))
    if local_sort == "xla":
        return lambda lanes: list(lax.sort(list(lanes), num_keys=len(lanes)))
    raise ValueError(f"unknown local_sort {local_sort!r}")


def _merge_resort_lex(mine, theirs, sort_fn):
    return sort_fn([jnp.concatenate([m, t]) for m, t in zip(mine, theirs)])


def _merge_bitonic_lex(mine, theirs, sort_fn):
    return bitonic_merge_lex(mine, theirs)


def _merge_take_lex(mine, theirs, sort_fn):
    # merge-path rank + scatter — the shared run-merge primitive
    # (kernels/keypack.merge_take_packed: packed rank-key binary search, the
    # same combine the pipeline tier uses on its chunked sorted runs), never
    # the O(B^2) lane-wise broadcast.
    return merge_take_packed(mine, theirs)


_MERGES_LEX = {"resort": _merge_resort_lex, "bitonic": _merge_bitonic_lex,
               "take": _merge_take_lex}


def _merge_sorted_rows_lex(rows):
    """Merge the rows of parallel (r, L) lane arrays — each row-tuple lex
    ascending, r a power of two — into one sorted lane tuple of (r*L,)
    arrays via a merge-path tree: log2(r) vmapped rounds of packed rank-key
    searchsorted + scatter (``kernels/keypack.py``), O(n log r) instead of a
    full O(n log n) re-sort. Any arity — key-only is the 1-lane case, and
    multi-lane tuples rank by binary search instead of the broadcast they
    used to need."""
    def mpair(a_rows, b_rows):
        return list(merge_take_packed(a_rows, b_rows))

    rows = list(rows)
    while rows[0].shape[0] > 1:
        rows = jax.vmap(mpair)([x[0::2] for x in rows],
                               [x[1::2] for x in rows])
    return [x[0] for x in rows]


def local_merge(mine, theirs, strategy: str = "bitonic"):
    """Merge two sorted key-only blocks (the 1-tuple view of the lex merge)."""
    if strategy == "bitonic":
        return bitonic_merge(mine, theirs)  # keeps the key-only fast path
    (out,) = _MERGES_LEX[strategy]([mine], [theirs],
                                   lambda ls: [jnp.sort(ls[0])])
    return out


# --------------------------------------------------------------------------
# engine 1: odd-even block sort (bubble sort across the mesh)
# --------------------------------------------------------------------------

def odd_even_block_sort_lex(lanes, axis_name: str, merge: str = "bitonic",
                            local_sort="auto"):
    """Sort lex tuples distributed along mesh axis ``axis_name``.

    To be called *inside* ``shard_map``. ``lanes``: list of this device's
    same-shape (B,) shards — key lanes first, payload/tie-break lanes last
    (``kernels/lex.py`` conventions). Returns the sorted lane tuple
    (globally ascending across the axis). ``merge``: 'resort' | 'bitonic'
    ('bitonic' needs pow2 B) | 'take'; ``local_sort``: see
    :func:`distributed_sort_lex`.
    """
    if merge not in _MERGES_LEX:
        raise ValueError(f"unknown merge strategy {merge!r}")
    lanes = list(lanes)
    num = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    sort_fn = _local_sort_fn(local_sort)
    lanes = sort_fn(lanes)
    bsz = lanes[0].shape[0]
    fwd = [(i, (i + 1) % num) for i in range(num)]
    bwd = [(i, (i - 1) % num) for i in range(num)]

    def round_body(r, lanes_t):
        blk = list(lanes_t)
        # round parity decides pairing: even r -> (0,1)(2,3)..; odd -> (1,2)(3,4)..
        left_of_pair = (me % 2) == (r % 2)
        partner = jnp.where(left_of_pair, me + 1, me - 1)
        has_partner = (partner >= 0) & (partner < num)

        # The pairing depends on the traced round index, so a static perm per
        # round is impossible; exchange with both ring neighbours and select.
        # from_left[j] = block of device j-1; from_right[j] = block of j+1.
        from_left = [lax.ppermute(a, axis_name, fwd) for a in blk]
        from_right = [lax.ppermute(a, axis_name, bwd) for a in blk]
        theirs = [jnp.where(left_of_pair, fr, fl)
                  for fl, fr in zip(from_left, from_right)]

        merged = _MERGES_LEX[merge](blk, theirs, sort_fn)
        new = [jnp.where(left_of_pair, m[:bsz], m[bsz:]) for m in merged]
        return tuple(jnp.where(has_partner, n_, a) for n_, a in zip(new, blk))

    return lax.fori_loop(0, num, round_body, tuple(lanes))


def odd_even_block_sort(block, axis_name: str, merge: str = "bitonic",
                        local_sort=jnp.sort):
    """Key-only odd-even block sort (the 1-tuple view). To be called inside
    ``shard_map``; ``block`` is this device's (B,) shard. ``local_sort``
    keeps its historical array->array signature (default ``jnp.sort``)."""
    if callable(local_sort):
        one = local_sort
        fn = lambda ls: [one(ls[0])]  # noqa: E731 — adapt array fn to lanes
    else:
        fn = local_sort
    (out,) = odd_even_block_sort_lex([block], axis_name, merge=merge,
                                     local_sort=fn)
    return out


# --------------------------------------------------------------------------
# engine 2: sample sort (splitter one-shot with exact-count exchange)
# --------------------------------------------------------------------------

class SampleSortResult(NamedTuple):
    """Per-device result of :func:`sample_sort_lex`.

    ``lanes``: tuple of (P*capacity,) sorted arrays — real elements occupy
    the prefix ``[0, count)``; slots beyond hold sentinel fill. ``count`` is
    exact (from the exchanged counts, never inferred from values).
    ``overflow`` is True iff some source had more than ``capacity`` elements
    destined for *this* device and the excess was clipped (each device flags
    its own inbound overflow — OR the flags across the axis for a global
    verdict) — impossible when capacity is the default worst case B."""

    lanes: Tuple[jax.Array, ...]
    count: jax.Array
    overflow: jax.Array


def _sample_partition_exchange(lanes, axis_name, n_valid, capacity,
                               oversample, local_sort):
    """Shared sample-sort core: local sort -> global splitters -> ONE
    all_to_all of data + one all_gather of the true count vectors. Returns
    ``(out_lanes, count_matrix, overflow, b, cap)``: ``out_lanes`` are this
    device's (P*cap,) arrays with the real elements sorted in the prefix,
    whose length is ``min(count_matrix[:, me], cap).sum()``;
    ``count_matrix[s, d]`` is the TRUE number of elements source s holds
    for destination d (pre-clip, replicated on every device)."""
    lanes = list(lanes)
    num = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    b = lanes[0].shape[0]
    cap = capacity if capacity is not None else b
    sort_fn = _local_sort_fn(local_sort)
    sentinels = [_sentinel(a.dtype) for a in lanes]

    # validity from construction, not from values: the host wrapper pads the
    # global tail, so device me's real elements are a prefix of its shard.
    if n_valid is None:
        local_valid = jnp.int32(b)
    else:
        local_valid = jnp.clip(n_valid - me * b, 0, b).astype(jnp.int32)

    # Invalid tail slots are overwritten with the all-sentinel tuple BEFORE
    # the sort: that tuple is lex-maximal under the full-tuple compare, so
    # fills sink to the tail and the first local_valid slots hold exactly
    # the real multiset (a real element equal to the fill in every lane is
    # interchangeable with it). Key-only sorting thus stays on the fast
    # single-operand path — no flag lane — while *counts* still come only
    # from the protocol, never from value comparisons.
    if n_valid is not None:
        idx = jnp.arange(b)
        lanes = [jnp.where(idx < local_valid, a, s)
                 for a, s in zip(lanes, sentinels)]
    local = sort_fn(lanes)
    vmask = jnp.arange(b) < local_valid

    # evenly spaced local quantiles -> global splitters (invalid samples are
    # masked to the lex-maximal sentinel tuple so they sort past every real
    # sample and never skew the low splitters)
    stride = max(1, b // oversample)
    pos = jnp.minimum(jnp.arange(oversample) * stride, b - 1)
    sample_ok = pos < local_valid
    samples = [jnp.where(sample_ok, a[pos], s) for a, s in zip(local, sentinels)]
    gathered = [lax.all_gather(s, axis_name).reshape(-1) for s in samples]
    all_samples = list(lax.sort(gathered, num_keys=len(gathered)))
    take = [(i + 1) * oversample for i in range(num - 1)]
    splitters = [s[jnp.asarray(take, jnp.int32)] for s in all_samples]

    # bucket by splitter (the paper's phase-2 distribution step):
    # dest = #splitters lex<= element — the packed rank-key binary search
    # (splitters are slices of the lex-sorted gathered samples, so they are
    # sorted tuples), the same rank primitive the run merges use
    if num > 1:
        dest = packed_searchsorted(splitters, local,
                                   side="right").astype(jnp.int32)
    else:
        dest = jnp.zeros((b,), jnp.int32)
    # rank within destination bucket via stable order (the valid prefix is
    # sorted, so same-destination elements are contiguous); invalid slots go
    # to the discard bucket ``num`` and never enter the counts.
    dest_eff = jnp.where(vmask, dest, num)
    counts = jnp.bincount(dest_eff, length=num + 1)[:num].astype(jnp.int32)
    offsets = jnp.cumsum(counts) - counts
    rank = jnp.arange(b) - offsets[jnp.minimum(dest_eff, num - 1)]
    keep = vmask & (rank < cap)
    slot = jnp.where(keep, dest * cap + rank, num * cap)
    buckets = [
        jnp.full((num * cap + 1,), s, a.dtype).at[slot].set(a)[: num * cap]
        .reshape(num, cap)
        for a, s in zip(local, sentinels)
    ]

    # ONE all_to_all for the data, plus ONE tiny all_gather for the TRUE
    # counts: every device learns the full (source, destination) count
    # matrix, so the validity mask comes from these counts — never from
    # comparing values against the sentinel — and the exact-placement step
    # can compute every device's global offset with no further collective.
    received = [lax.all_to_all(bk, axis_name, split_axis=0, concat_axis=0,
                               tiled=False) for bk in buckets]
    count_matrix = lax.all_gather(counts, axis_name)  # [src, dst] true counts
    recv_counts = count_matrix[:, me]
    overflow = jnp.any(recv_counts > cap)

    # Final combine: unfilled bucket slots already hold the all-sentinel
    # fill tuple by construction, so any order-preserving combine leaves the
    # real multiset in the count-sized prefix (same argument as the local
    # sort). Each received row is a slice of a sorted block, hence sorted —
    # pow2 row counts take a merge-path tree (log P rounds of packed
    # rank-key searchsorted gathers, any lane arity) instead of re-sorting
    # all P·cap elements; non-pow2 falls back to the full-tuple sort.
    if num & (num - 1) == 0:
        out = _merge_sorted_rows_lex(received)
    else:
        out = sort_fn([r.reshape(-1) for r in received])
    return out, count_matrix, overflow, b, cap


def sample_sort_lex(lanes, axis_name: str, n_valid: Optional[int] = None,
                    capacity: Optional[int] = None, oversample: int = 8,
                    local_sort="auto") -> SampleSortResult:
    """Splitter-based distributed lex sort — the paper's *bucketing* idea at
    mesh scale, and the fix for odd-even block sort's O(P)-round wall.

    To be called inside ``shard_map``. ``lanes``: list of this device's
    same-shape (B,) shards (key lanes first, payload last). ``n_valid``:
    global count of real elements when the caller padded the tail of the
    *last* shards (as :func:`distributed_sort_lex` does); None = all real.
    ``capacity`` bounds the per-source-per-destination bucket; the default B
    is the worst case, so no element can ever be dropped. Returns
    :class:`SampleSortResult` — the concatenation of every device's valid
    prefix (in axis order) is the globally sorted sequence.
    """
    me = lax.axis_index(axis_name)
    out, count_matrix, overflow, _, cap = _sample_partition_exchange(
        lanes, axis_name, n_valid, capacity, oversample, local_sort)
    count = jnp.sum(jnp.minimum(count_matrix[:, me], cap))
    return SampleSortResult(tuple(out), count, overflow)


def sample_sort_exact(lanes, axis_name: str, n_valid: Optional[int] = None,
                      capacity: Optional[int] = None, oversample: int = 8,
                      local_sort="auto"):
    """Sample sort returning *exactly placed* (B,) shards: a second
    ``all_to_all`` moves every element to the device and slot of its global
    rank, so the ``out_specs``-concatenated result is the globally sorted
    array with all padding at the tail — no host-side compaction (which
    XLA's partitioner would otherwise render as a storm of all-gathers).

    Global ranks come from the gathered count matrix (already on every
    device — no extra collective), never from values. Placement ships an
    explicit occupancy flag through the exchange, so receivers select real
    elements per slot without comparing against the sentinel. Returns
    ``(out_lanes, overflow, kept)``: ``overflow`` is this device's inbound
    overflow flag (OR across the axis for the global verdict); ``kept`` is
    the *global* number of elements that survived capacity clipping
    (``sum(min(count_matrix, capacity))``, replicated — equals the real
    element count whenever ``overflow`` is False everywhere). Unfilled
    slots (input padding) hold the lex-maximal sentinel tuple.
    """
    num = axis_size(axis_name)
    me = lax.axis_index(axis_name)
    out, count_matrix, overflow, b, cap = _sample_partition_exchange(
        lanes, axis_name, n_valid, capacity, oversample, local_sort)
    sentinels = [_sentinel(a.dtype) for a in out]
    m = out[0].shape[0]

    # my elements' global ranks: offset of my valid run + local index
    all_counts = jnp.sum(jnp.minimum(count_matrix, cap), axis=0)
    kept = jnp.sum(all_counts)
    cnt = all_counts[me]
    my_off = (jnp.cumsum(all_counts) - all_counts)[me]
    i = jnp.arange(m)
    pos = my_off + i
    valid = i < cnt
    # bucket row = destination device (pos // b), column = in-shard slot
    # (pos % b) — i.e. the flat bucket index IS the global rank
    slot = jnp.where(valid, pos, num * b)
    buckets = [
        jnp.full((num * b + 1,), s, a.dtype).at[slot].set(a)[: num * b]
        .reshape(num, b)
        for a, s in zip(out, sentinels)
    ]
    occupied = jnp.zeros((num * b + 1,), jnp.int32).at[slot].set(1)[: num * b] \
        .reshape(num, b)
    recv = [lax.all_to_all(bk, axis_name, split_axis=0, concat_axis=0,
                           tiled=False) for bk in buckets]
    rocc = lax.all_to_all(occupied, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)
    # global positions are unique, so each slot has at most one occupied
    # source; empty slots keep source 0's sentinel fill
    src = jnp.argmax(rocc, axis=0)
    cols = jnp.arange(b)
    return tuple(r[src, cols] for r in recv), overflow, kept


def sample_sort(block, axis_name: str, capacity: int | None = None,
                oversample: int = 8, local_sort="auto"):
    """Key-only sample sort (the 1-tuple view). Returns ``(values, count)``
    per device: ``values`` is (P*capacity,) with the real elements sorted in
    the prefix ``[0, count)``; ``count`` is exact even when real elements
    equal the padding sentinel (``iinfo.max`` / the all-ones-bits NaN —
    ``kernels.lex.sentinel_for``)."""
    res = sample_sort_lex([block], axis_name, capacity=capacity,
                          oversample=oversample, local_sort=local_sort)
    return res.lanes[0], res.count


# --------------------------------------------------------------------------
# engine selection + host-facing front-end
# --------------------------------------------------------------------------

def choose_engine(num_devices: int, block: int, engine: str = "auto") -> str:
    """Pick the mesh engine for P devices of B-element blocks — the
    ``kernels.ops.choose_plan`` cost model lifted to mesh granularity.

    odd_even moves O(P·B) bytes per device over P latency-bound rounds;
    sample moves O(B) bytes in one all_to_all plus an O(P·oversample)
    splitter all_gather. The splitter machinery only loses when the round
    count is already trivial: P <= 2 (<= 2 merge rounds). Beyond that the
    one-shot wins and keeps winning as P grows — block size scales both
    engines' local work equally, so the boundary is P-driven only. Explicit
    ``engine`` overrides."""
    if engine != "auto":
        if engine not in ("odd_even", "sample"):
            raise ValueError(f"unknown engine {engine!r}")
        return engine
    return "odd_even" if num_devices <= 2 else "sample"


def _pad_tail(a, npad):
    if a.shape[0] == npad:
        return a
    fill = jnp.full((npad - a.shape[0],), _sentinel(a.dtype), a.dtype)
    return jnp.concatenate([a, fill])


@functools.lru_cache(maxsize=128)
def _build_host_fn(mesh, axis, eng, merge, local_sort, oversample, n,
                   dtypes, capacity=None):
    """Jitted host function for one (mesh, config, shape) combination —
    cached so repeated calls (serving admission waves, benchmarks) reuse the
    compiled executable instead of re-tracing per call. Returns
    ``run(*padded) -> (data_lanes, overflow_flags, kept)``: for the sample
    engine ``overflow_flags`` is the (P,) per-device inbound overflow vector
    and ``kept`` the global surviving-element count (replicated); for
    odd_even — which has no capacity to overflow — both are ``None``."""
    from jax.sharding import PartitionSpec as P

    from ..parallel.compat import shard_map_norep

    spec_in = tuple([P(axis)] * len(dtypes))

    if eng == "odd_even":
        body = functools.partial(odd_even_block_sort_lex, axis_name=axis,
                                 merge=merge, local_sort=local_sort)
        fn = shard_map_norep(lambda *ls: body(list(ls)), mesh=mesh,
                             in_specs=spec_in, out_specs=spec_in)

        @jax.jit
        def run(*padded):
            # Sorted in place across the axis: padding tuples (all-sentinel,
            # hence lex-maximal) sort to the global tail, so the leading-n
            # slice is exact.
            return tuple(o[:n] for o in fn(*padded)), None, None
    else:
        def body(*ls):
            out, ovf, kept = sample_sort_exact(
                list(ls), axis_name=axis, n_valid=n, capacity=capacity,
                oversample=oversample, local_sort=local_sort)
            return (*out, ovf[None].astype(jnp.int32), kept[None])

        fn = shard_map_norep(body, mesh=mesh, in_specs=spec_in,
                             out_specs=spec_in + (P(axis), P(axis)))

        @jax.jit
        def run(*padded):
            # Exact rank placement puts every surviving element at its
            # global rank and sentinel-fills unassigned tail slots, so the
            # leading-n slice is exact whenever nothing overflowed.
            res = fn(*padded)
            return (tuple(o[:n] for o in res[:-2]), res[-2], res[-1])

    return run


def distributed_sort_lex(keys_lanes, mesh, axis: str = "data", vals=None,
                         engine: str = "auto", merge: str = "bitonic",
                         local_sort="auto", oversample: int = 8,
                         capacity: int | None = None,
                         on_overflow: str = "raise", validate: str = "off"):
    """Sort 1-D lex tuples sharded over ``axis`` of ``mesh``. Host-facing.

    ``keys_lanes``: sequence of same-shape 1-D arrays, lane 0 most
    significant; optional ``vals`` rides the keys' permutation as the final
    tie-break lane (``kernels.ops.sort_lex`` semantics). ``engine``: 'auto'
    (:func:`choose_engine`), 'odd_even', or 'sample'; ``merge`` applies to
    odd_even only. Any length: non-divisible inputs are sentinel-padded to
    the next multiple of the axis size and sliced back.

    ``capacity`` (sample engine only) bounds the per-source-per-destination
    exchange bucket; the default ``None`` sizes it at the worst-case block
    so zero elements can ever be dropped. A smaller explicit capacity
    shrinks the exchange tensor ``P * capacity``-fold but can overflow on
    skew; ``on_overflow`` is then the degrade policy:
      * ``'raise'`` — raise ``repro.runtime.CapacityOverflow``;
      * ``'retry'`` — double the capacity and re-run until the exchange
        fits (bounded: the worst-case block size always fits), logging each
        escalation — the supervisor-friendly lossless policy;
      * ``'clip'``  — return only the surviving elements (the output
        shortens to the exchanged count) with a warning log.

    ``validate``: ``'off'`` | ``'cheap'`` (host check that the output is
    lex-sorted and, on lossless paths, conserves the element count) |
    ``'full'`` (adds multiset conservation via the order-independent content
    digest of ``pipeline.validate``) — raises
    ``pipeline.validate.ValidationError`` on violation.

    Returns a tuple of sorted lanes, or ``(lanes, sorted_vals)`` when
    ``vals`` is given.
    """
    from ..runtime.failure import CapacityOverflow
    if on_overflow not in ("raise", "retry", "clip"):
        raise ValueError(f"unknown on_overflow policy {on_overflow!r}")
    arrs = list(keys_lanes) + ([vals] if vals is not None else [])
    if not arrs or any(a.ndim != 1 for a in arrs):
        raise ValueError("need 1-D lanes")
    if any(a.shape != arrs[0].shape for a in arrs[1:]):
        raise ValueError("all lanes (and vals) must have identical shapes")
    n = arrs[0].shape[0]
    num = mesh.shape[axis]
    b = -(-n // num) if n else 1
    npad = b * num
    eng = choose_engine(num, b, engine)
    if eng == "odd_even" and merge == "bitonic" and b & (b - 1):
        merge = "resort"  # bitonic merge needs pow2 blocks; stay exact
    dtypes = tuple(jnp.asarray(a).dtype for a in arrs)
    cap = capacity if eng == "sample" else None
    padded = [_pad_tail(a, npad) for a in arrs]
    clipped = False
    while True:
        if callable(local_sort):  # unhashable config: build uncached
            run = _build_host_fn.__wrapped__(mesh, axis, eng, merge,
                                             local_sort, oversample, n,
                                             dtypes, cap)
        else:
            run = _build_host_fn(mesh, axis, eng, merge, local_sort,
                                 oversample, n, dtypes, cap)
        out, ovf, kept = run(*padded)
        if ovf is None or cap is None or not bool(jnp.any(ovf)):
            break
        if on_overflow == "raise":
            # the exchange reports the flag, not the exact need: required
            # defaults to the always-sufficient worst-case block size
            raise CapacityOverflow(
                f"sample-sort exchange overflowed capacity {cap} "
                f"(block size {b} always fits)", cap, required=b)
        if on_overflow == "clip":
            kept_n = int(kept[0])
            log.warning("sample-sort exchange overflow: clipping %d "
                        "element(s) past capacity %d", n - kept_n, cap)
            out = tuple(o[:kept_n] for o in out)
            clipped = True
            break
        new_cap = min(cap * 2, b)
        log.warning("sample-sort exchange overflow: capacity %d -> %d "
                    "(retry)", cap, new_cap)
        cap = new_cap
    if validate != "off":
        from ..pipeline.validate import check_lanes_sorted, check_multiset
        check_lanes_sorted(out, what="distributed_sort_lex output")
        if not clipped:
            if out[0].shape[0] != n:
                from ..pipeline.validate import ValidationError
                raise ValidationError(
                    f"distributed_sort_lex lost elements: {out[0].shape[0]}"
                    f" != {n}")
            if validate == "full":
                check_multiset(arrs, out,
                               what="distributed_sort_lex multiset")
    if vals is None:
        return out
    return out[:-1], out[-1]


def distributed_sort(x, mesh, axis: str = "data", engine: str = "auto",
                     merge: str = "bitonic", local_sort="auto"):
    """Sort a 1-D array sharded over ``axis`` of ``mesh`` (key-only view of
    :func:`distributed_sort_lex`); any length, any engine."""
    (out,) = distributed_sort_lex((x,), mesh, axis=axis, engine=engine,
                                  merge=merge, local_sort=local_sort)
    return out


def distributed_sort_kv(keys, vals, mesh, axis: str = "data",
                        engine: str = "auto", merge: str = "bitonic",
                        local_sort="auto"):
    """Key-value view of :func:`distributed_sort_lex`: ``vals`` rides the
    keys' permutation as the final tie-break lane."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    lanes, ov = distributed_sort_lex((keys,), mesh, axis=axis, vals=vals,
                                     engine=engine, merge=merge,
                                     local_sort=local_sort)
    return lanes[0], ov


# --------------------------------------------------------------------------
# out-of-core: chunk-per-device ingest + run exchange + streaming combine
# --------------------------------------------------------------------------

def _chunk_devices(mesh, axis, devices):
    if devices is not None:
        return list(devices)
    if mesh is not None:
        # the mesh's devices in axis-major flat order (1-D meshes: the ring)
        return list(np.asarray(mesh.devices).reshape(-1))
    return list(jax.devices())


def _run_splitters(cmp_runs, num: int, oversample: int):
    """Global splitter tuples for a ``num``-way partition of k sorted runs:
    evenly spaced per-run quantile samples of the compare lanes, pooled and
    lex-sorted host-side (uint32 compare lanes — a few k*oversample rows),
    then ``num - 1`` evenly spaced picks. The splitters only steer *balance*;
    correctness never depends on them because the per-run boundaries are
    exact searchsorted positions."""
    samples = [[] for _ in cmp_runs[0]]
    for cmp_r in cmp_runs:
        n_r = int(cmp_r[0].shape[0])
        if n_r == 0:
            continue
        pos = np.minimum(np.arange(oversample) * max(1, n_r // oversample),
                         n_r - 1)
        for i, lane in enumerate(cmp_r):
            samples[i].append(np.asarray(lane)[pos])
    pooled = [np.concatenate(s) for s in samples]
    order = np.lexsort(tuple(reversed(pooled)))
    pooled = [p[order] for p in pooled]
    take = [(d + 1) * len(order) // num for d in range(num - 1)]
    return [jnp.asarray(p[take]) for p in pooled]


def distributed_chunked_sort_lex(keys, mesh=None, axis: str = "data",
                                 devices=None, algorithm: str = "pallas",
                                 capacity: int | None = None,
                                 store=None, supervisor=None,
                                 validate: str = "off",
                                 on_overflow: str = "raise",
                                 merge_engine: str = "auto",
                                 oversample: int = 8,
                                 shard_store=None,
                                 gather: bool | None = None):
    """Out-of-core mesh sort of packed shortlex words — the MPI follow-up's
    bucket->distribute->merge-across-ranks shape composed from the pipeline
    and kernel tiers, host-orchestrated over explicit device placement (so
    it runs identically on a TPU mesh and on fake CPU devices):

      1. **chunk-per-device ingest**: row-shard ``keys`` into one chunk per
         device, ``device_put`` each onto its device, and run the fused
         per-chunk bucketize + segmented-sort (``pipeline.ingest``'s
         ``_ingest_chunk`` — PR 6's ``RunStore`` resume, manifests, and
         ``on_overflow`` forward untouched) to get local ``SortedRun``s.
      2. **one exact-count sample-sort exchange of whole runs** (supervisor
         stage ``'run_exchange'``): splitters come from pooled per-run
         quantile samples; each run's destination boundaries are *exact*
         ``lex_searchsorted`` positions over its packed compare lanes, so
         destination d receives precisely its key range as k contiguous
         sorted sub-runs — counts derive from the boundaries, never from
         sentinel comparisons, and nothing can be silently lost.
      3. **streaming combine** (stage ``'streaming_combine'`` inside
         ``pipeline.merge.merge_runs``): each destination merges its k
         sub-runs in ONE k-way pass (``kernels/kway_kernel.py``); the
         concatenation of destinations in order is the global sort.

    ``keys``: packed (n, lanes) uint32 words, host or device. Devices come
    from ``devices`` (explicit list), else ``mesh``'s flat device order,
    else all local devices. ``capacity`` bounds each destination's combine
    input; ``on_overflow`` is then the degrade policy — 'raise'
    (``CapacityOverflow`` with the required size), 'retry' (double until it
    fits; always terminates at the worst-case destination count), or 'clip'
    (each overflowing destination keeps its ``capacity`` smallest elements,
    with a warning; conservation checks are skipped for the clipped
    output). ``validate``: 'off' | 'cheap' | 'full' — the PR 6 gate
    (``pipeline.validate.check_chunked``: per-run manifest reconciliation +
    count/histogram/sortedness conservation, 'full' adds content digests)
    applied across ingest, exchange, and combine end to end.

    **Sharded spill** (``shard_store``, a ``pipeline.shards.ShardStore``):
    each destination's merged output lands as an atomic disk shard the
    moment its combine completes — per-shard ``RunManifest`` (count,
    min/max key, additive digest) in the snapshot metadata, so (a) a killed
    job resumes at shard granularity (a stored shard whose count and summed
    sub-run digest match the re-exchanged destination *loads* instead of
    re-merging; torn or mismatched shards recompute), and (b) with
    ``validate != 'off'`` the ``check_sharded`` gate proves cross-shard
    boundary ordering + count/histogram(/digest) conservation from
    manifests alone, no rescan. ``gather`` controls the result form:
    ``True`` (default without a shard store) concatenates onto the default
    device and returns a ``SortedRun``; ``False`` (default *with* a shard
    store) skips the gather entirely — for results that don't fit the home
    device either — and returns the ``pipeline.shards.ShardedRun`` handle.

    When the ``supervisor`` carries a ``SpeculationPolicy``, each
    destination combine runs through ``run_speculative`` — a straggling
    merge gets a backup replica, first successful completion wins, the
    loser is discarded only after its output digest matches.

    Returns the globally sorted :class:`~repro.pipeline.ingest.SortedRun`,
    or a :class:`~repro.pipeline.shards.ShardedRun` when ``gather=False``.
    """
    from ..pipeline.ingest import SortedRun, _ingest_chunk
    from ..pipeline.merge import merge_runs
    from ..pipeline.validate import (ValidationError, check_chunked,
                                     check_lanes_sorted, check_run,
                                     check_sharded, multiset_digest)
    from ..runtime.failure import CapacityOverflow
    if on_overflow not in ("raise", "retry", "clip"):
        raise ValueError(f"unknown on_overflow policy {on_overflow!r}")
    if validate not in ("off", "cheap", "full"):
        raise ValueError("validate must be one of ('off', 'cheap', 'full')")
    if gather is None:
        gather = shard_store is None
    if not gather and shard_store is None:
        raise ValueError("gather=False requires a shard_store to spill to")
    devs = _chunk_devices(mesh, axis, devices)
    num = len(devs)
    if not isinstance(keys, jax.Array):
        keys = np.asarray(keys, dtype=np.uint32)
    n = int(keys.shape[0])
    if n == 0:
        if not gather:
            from ..pipeline.shards import ShardedRun
            return ShardedRun(store=shard_store, manifests=())
        return SortedRun(lengths=jnp.zeros((0,), jnp.int32),
                         keys=jnp.zeros(keys.shape, jnp.uint32))
    b = -(-n // num)

    # 1. chunk-per-device ingest (resume/manifests/overflow via the
    # pipeline's own chunk stage)
    runs, manifests = [], []
    for d, start in enumerate(range(0, n, b)):
        chunk = jax.device_put(keys[start:start + b], devs[d])
        run, man = _ingest_chunk(
            chunk, d, algorithm=algorithm, capacity=int(chunk.shape[0]),
            on_overflow=on_overflow, store=store, supervisor=supervisor,
            need_manifest=validate != "off")
        runs.append(run)
        manifests.append(man)

    lanes_rs = [r.lanes() for r in runs]
    cmp_rs = [r.cmp_lanes() for r in runs]

    # 2. exact-count exchange of whole sorted sub-runs
    def exchange(oversample):
        if num == 1 or len(runs) == 1:
            bnds = [jnp.asarray([0, int(r[0].shape[0])] + [int(
                r[0].shape[0])] * (num - 1), jnp.int32) for r in lanes_rs]
        else:
            splitters = _run_splitters(cmp_rs, num, oversample)
            bnds = []
            for cmp_r, r in zip(cmp_rs, lanes_rs):
                pos = lex_searchsorted(cmp_r, splitters, side="right")
                n_r = jnp.asarray([int(r[0].shape[0])], jnp.int32)
                bnds.append(jnp.concatenate(
                    [jnp.zeros((1,), jnp.int32),
                     pos.astype(jnp.int32), n_r]))
        bnds = [[int(x) for x in bnd] for bnd in bnds]
        per_dest = []
        for d in range(num):
            dev = devs[d % len(devs)]
            sub_lanes, sub_cmps = [], []
            for bnd, lanes, cmps in zip(bnds, lanes_rs, cmp_rs):
                lo, hi = bnd[d], bnd[d + 1]
                if hi <= lo:
                    continue
                sub_lanes.append(tuple(jax.device_put(x[lo:hi], dev)
                                       for x in lanes))
                sub_cmps.append(tuple(jax.device_put(c[lo:hi], dev)
                                      for c in cmps))
            per_dest.append((sub_lanes, sub_cmps))
        return per_dest

    while True:
        if supervisor is not None:
            per_dest = supervisor.run_stage("run_exchange", exchange,
                                            oversample)
        else:
            per_dest = exchange(oversample)
        incoming = [sum(int(s[0].shape[0]) for s in sub) if sub else 0
                    for sub, _ in per_dest]
        worst = max(incoming) if incoming else 0
        if capacity is None or worst <= capacity:
            clipped = False
            break
        if on_overflow == "raise":
            raise CapacityOverflow(
                f"run exchange: destination needs {worst} > capacity "
                f"{capacity}", capacity, required=worst)
        if on_overflow == "clip":
            clipped = True
            break
        # retry rebalances as well as grows: denser samples usually shrink
        # the worst destination, and the capacity doubling guarantees the
        # loop terminates even under unsplittable skew (duplicate keys)
        new_cap = min(capacity * 2, n)
        log.warning("run exchange overflow (worst destination %d): "
                    "capacity %d -> %d, oversample %d -> %d (retry)",
                    worst, capacity, new_cap, oversample, oversample * 2)
        capacity = new_cap
        oversample *= 2

    # 3. one streaming k-way combine per destination — each output spilled
    # as an atomic shard (when a shard_store is given) the moment it lands,
    # so a kill between destinations loses only the in-flight one
    from ..checkpoint.manager import CorruptSnapshotError
    from ..pipeline.ingest import _run_from_arrays
    from ..pipeline.manifest import RunManifest
    arity = len(lanes_rs[0])
    speculative = (supervisor is not None
                   and getattr(supervisor, "speculation", None) is not None)
    merged_dests = []        # (gather path) per-destination lane tuples
    shard_manifests = []     # (spill path) destination-ordered manifests
    for d, (sub_lanes, sub_cmps) in enumerate(per_dest):
        # expected shard identity from the exchange alone: incoming count +
        # summed sub-run key digest (additive, so the merged output's digest
        # equals the sum — no merge needed to know what "done" looks like)
        want_digest = None
        if shard_store is not None:
            want_digest = sum(multiset_digest(s[1:]) for s in sub_lanes) \
                % (1 << 64)

        merged = None
        if shard_store is not None:
            try:
                man_d = shard_store.manifest(d)
            except CorruptSnapshotError as e:
                log.warning("shard store: shard %d manifest unreadable "
                            "(%s) — recomputing", d, e)
                man_d = None
            if (man_d is not None and man_d.count == incoming[d]
                    and man_d.digest == want_digest):
                try:
                    loaded = _run_from_arrays(*shard_store.load(d))
                    if validate != "off":
                        check_run(loaded, man_d, mode=validate)
                    elif int(loaded.lengths.shape[0]) != man_d.count:
                        raise ValidationError(
                            f"shard {d}: loaded {int(loaded.lengths.shape[0])} "
                            f"row(s) but manifest records {man_d.count}")
                except (CorruptSnapshotError, ValidationError) as e:
                    log.warning("shard store: shard %d failed its load "
                                "gate (%s) — recomputing", d, e)
                    shard_store.drop(d)
                else:
                    merged = loaded.lanes()
                    shard_manifests.append(man_d)
            elif man_d is not None:
                log.warning("shard store: shard %d manifest does not match "
                            "the exchanged destination (stale or clipped "
                            "shard) — recomputing", d)

        if merged is None:
            if not sub_lanes:
                merged = (jnp.zeros((0,), jnp.int32),
                          *(jnp.zeros((0,), jnp.uint32)
                            for _ in range(arity - 1)))
            elif speculative:
                # the backup replica re-runs the same pure combine; the
                # inner merge skips its own stage probe so the speculative
                # wrapper owns the injector/retry bookkeeping
                merged = supervisor.run_speculative(
                    "streaming_combine",
                    lambda sl=sub_lanes, sc=sub_cmps: merge_runs(
                        sl, engine=merge_engine, cmp_runs=sc,
                        supervisor=None),
                    digest_of=lambda lanes: multiset_digest(list(lanes)))
            else:
                merged = merge_runs(sub_lanes, engine=merge_engine,
                                    cmp_runs=sub_cmps, supervisor=supervisor)
            if clipped and incoming[d] > capacity:
                log.warning("run exchange overflow: destination %d clipped "
                            "%d element(s) past capacity %d", d,
                            incoming[d] - capacity, capacity)
                merged = tuple(x[:capacity] for x in merged)
            if shard_store is not None:
                run_d = SortedRun.from_lanes(merged)
                man_d = RunManifest.from_run(run_d, d)
                shard_store.put(man_d, run_d)
                shard_manifests.append(man_d)
        merged_dests.append(merged)

    if shard_store is not None and validate != "off":
        if clipped:
            # conservation cannot hold for a clipped output; still prove
            # the shards concatenate in order (each is internally sorted —
            # its own merge or load gate proved that)
            occ = [m for m in shard_manifests if m.count]
            for a, b in zip(occ, occ[1:]):
                if tuple(a.max_key) > tuple(b.min_key):
                    raise ValidationError(
                        f"shard boundary disorder: shard {a.chunk_id} max "
                        f"key {a.max_key} > shard {b.chunk_id} min key "
                        f"{b.min_key}")
        else:
            check_sharded(manifests, shard_manifests, mode=validate)

    if not gather:
        from ..pipeline.shards import ShardedRun
        return ShardedRun(store=shard_store,
                          manifests=tuple(shard_manifests))

    # destinations live on their own devices; the host-facing result gathers
    # onto the default device (committed arrays never concatenate across)
    home = jax.devices()[0]
    occupied = [m for m in merged_dests if int(m[0].shape[0])]
    out = tuple(jnp.concatenate([jax.device_put(m[i], home)
                                 for m in occupied])
                for i in range(arity)) if occupied else tuple(
        jnp.zeros((0,), jnp.int32 if i == 0 else jnp.uint32)
        for i in range(arity))
    result = SortedRun.from_lanes(out)

    if validate != "off":
        if clipped:
            check_lanes_sorted(out, what="distributed_chunked output")
        else:
            check_chunked(runs, manifests, result, mode=validate)
    return result
