"""Fixed-width key packing — the paper's "approach 2" (3-D char array) insight.

The paper observes a 6.68x speedup from replacing ragged ``vector<string>``
with a dense fixed-width char array. On TPU the dense layout is not an
optimization but a *requirement*: there are no ragged tensors. We take the
idea to its conclusion and pack fixed-width byte strings into big-endian
``uint32`` lanes so that lexicographic byte order coincides with unsigned
integer order, making every comparison a single vector op instead of a
character loop.

A word of up to ``4 * n_lanes`` bytes becomes an ``(n_lanes,)`` uint32 row;
an array of n words is an ``(n, n_lanes)`` uint32 matrix (the paper's 3-D
array collapses to 2-D because the char dimension is packed into the integer
lanes). Padding bytes are 0, which sorts before every real character, so
prefixes order correctly ("ab" < "abc").
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "pack_words",
    "unpack_words",
    "byte_length",
    "lanes_for_width",
    "SENTINEL_U32",
]

# Sentinel larger than any real key lane; used to pad bucket slots so padded
# rows sink to the end of an ascending sort.
SENTINEL_U32 = np.uint32(0xFFFFFFFF)


def byte_length(word) -> int:
    """Encoded byte length of one word — THE length every layer buckets and
    sorts by (str encodes as UTF-8, bytes-likes count raw). One rule shared
    by packing, the host reference bucketizer, and the chunked ingress."""
    return len(word.encode("utf-8")) if isinstance(word, str) else len(bytes(word))


def lanes_for_width(width: int) -> int:
    """Number of uint32 lanes needed for ``width`` bytes."""
    return max(1, (width + 3) // 4)


def pack_words(words, width: int | None = None) -> np.ndarray:
    """Pack a list of byte/ASCII strings into an (n, lanes) uint32 matrix.

    Big-endian packing inside each lane and lane-major significance preserve
    lexicographic order: ``words[i] < words[j]`` (as byte strings) iff
    ``keys[i] < keys[j]`` compared lane-lexicographically.
    """
    encoded = [w.encode("utf-8") if isinstance(w, str) else bytes(w) for w in words]
    if width is None:
        width = max((len(w) for w in encoded), default=1)
    lanes = lanes_for_width(width)
    byte_width = lanes * 4
    n = len(encoded)
    buf = np.zeros((n, byte_width), dtype=np.uint8)
    for i, w in enumerate(encoded):
        if len(w) > byte_width:
            raise ValueError(f"word of {len(w)} bytes exceeds width {byte_width}")
        buf[i, : len(w)] = np.frombuffer(w, dtype=np.uint8)
    # big-endian: first byte is most significant
    as_u32 = buf.reshape(n, lanes, 4).astype(np.uint32)
    keys = (
        (as_u32[..., 0] << 24)
        | (as_u32[..., 1] << 16)
        | (as_u32[..., 2] << 8)
        | as_u32[..., 3]
    )
    return keys.astype(np.uint32)


def unpack_words(keys: np.ndarray) -> list:
    """Inverse of :func:`pack_words` (strips trailing zero padding)."""
    keys = np.asarray(keys, dtype=np.uint32)
    if keys.ndim == 1:
        keys = keys[:, None]
    n, lanes = keys.shape
    out = np.zeros((n, lanes, 4), dtype=np.uint8)
    out[..., 0] = (keys >> 24) & 0xFF
    out[..., 1] = (keys >> 16) & 0xFF
    out[..., 2] = (keys >> 8) & 0xFF
    out[..., 3] = keys & 0xFF
    flat = out.reshape(n, lanes * 4)
    words = []
    for row in flat:
        nz = np.nonzero(row)[0]
        end = int(nz[-1]) + 1 if nz.size else 0
        words.append(bytes(row[:end]).decode("utf-8", errors="replace"))
    return words
