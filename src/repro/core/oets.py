"""Odd-even transposition sort (OETS) — the parallel formulation of bubble sort.

The paper parallelizes bubble sort across length-buckets but keeps the
in-bucket sort a serial compare-swap chain. A serial chain has zero
parallelism on a TPU vector unit, so we use the textbook parallel-time
formulation of the same comparator network: n alternating phases, each doing
~n/2 *independent* neighbour compare-exchanges. Total comparisons remain
n(n-1)/2 — exactly the count the paper quotes — but each phase is one fused
vector op across all lanes.

All functions support multi-lane keys ``(n, L) uint32`` compared
lane-lexicographically (see ``core/packing.py``) as well as plain 1-D arrays
of any comparable dtype. Key-value variants carry a payload through the same
permutation (used by the MoE sort-based dispatch).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels.lex import order_view, sentinel_for

__all__ = [
    "lex_gt",
    "oets_sort",
    "oets_sort_kv",
    "oets_argsort",
]


def lex_gt(a: jax.Array, b: jax.Array) -> jax.Array:
    """Lane-lexicographic ``a > b`` under the canonical total order of
    ``kernels/lex.py`` (float lanes compare by order bits: NaN above
    ``+inf``, ``-0.0 == +0.0``).

    ``a``/``b``: (..., L) multi-lane keys or (...,) scalars. Returns bool (...).
    """
    if a.ndim == b.ndim and a.ndim >= 1 and a.shape[-1:] == b.shape[-1:] and _is_multilane(a):
        gt = jnp.zeros(a.shape[:-1], dtype=bool)
        eq = jnp.ones(a.shape[:-1], dtype=bool)
        for lane in range(a.shape[-1]):
            al, bl = a[..., lane], b[..., lane]
            gt = gt | (eq & (al > bl))
            eq = eq & (al == bl)
        return gt
    return order_view(a) > order_view(b)


def _is_multilane(x: jax.Array) -> bool:
    # Multi-lane keys are 2-D+ unsigned-int arrays whose trailing axis is lanes.
    return x.ndim >= 2 and jnp.issubdtype(x.dtype, jnp.unsignedinteger)


# the shared padding contract lives with the comparator (kernels/lex.py)
_sentinel = sentinel_for


def _compare_exchange(lo, hi, vlo=None, vhi=None):
    """One vectorized compare-exchange: returns (min, max) (+ payloads)."""
    swap = lex_gt(lo, hi)
    if lo.ndim > swap.ndim:  # broadcast over lane axis
        swap_k = swap[..., None]
    else:
        swap_k = swap
    new_lo = jnp.where(swap_k, hi, lo)
    new_hi = jnp.where(swap_k, lo, hi)
    if vlo is None:
        return new_lo, new_hi
    swap_v = swap.reshape(swap.shape + (1,) * (vlo.ndim - swap.ndim))
    new_vlo = jnp.where(swap_v, vhi, vlo)
    new_vhi = jnp.where(swap_v, vlo, vhi)
    return new_lo, new_hi, new_vlo, new_vhi


def _phase_even(keys, vals):
    """Pairs (0,1),(2,3),...  ``keys``: (n[, L]) with n even."""
    n = keys.shape[0]
    kp = keys.reshape((n // 2, 2) + keys.shape[1:])
    if vals is None:
        lo, hi = _compare_exchange(kp[:, 0], kp[:, 1])
        return jnp.stack([lo, hi], axis=1).reshape(keys.shape), None
    vp = vals.reshape((n // 2, 2) + vals.shape[1:])
    lo, hi, vlo, vhi = _compare_exchange(kp[:, 0], kp[:, 1], vp[:, 0], vp[:, 1])
    return (
        jnp.stack([lo, hi], axis=1).reshape(keys.shape),
        jnp.stack([vlo, vhi], axis=1).reshape(vals.shape),
    )


def _phase_odd(keys, vals):
    """Pairs (1,2),(3,4),...,(n-3,n-2); endpoints fixed. n even."""
    n = keys.shape[0]
    if n <= 2:
        return keys, vals
    mid_k, mid_v = _phase_even(keys[1 : n - 1], None if vals is None else vals[1 : n - 1])
    keys = jnp.concatenate([keys[:1], mid_k, keys[n - 1 :]], axis=0)
    if vals is None:
        return keys, None
    vals = jnp.concatenate([vals[:1], mid_v, vals[n - 1 :]], axis=0)
    return keys, vals


def _pad_even(keys, vals):
    n = keys.shape[0]
    if n % 2 == 0:
        return keys, vals, n
    pad_k = jnp.full((1,) + keys.shape[1:], _sentinel(keys.dtype), dtype=keys.dtype)
    keys = jnp.concatenate([keys, pad_k], axis=0)
    if vals is not None:
        pad_v = jnp.zeros((1,) + vals.shape[1:], dtype=vals.dtype)
        vals = jnp.concatenate([vals, pad_v], axis=0)
    return keys, vals, n


def _oets(keys, vals, num_phases=None):
    keys, vals, n_orig = _pad_even(keys, vals)
    n = keys.shape[0]
    if n_orig <= 1:
        return keys[:n_orig], None if vals is None else vals[:n_orig]
    # One loop iteration = one even + one odd phase. ceil(n/2) iterations
    # guarantee the full n phases of OETS (sorted for any input).
    iters = (n + 1) // 2 if num_phases is None else (num_phases + 1) // 2

    if vals is None:
        def body(_, k):
            k, _v = _phase_even(k, None)
            k, _v = _phase_odd(k, None)
            return k

        keys = lax.fori_loop(0, iters, body, keys)
        return keys[:n_orig], None

    def body_kv(_, kv):
        k, v = kv
        k, v = _phase_even(k, v)
        k, v = _phase_odd(k, v)
        return (k, v)

    keys, vals = lax.fori_loop(0, iters, body_kv, (keys, vals))
    return keys[:n_orig], vals[:n_orig]


def oets_sort(keys: jax.Array, num_phases: int | None = None) -> jax.Array:
    """Sort ascending along axis 0 via odd-even transposition.

    ``keys``: (n,) any comparable dtype, or (n, L) uint32 multi-lane keys.
    ``num_phases`` (optional) runs a truncated network (for partial sorting
    experiments); default n phases = fully sorted.
    """
    out, _ = _oets(keys, None, num_phases)
    return out


def oets_sort_kv(keys: jax.Array, vals: jax.Array, num_phases: int | None = None):
    """Sort ``keys`` ascending, carrying ``vals`` through the permutation."""
    if vals.shape[0] != keys.shape[0]:
        raise ValueError("keys/vals leading dims differ")
    return _oets(keys, vals, num_phases)


def oets_argsort(keys: jax.Array, num_phases: int | None = None) -> jax.Array:
    """Permutation indices that sort ``keys`` (stable only up to equal keys)."""
    idx = jnp.arange(keys.shape[0], dtype=jnp.int32)
    _, perm = _oets(keys, idx, num_phases)
    return perm
