"""Optimizer substrate (no external deps): AdamW with sharded/abstract state,
global-norm clipping, cosine schedule with warmup."""

from .adamw import AdamWConfig, init_opt_state, adamw_update, opt_state_axes
from .schedule import cosine_schedule
from .clip import clip_by_global_norm, global_norm

__all__ = [
    "AdamWConfig", "init_opt_state", "adamw_update", "opt_state_axes",
    "cosine_schedule", "clip_by_global_norm", "global_norm",
]
