"""AdamW with explicit pytree state.

Moment dtype is configurable (``optim_dtype``): bf16 moments halve optimizer
HBM — one of the distributed-memory tricks that lets the 405B config fit the
v5e pod (see EXPERIMENTS.md §Dry-run memory table). Moments inherit each
parameter's sharding (same logical axes), so FSDP shards optimizer state
exactly like ZeRO-3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "opt_state_axes"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def init_opt_state(params, moment_dtype=jnp.float32, abstract: bool = False):
    def zeros_like(p):
        if abstract or isinstance(p, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(p.shape, moment_dtype)
        return jnp.zeros(p.shape, moment_dtype)

    count = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
             else jnp.zeros((), jnp.int32))
    return {
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
        "count": count,
    }


def opt_state_axes(param_axes):
    """Moments shard like their parameters; count is replicated."""
    return {
        "m": param_axes,
        "v": param_axes,
        "count": (),
    }


def adamw_update(grads, state, params, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1.0 - cfg.b2)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
