"""Dependency-free checkpointing built for crash safety and elasticity.

Layout:   <dir>/step_<N>/manifest.json + <leaf>.npy
Atomicity: writes land in <dir>/.tmp_<N>, then one os.replace renames the
           complete snapshot into place — a crash mid-save can never corrupt
           the latest checkpoint.
Async:     save() optionally returns immediately; the writer thread is
           joined before the next save (single in-flight snapshot).
Elastic:   restore() takes an optional sharding pytree and device_puts every
           leaf with it — the snapshot written on a 512-chip mesh restores
           onto whatever mesh the surviving nodes can form.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "CorruptSnapshotError", "save", "restore",
           "latest_step", "read_manifest", "list_steps", "sweep_tmp"]

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^\.tmp_(\d+)$")


class CorruptSnapshotError(RuntimeError):
    """A snapshot file is unreadable — truncated, zero-length, or otherwise
    torn (a kill mid-write *after* the atomic rename can't produce this, but
    filesystem-level damage or external tampering can). Carries the path so
    a resuming job can log exactly which artifact to drop and recompute."""

    def __init__(self, path: str, reason: str):
        super().__init__(f"corrupt snapshot file {path}: {reason}")
        self.path = path
        self.reason = reason


def sweep_tmp(directory: str) -> list:
    """Remove leftover ``.tmp_<N>`` droppings (a job killed mid-save before
    its atomic rename). Returns the swept step numbers. Stores call this on
    open so half-written snapshots never accumulate and can never be
    mistaken for landed data."""
    if not os.path.isdir(directory):
        return []
    swept = []
    for d in os.listdir(directory):
        if (m := _TMP_RE.match(d)):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
            swept.append(int(m.group(1)))
    return sorted(swept)


def _load_npy(path: str) -> np.ndarray:
    """``np.load`` with torn-write detection: truncated or zero-length
    files raise :class:`CorruptSnapshotError` naming the path instead of a
    bare numpy/EOF exception."""
    try:
        if os.path.getsize(path) == 0:
            raise CorruptSnapshotError(path, "zero-length file")
        return np.load(path)
    except CorruptSnapshotError:
        raise
    except Exception as e:  # ValueError from a torn header, EOFError, OSError
        raise CorruptSnapshotError(path, f"unreadable npy ({e})") from e


def _leaf_names(tree):
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths_leaves:
        name = jax.tree_util.keystr(path)
        names.append(name.replace("/", "_").replace("'", "").strip("[]").replace("][", "."))
    if len(set(names)) != len(names):
        raise ValueError("non-unique leaf names in pytree")
    return names, [l for _, l in paths_leaves]


def save(directory: str, step: int, tree: Any, extra: Any = None) -> str:
    """Atomic synchronous snapshot. Returns the final path.

    ``extra``: optional JSON-serialisable metadata stored under the
    manifest's ``"extra"`` key — e.g. the sort pipeline's per-run invariants
    (``pipeline.manifest.RunManifest``), readable without loading any array
    via :func:`read_manifest`."""
    names, leaves = _leaf_names(tree)
    tmp = os.path.join(directory, f".tmp_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    if extra is not None:
        manifest["extra"] = extra
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{name}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory) if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def list_steps(directory: str) -> list:
    """All completed snapshot steps, ascending (resume discovery for stores
    that keep many live steps, e.g. one per sorted run)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(directory)
                  if (m := _STEP_RE.match(d)))


def read_manifest(directory: str, step: int) -> dict:
    """The snapshot's manifest (leaf specs + any ``extra`` metadata) without
    touching the arrays — how a resuming sort job decides which runs are
    already complete before loading anything."""
    path = os.path.join(directory, f"step_{step}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CorruptSnapshotError(path, f"unreadable manifest ({e})") from e


def restore(directory: str, step: int, target: Any, shardings: Any = None) -> Any:
    """Load a snapshot into the structure of ``target`` (a pytree of arrays
    or ShapeDtypeStructs). ``shardings`` (same structure) resharding-places
    every leaf — elastic restore onto a different mesh."""
    path = os.path.join(directory, f"step_{step}")
    manifest = read_manifest(directory, step)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    names, leaves = _leaf_names(target)
    out = []
    for name, leaf in zip(names, leaves):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        leaf_path = os.path.join(path, by_name[name]["file"])
        arr = _load_npy(leaf_path)
        if tuple(arr.shape) != tuple(by_name[name]["shape"]):
            # loadable but short/oversized vs what save() recorded: a torn
            # or externally damaged file, not a caller shape mistake
            raise CorruptSnapshotError(
                leaf_path, f"shape {tuple(arr.shape)} != manifest "
                f"{tuple(by_name[name]['shape'])}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != target {leaf.shape}")
        out.append(arr)
    treedef = jax.tree_util.tree_structure(target)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    else:
        restored = jax.tree.map(jax.numpy.asarray, restored)
    return restored


class CheckpointManager:
    """keep-N rotation + optional async writes + resume discovery."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.directory)
            if (m := _STEP_RE.match(d))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def save(self, step: int, tree: Any, extra: Any = None):
        self.wait()
        # materialize on host *before* returning so donated buffers are safe
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            save(self.directory, step, host_tree, extra=extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, target: Any, shardings: Any = None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, step, target, shardings)
