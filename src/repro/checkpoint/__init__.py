"""Fault-tolerant checkpointing: atomic manifest+npy snapshots, keep-N GC,
async save thread, reshard-on-restore for elastic recovery, and manifest
metadata readable without loading arrays (sorted-run resume discovery)."""

from .manager import (CheckpointManager, CorruptSnapshotError, latest_step,
                      list_steps, read_manifest, restore, save, sweep_tmp)

__all__ = ["CheckpointManager", "CorruptSnapshotError", "save", "restore",
           "latest_step", "list_steps", "read_manifest", "sweep_tmp"]
