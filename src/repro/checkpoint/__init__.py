"""Fault-tolerant checkpointing: atomic manifest+npy snapshots, keep-N GC,
async save thread, reshard-on-restore for elastic recovery, and manifest
metadata readable without loading arrays (sorted-run resume discovery)."""

from .manager import (CheckpointManager, latest_step, list_steps,
                      read_manifest, restore, save)

__all__ = ["CheckpointManager", "save", "restore", "latest_step",
           "list_steps", "read_manifest"]
