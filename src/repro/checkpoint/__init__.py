"""Fault-tolerant checkpointing: atomic manifest+npy snapshots, keep-N GC,
async save thread, reshard-on-restore for elastic recovery."""

from .manager import CheckpointManager, latest_step, restore, save

__all__ = ["CheckpointManager", "save", "restore", "latest_step"]
