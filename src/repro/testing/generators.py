"""Canonical adversarial input generators for the conformance matrix.

One named generator set, shared by every op contract, so "the engine
survives duplicates / sentinel collisions / NaN / skew / size edges" is
asserted once per (op, engine, mode, dtype) instead of re-invented per test
file. The set encodes every input class that has actually broken (or nearly
broken) an engine in this repo's history:

  * ``random``        — full-range draws (signed ints include negatives);
  * ``dup_heavy``     — a 4-value alphabet, so comparator ties dominate and
                        stability/tie-break handling is load-bearing;
  * ``sentinel``      — values colliding with the padding sentinel
                        (``iinfo.max`` / ``+inf``, plus ``iinfo.min`` /
                        ``-inf``): the exact class behind PR 3's
                        silent-data-loss fix;
  * ``nan``           — float32 NaN payloads with distinct bit patterns
                        (quiet/signalling, either sign, the all-ones
                        sentinel pattern) plus ``-0.0``/``+0.0`` mixes.
                        The contract is ``jnp.sort``-equivalent total order
                        (see ``kernels/ops.py``): NaNs sink to the tail,
                        the bit-level multiset is conserved exactly, and
                        the output is non-decreasing under the canonical
                        order bits — checked on *every* engine. (Building
                        the first matrix discovered the padded engines
                        losing elements under NaN; the total-order key
                        plane of ``kernels/lex.py`` fixed it, and
                        ``tests/test_conformance`` pins the regression);
  * ``skewed``        — heavy-tailed values / one dominant word length (the
                        capacity-pressure case of the bucket pipeline);
  * ``empty``         — n = 0 (no kernel launch; shape plumbing only);
  * ``singleton``     — n = 1 (maximal padding fraction);
  * ``tile_boundary`` — n = 129: one element past the 128-lane tile, the
                        boundary where the engine cost model switches tiers
                        (oets -> bitonic, 1 -> 2 blocksort blocks) and
                        interpret-mode padding doubles. For word inputs the
                        analogue is byte lengths straddling the 4-byte lane
                        boundaries (3/4/5 and 7/8).

Element generators fill 1-D arrays per dtype; word generators produce the
paper's variable-length words (as ``str``/``bytes``) for the distribute /
bucketize contracts. Sizes default to 96 so every 96/1-element case padded
to one 128-lane tile shares a single interpret-mode kernel compile per
(op, engine, dtype, mode) — the compile budget rule of ``tests/`` (keep
tier-1 widths <= 128; only ``tile_boundary`` deliberately crosses).
"""

from __future__ import annotations

import numpy as np

__all__ = ["ADVERSARIAL", "default_n", "check_mode", "applicable",
           "fill_elements", "make_words", "sorted_run_sizes",
           "kway_run_sizes"]

# the canonical generator set, in documentation order
ADVERSARIAL = ("random", "dup_heavy", "sentinel", "nan", "skewed",
               "empty", "singleton", "tile_boundary")

_DEFAULT_N = 96
_SIZES = {"empty": 0, "singleton": 1, "tile_boundary": 129}


def default_n(gen: str) -> int:
    """Element count of a generator's canonical case."""
    return _SIZES.get(gen, _DEFAULT_N)


def check_mode(gen: str) -> str:
    """'exact' (bit-identical to the oracle) or 'total_order' (bit-level
    multiset conserved AND non-decreasing under the canonical order bits —
    the ``jnp.sort``-equivalent NaN contract, where distinct NaN payloads
    tie so their relative order is unspecified)."""
    return "total_order" if gen == "nan" else "exact"


def applicable(gen: str, dtype) -> bool:
    """Whether a generator draws meaningful data for ``dtype`` (``nan`` is
    float-only; everything else applies everywhere)."""
    if gen == "nan":
        return np.issubdtype(np.dtype(dtype), np.floating)
    return True


def fill_elements(gen: str, rng: np.random.Generator, n: int,
                  dtype) -> np.ndarray:
    """Draw ``n`` elements of ``dtype`` for generator ``gen``."""
    dtype = np.dtype(dtype)
    is_float = np.issubdtype(dtype, np.floating)
    if n == 0:
        return np.zeros(0, dtype)
    if is_float:
        x = rng.normal(scale=10.0, size=n).astype(dtype)
        if gen == "dup_heavy":
            x = rng.choice(np.array([-1.5, -0.0, 0.0, 2.5], dtype), n)
        elif gen == "sentinel":
            x[rng.random(n) < 0.25] = np.inf
            x[rng.random(n) < 0.10] = -np.inf
        elif gen == "nan":
            x[rng.random(n) < 0.15] = np.nan
            # ±0.0 mixes: comparator-equal values with distinct bits
            x[rng.random(n) < 0.10] = dtype.type(-0.0)
            x[rng.random(n) < 0.10] = dtype.type(0.0)
            if dtype.itemsize == 4:
                # distinct NaN bit patterns: quiet/signalling, either sign,
                # and the all-ones padding-sentinel pattern itself
                pats = np.array([0x7FC00001, 0xFFC00000, 0x7F800001,
                                 0xFFFFFFFF], np.uint32).view(np.float32)
                mask = rng.random(n) < 0.10
                x[mask] = pats[rng.integers(0, len(pats), int(mask.sum()))]
        elif gen == "skewed":
            x = np.where(rng.random(n) < 0.9, dtype.type(0.5),
                         (rng.normal(size=n) * 1e6).astype(dtype))
        return x
    info = np.iinfo(dtype)
    if gen == "dup_heavy":
        return rng.integers(0, 4, n).astype(dtype)
    if gen == "sentinel":
        x = rng.integers(0, 100, n).astype(dtype)
        x[rng.random(n) < 0.25] = info.max
        x[rng.random(n) < 0.10] = info.min
        return x
    if gen == "skewed":
        small = rng.integers(0, 2, n)
        big = rng.integers(info.max // 2, info.max, n)
        return np.where(rng.random(n) < 0.9, small, big).astype(dtype)
    # random (and the size edges, which reuse the random fill)
    return rng.integers(info.min, info.max, n, endpoint=True).astype(dtype)


_ALPHABET = list("abcdefghijklmnop")


def _word(rng: np.random.Generator, length: int):
    return "".join(rng.choice(_ALPHABET, length))


def make_words(gen: str, rng: np.random.Generator,
               max_len: int = 8) -> list:
    """Draw the word list for a distribute/bucketize case. Lengths stay
    within ``max_len`` bytes (2 uint32 lanes at the default), the per-length
    bucket count the oracle reconstructs on host."""
    n = default_n(gen)
    if gen == "empty":
        return []
    if gen == "singleton":
        return ["q"]
    if gen == "dup_heavy":
        pool = [_word(rng, l) for l in (1, 3, max_len)]
        return [pool[i] for i in rng.integers(0, len(pool), n)]
    if gen == "sentinel":
        # raw 0xFF bytes pack to lanes equal to the uint32 padding sentinel
        words = [bytes([0xFF]) * int(l)
                 for l in rng.integers(1, max_len + 1, n // 2)]
        words += [_word(rng, int(l))
                  for l in rng.integers(1, max_len + 1, n - n // 2)]
        return [words[i] for i in rng.permutation(len(words))]
    if gen == "skewed":
        # one dominant length: the capacity-pressure / overflow-adjacent case
        lengths = np.where(rng.random(n) < 0.9, max_len - 1,
                           rng.integers(1, max_len + 1, n))
        return [_word(rng, int(l)) for l in lengths]
    if gen == "tile_boundary":
        # byte lengths straddling the 4-byte lane boundaries
        return [_word(rng, l) for l in (3, 4, 5, 7, 8) * 4]
    # random (and nan, which word contracts never register)
    return [_word(rng, int(l)) for l in rng.integers(1, max_len + 1, n)]


def sorted_run_sizes(gen: str) -> tuple[int, int]:
    """(|a|, |b|) for a two-run merge case: asymmetric for ``skewed``, one
    empty run for ``empty``, and straddling the merge block for
    ``tile_boundary``."""
    return {"empty": (0, _DEFAULT_N), "singleton": (1, 1),
            "skewed": (120, 8), "tile_boundary": (129, 100),
            }.get(gen, (_DEFAULT_N, 80))


def kway_run_sizes(gen: str) -> tuple:
    """Per-run sizes for a k-way merge case — always five runs (the
    contract's jitted runner is shape-polymorphic but arity-static), with
    ``empty_run`` interleaving zero-length runs among real ones (the static
    empty-drop path) and every size under the interpret-mode compile
    budget."""
    if gen == "empty_run":
        return (48, 0, 33, 0, 17)
    return (64, 48, 33, 16, 9)
