"""Conformance kit: the op-contract registry, adversarial generators, and
execution-mode axis behind ``tests/test_conformance.py`` — the single
tier-1 contract surface of the sort engine — plus the per-run provenance
that ``benchmarks/gate.py`` stamps into ``BENCH_kernels.json``.

The source paper's claim is empirical (one sort, measured across execution
configurations); this package is the apparatus that keeps every engine in
this repo *provably* equivalent across those configurations: each op in
``kernels.ops`` carries a NumPy oracle, a canonical adversarial input set,
and runs under every execution mode the host offers, bit-identical across
all of them.
"""

from .contracts import (CONTRACTS, Case, ConformanceRun, OpContract,
                        assert_conforms, iter_matrix, run_case)
from .generators import (ADVERSARIAL, applicable, check_mode, default_n,
                         fill_elements, make_words, sorted_run_sizes)
from .modes import ExecutionMode, available_modes, provenance

__all__ = [
    "CONTRACTS", "Case", "ConformanceRun", "OpContract", "assert_conforms",
    "iter_matrix", "run_case",
    "ADVERSARIAL", "applicable", "check_mode", "default_n", "fill_elements",
    "make_words", "sorted_run_sizes",
    "ExecutionMode", "available_modes", "provenance",
]
