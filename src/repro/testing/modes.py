"""The execution-mode axis of the conformance kit.

The source paper's strongest related work (arXiv 2109.01719) measures one
algorithm under four *modes of execution*; this module is our analogue for
the sort engine: every op contract runs under every mode available on the
host and the results must be bit-identical across them. A mode fixes two
independently meaningful knobs:

  * ``interpret`` — the Pallas lowering: ``True`` runs kernel bodies
    through the Pallas interpreter (unrolled into the XLA program — the
    only option on CPU), ``False`` lowers natively (Mosaic on TPU, Triton
    on GPU);
  * ``jit`` — dispatch granularity: ``False`` calls the op front-end
    eagerly (each jnp op dispatched separately around the kernel launches),
    ``True`` traces the whole op call into **one** compiled XLA program —
    the production configuration (``core.bucketing.sorted_packed`` is one
    such fused program), where XLA fusion rewrites the surrounding ops and
    trace-time Python branching in the front-ends must hold.

On CPU that yields ``interpret-cpu`` (eager) and ``compiled-cpu`` (one XLA
program; Pallas bodies still interpreter-unrolled — recorded honestly in
provenance as ``pallas='interpret'``). On TPU/GPU the compiled mode lowers
the kernels natively. ``available_modes()`` probes the running backend, so
the same test matrix exercises whichever pairs the host offers — at least
two everywhere.

Per-run provenance (``provenance(mode)``) extends
``kernels.ops.execution_provenance`` with the mode label, so conformance
results and benchmark records carry the same backend/mode/jax-version
fields and are only ever compared like-with-like (``benchmarks/gate.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

from ..kernels.ops import execution_provenance

__all__ = ["ExecutionMode", "available_modes", "provenance"]


@dataclass(frozen=True)
class ExecutionMode:
    """One point on the execution-mode axis.

    ``name`` — the stable label stamped into provenance;
    ``backend`` — the jax backend the mode requires;
    ``interpret`` — the Pallas ``interpret`` flag passed through the ops;
    ``jit`` — whether the contract wraps the whole op call in ``jax.jit``.
    """

    name: str
    backend: str
    interpret: bool
    jit: bool


def available_modes() -> tuple[ExecutionMode, ...]:
    """The execution modes this host can actually run, most-debuggable
    first. Always at least two: the eager interpreter mode and the
    single-program compiled mode for the running backend."""
    backend = jax.default_backend()
    modes = [ExecutionMode(f"interpret-{backend}", backend,
                           interpret=True, jit=False)]
    if backend in ("tpu", "gpu"):
        modes.append(ExecutionMode(f"compiled-{backend}", backend,
                                   interpret=False, jit=True))
    else:
        # CPU cannot lower Pallas natively ("Only interpret mode is
        # supported on CPU backend"), so compiled-cpu means: one jitted XLA
        # program with the kernel bodies interpreter-unrolled inside it.
        modes.append(ExecutionMode("compiled-cpu", backend,
                                   interpret=True, jit=True))
    return tuple(modes)


def provenance(mode: ExecutionMode) -> dict:
    """Backend/mode/jax-version provenance for one conformance run."""
    return execution_provenance(interpret=mode.interpret, mode=mode.name)
