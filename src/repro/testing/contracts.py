"""Op-contract registry: every public engine of ``kernels.ops`` bound to a
NumPy oracle, the canonical adversarial generator set, and the
execution-mode axis.

One :class:`OpContract` per front-end op —
``sort / sort_kv / sort_lex / segmented_sort / merge_sorted /
merge_sorted_lex / merge_runs / bucketize / distribute`` — declaring:

  * ``engines`` — every engine the op routes between (comparator
    algorithms for the sorts, merge engines for the merges, the capacity
    tiers for bucketize); the conformance matrix runs all of them, so "the
    cost model picked a different engine" can never hide a broken one;
  * ``generators`` × ``dtypes`` — which adversarial cases apply
    (``repro.testing.generators``), with per-generator dtype restriction so
    the sentinel case runs on every sentinel-colliding dtype while cheap
    structural edges don't multiply the interpret-mode compile budget;
  * ``build`` / ``oracle`` / ``check`` — deterministic case construction
    (CRC-seeded, stable across processes), the NumPy reference, and the
    conformance predicate: bit-identical by default; for the NaN cases the
    ``jnp.sort``-equivalent total-order contract — bit-level multiset
    conserved AND non-decreasing under the canonical order bits of
    ``kernels/lex.py`` (checked via ``pipeline.validate``'s numpy mirror,
    pinning the two layers to one definition of sorted); capacity-
    parametric for bucketize (the op picks its own autotuned capacity);
  * ``run`` — executes the op under an :class:`~repro.testing.modes.
    ExecutionMode`: the mode's Pallas ``interpret`` flag threads through,
    and ``jit`` modes trace the whole call into one cached compiled
    program (jitted callables are memoized module-wide — a fresh
    ``jax.jit`` per test would recompile every case).

``iter_matrix()`` expands the registry into (op, engine, mode, generator,
dtype) points — the single tier-1 contract surface
``tests/test_conformance.py`` parametrizes over. ``run_case`` returns the
outputs together with per-run provenance
(``kernels.ops.execution_provenance``), the same stamp
``benchmarks/gate.py`` requires on benchmark records.

Mode support is explicit, not silent: a combination an engine cannot honor
(e.g. the host-synced capacity-autotune retry tier under ``jit``) is
reported by ``supports()`` with a reason and surfaces as a *skip* in the
matrix, never as a quietly-identical re-run.
"""

from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.packing import byte_length, pack_words
from ..kernels import ops
from ..kernels.lex import sentinel_for
from ..pipeline.validate import (ValidationError, check_lanes_sorted,
                                 order_bits_view)
from ..pipeline.merge import merge_runs as _pipeline_merge_runs
from .generators import (applicable, check_mode, default_n, fill_elements,
                         kway_run_sizes, make_words, sorted_run_sizes)
from .modes import ExecutionMode, provenance

__all__ = ["Case", "OpContract", "ConformanceRun", "CONTRACTS",
           "iter_matrix", "run_case", "assert_conforms"]

# forced blocksort block so sub-block inputs still exercise the engine and
# tile_boundary (n=129) genuinely spans two blocks
_BLOCK = 128
_WORD_WIDTH = 8          # bytes -> 2 uint32 lanes, num_buckets = 9
_SEG_SHAPE = (6, 32, 2)  # (buckets, capacity, lanes) of the segmented case


def _seed(*parts) -> int:
    # stable across processes (hash() is PYTHONHASHSEED-randomized)
    return zlib.crc32("-".join(map(str, parts)).encode())


@dataclass(frozen=True)
class Case:
    """One conformance input: ``arrays`` feed the op, ``meta`` carries
    host-side context the oracle needs (word lengths, counts, capacity)."""

    op: str
    gen: str
    dtype: str
    arrays: tuple
    meta: dict = field(default_factory=dict)

    @property
    def check(self) -> str:
        return check_mode(self.gen)


class ConformanceRun(NamedTuple):
    """Outputs of one op execution plus the provenance it ran under."""

    outputs: tuple
    provenance: dict


@dataclass(frozen=True)
class OpContract:
    name: str
    engines: tuple
    generators: tuple
    dtypes_for: Callable[[str], tuple]
    build: Callable[[str, str], Case]
    run: Callable[[Case, str, ExecutionMode], tuple]
    oracle: Callable[[Case], tuple]
    # returns a skip reason, or None when the combination is runnable
    supports: Callable[[str, ExecutionMode, str], Optional[str]] = \
        lambda engine, mode, gen: None
    # override for ops whose conformance is not plain output==oracle
    check: Optional[Callable[[Case, tuple], None]] = None


# --- shared helpers ----------------------------------------------------------

_JIT_CACHE: dict = {}


def _maybe_jit(key, fn, jit: bool):
    """Memoized ``jax.jit`` wrapper: one traced callable per (op, engine,
    mode) so repeated cases share compile-cache entries."""
    if not jit:
        return fn
    cached = _JIT_CACHE.get(key)
    if cached is None:
        cached = _JIT_CACHE[key] = jax.jit(fn)
    return cached


def _np(outs):
    return tuple(np.asarray(o) for o in outs)


def _bits(a: np.ndarray) -> np.ndarray:
    """Bit-pattern view for order-insensitive multiset compares (NaN-safe)."""
    return a.view({4: np.uint32, 8: np.uint64, 2: np.uint16, 1: np.uint8}
                  [a.dtype.itemsize])


def _assert_permutation(got, want):
    """Outputs are a bit-level row-multiset permutation of the inputs
    (lanes compared as parallel tuples) — NaN payload bits and ``-0.0``
    signs must survive exactly."""
    g = np.stack([_bits(np.ascontiguousarray(a)) for a in got])
    w = np.stack([_bits(np.ascontiguousarray(a)) for a in want])
    if g.shape != w.shape:
        raise AssertionError(f"shape changed: {g.shape} != {w.shape}")
    if g.size:
        g = g[:, np.lexsort(g[::-1])]
        w = w[:, np.lexsort(w[::-1])]
    np.testing.assert_array_equal(g, w)


def _assert_total_order(got, want):
    """The ``jnp.sort``-equivalent NaN contract: outputs are a bit-level
    row-multiset permutation of the oracle reference AND lex non-decreasing
    under the canonical order bits (distinct NaN payloads tie, so only the
    multiset pins their bits). Sortedness runs through
    ``pipeline.validate.check_lanes_sorted`` — the production gate and the
    conformance matrix share one definition of "sorted"."""
    _assert_permutation(got, want)
    try:
        check_lanes_sorted(list(got), what="conformance output")
    except ValidationError as e:
        raise AssertionError(str(e)) from None


def assert_conforms(contract: OpContract, case: Case, outputs: tuple):
    """The conformance predicate: contract-custom check, total-order (NaN
    cases: multiset + canonical-order sortedness), or exact equality
    against the NumPy oracle."""
    if contract.check is not None:
        contract.check(case, outputs)
        return
    got = _np(outputs)
    want = _np(contract.oracle(case))
    assert len(got) == len(want)
    if case.check == "total_order":
        _assert_total_order(got, want)
        return
    for g, w in zip(got, want):
        assert g.dtype == w.dtype, f"dtype changed: {g.dtype} != {w.dtype}"
        np.testing.assert_array_equal(g, w)


def run_case(contract: OpContract, case: Case, engine: str,
             mode: ExecutionMode) -> ConformanceRun:
    """Execute one (case, engine, mode) cell and stamp its provenance."""
    outputs = contract.run(case, engine, mode)
    return ConformanceRun(outputs, provenance(mode))


# --- sort / sort_kv ----------------------------------------------------------

_SORT_ENGINES = ("oets", "bitonic", "blocksort")

# Every engine runs the nan generator now. Padded comparator networks used
# to lose elements under NaN (a NaN compares false both ways, so a padding
# sentinel could strand inside the sliced-back region — silent data loss);
# the canonical order bits of ``kernels/lex.py`` place every NaN *below*
# the all-ones padding sentinel, so the hazard is structurally gone.
# tests/test_conformance.py::test_nan_padding_hazard pins the regression.


def _sort_dtypes(gen: str) -> tuple:
    return {"random": ("int32", "float32"),
            "dup_heavy": ("int32", "float32"),
            "sentinel": ("int32", "uint32", "float32"),
            "nan": ("float32",)}.get(gen, ("int32",))


def _build_sort(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("sort", gen, dtype))
    x = fill_elements(gen, rng, default_n(gen), dtype)
    return Case("sort", gen, dtype, (x,))


def _run_sort(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    fn = _maybe_jit(("sort", engine, mode.name),
                    lambda x: ops.sort(x, algorithm=engine,
                                       block_size=_BLOCK if engine == "blocksort" else None,
                                       interpret=mode.interpret), mode.jit)
    return (fn(jnp.asarray(case.arrays[0])),)


def _oracle_sort(case: Case) -> tuple:
    return (np.sort(case.arrays[0]),) if case.check == "exact" \
        else (case.arrays[0],)


def _build_sort_kv(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("sort_kv", gen, dtype))
    n = default_n(gen)
    k = fill_elements(gen, rng, n, dtype)
    v = rng.permutation(n).astype(np.int32)
    return Case("sort_kv", gen, dtype, (k, v))


def _run_sort_kv(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    fn = _maybe_jit(("sort_kv", engine, mode.name),
                    lambda k, v: ops.sort_kv(k, v, algorithm=engine,
                                             block_size=_BLOCK if engine == "blocksort" else None,
                                             interpret=mode.interpret),
                    mode.jit)
    return fn(jnp.asarray(case.arrays[0]), jnp.asarray(case.arrays[1]))


def _oracle_sort_kv(case: Case) -> tuple:
    k, v = case.arrays
    if case.check != "exact":
        return k, v
    order = np.lexsort((v, k))  # vals are the engines' final tie-break lane
    return k[order], v[order]


# --- sort_lex ----------------------------------------------------------------

# 3-lane tuple with per-lane bounds totalling 2+32+16 = 50 bits: inside the
# 64-bit rank-key budget with fewer packed (2) than original (3) lanes, so
# engine='packed' is genuinely honored (a full-width 3-lane uint32 tuple
# would overflow the budget and silently fall back to 'lanes' — pinned by
# test_conformance's routing test). Lane 1 stays full-width so the sentinel
# generator still collides with 0xFFFFFFFF inside the packed path.
_LEX_MAX_VALUES = (3, None, 0xFFFF)


def _build_sort_lex(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("sort_lex", gen, dtype))
    n = default_n(gen)
    # tiny lane-0 alphabet so the deeper lanes actually decide the order
    lanes = (fill_elements("dup_heavy", rng, n, dtype),
             fill_elements(gen, rng, n, dtype),
             fill_elements(gen, rng, n, dtype) % np.uint32(0x10000))
    return Case("sort_lex", gen, dtype, tuple(lanes))


def _run_sort_lex(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    fn = _maybe_jit(("sort_lex", engine, mode.name),
                    lambda *lanes: ops.sort_lex(list(lanes), engine=engine,
                                                max_values=_LEX_MAX_VALUES,
                                                interpret=mode.interpret),
                    mode.jit)
    return tuple(fn(*[jnp.asarray(l) for l in case.arrays]))


def _lexsort_all(lanes):
    # lexsort over the canonical order-bit views (identity for integer
    # lanes), so float lanes sort NaN-correctly — np.lexsort on raw floats
    # would scatter NaN rows arbitrarily
    order = np.lexsort(tuple(reversed([order_bits_view(np.asarray(l))
                                       for l in lanes])))
    return tuple(np.asarray(l)[order] for l in lanes)


def _oracle_sort_lex(case: Case) -> tuple:
    return _lexsort_all(case.arrays)


# --- segmented_sort ----------------------------------------------------------

def _build_segmented(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("segmented", gen, dtype))
    nb, cap, lanes = _SEG_SHAPE
    if gen == "empty":
        nb, cap = 0, 0
    elif gen == "singleton":
        nb, cap = 1, 1
    elif gen == "tile_boundary":
        nb, cap = 2, 129
    keys = fill_elements("random" if gen in ("empty", "singleton",
                                             "tile_boundary") else gen,
                         rng, nb * cap * lanes, dtype).reshape(nb, cap, lanes)
    if gen == "skewed":
        counts = np.resize([0, cap, 1, cap - 1], nb).astype(np.int32)
    else:
        counts = rng.integers(0, cap + 1, nb).astype(np.int32)
    return Case("segmented_sort", gen, dtype, (keys, counts))


def _run_segmented(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    del engine  # single fused engine; width routes via choose_plan inside
    fn = _maybe_jit(("segmented_sort", mode.name),
                    lambda k, c: ops.segmented_sort(k, c,
                                                    interpret=mode.interpret),
                    mode.jit)
    return (fn(jnp.asarray(case.arrays[0]), jnp.asarray(case.arrays[1])),)


def _oracle_segmented(case: Case) -> tuple:
    keys, counts = case.arrays
    out = np.empty_like(keys)
    sent = sentinel_for(keys.dtype)
    for b in range(keys.shape[0]):
        rows = keys[b].copy()
        rows[counts[b]:] = sent  # the op masks slots >= count to sentinel
        order = np.lexsort(tuple(reversed([rows[:, l]
                                           for l in range(rows.shape[1])])))
        out[b] = rows[order]
    return (out,)


# --- merge_sorted / merge_sorted_lex ----------------------------------------

_MERGE_ENGINES = ("packed", "kernel", "lanes", "kway")


def _merge_dtypes(gen: str) -> tuple:
    return {"random": ("int32", "float32"),
            "sentinel": ("int32", "uint32"),
            "nan": ("float32",)}.get(gen, ("int32",))


def _ob_sort(x: np.ndarray) -> np.ndarray:
    """Stable sort under the canonical order bits — the only host-side sort
    that builds a *valid* merge input run out of NaN data (np.sort leaves
    the NaN tail in arbitrary payload order, which breaks the order-bit
    sortedness precondition when the all-ones sentinel pattern is among
    the payloads)."""
    return x[np.argsort(order_bits_view(x), kind="stable")]


def _build_merge(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("merge", gen, dtype))
    na, nb = sorted_run_sizes(gen)
    a = _ob_sort(fill_elements(gen, rng, na, dtype))
    b = _ob_sort(fill_elements(gen, rng, nb, dtype))
    return Case("merge_sorted", gen, dtype, (a, b))


def _run_merge(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    fn = _maybe_jit(("merge_sorted", engine, mode.name),
                    lambda a, b: ops.merge_sorted(a, b, engine=engine,
                                                  interpret=mode.interpret),
                    mode.jit)
    return (fn(jnp.asarray(case.arrays[0]), jnp.asarray(case.arrays[1])),)


def _oracle_merge(case: Case) -> tuple:
    # _ob_sort, not np.sort: numpy's vectorised float sort canonicalises
    # NaN payloads and -0.0 signs (observed on numpy 2.0), which would
    # corrupt the very bit multiset the NaN contract checks
    return (_ob_sort(np.concatenate(case.arrays)),)


def _build_merge_lex(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("merge_lex", gen, dtype))
    na, nb = sorted_run_sizes(gen)

    def run(n):
        lanes = [fill_elements("dup_heavy", rng, n, dtype),
                 fill_elements(gen, rng, n, dtype),
                 np.arange(n, dtype=np.int32)]  # payload = final tie-break
        return _lexsort_all(lanes)  # runs must be sorted by the full tuple

    return Case("merge_sorted_lex", gen, dtype, (run(na), run(nb)))


def _run_merge_lex(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    a_lanes, b_lanes = case.arrays
    n_arr = len(a_lanes)
    fn = _maybe_jit(("merge_sorted_lex", engine, mode.name),
                    lambda *arrs: tuple(ops.merge_sorted_lex(
                        arrs[:n_arr], arrs[n_arr:], engine=engine,
                        interpret=mode.interpret)), mode.jit)
    return tuple(fn(*[jnp.asarray(x) for x in a_lanes + b_lanes]))


def _oracle_merge_lex(case: Case) -> tuple:
    a_lanes, b_lanes = case.arrays
    return _lexsort_all([np.concatenate([a, b])
                         for a, b in zip(a_lanes, b_lanes)])


# --- merge_runs (one-launch streaming k-way vs the tournament oracle) --------

# 'kway' = the streaming front-end as routed off-TPU (one global-rank
# scatter); 'kway_kernel' forces the Pallas streaming kernel under the
# interpreter (block 128 so the case genuinely spans blocks and exercises
# the double-buffered segment DMA); 'tournament' = the legacy pairwise tree
# kept as the differential oracle.
_KWAY_ENGINES = ("kway", "kway_kernel", "tournament")


def _build_merge_runs(gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed("merge_runs", gen, dtype))
    data_gen = "random" if gen == "empty_run" else gen

    def run(n):
        lanes = [fill_elements("dup_heavy", rng, n, dtype),
                 fill_elements(data_gen, rng, n, dtype),
                 np.arange(n, dtype=np.int32)]  # payload = final tie-break
        return _lexsort_all(lanes)  # runs must be sorted by the full tuple

    return Case("merge_runs", gen, dtype,
                tuple(run(n) for n in kway_run_sizes(gen)))


def _run_merge_runs(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    n_arr = len(case.arrays[0])
    k = len(case.arrays)

    def call(*arrs):
        runs = [arrs[i * n_arr:(i + 1) * n_arr] for i in range(k)]
        return tuple(_pipeline_merge_runs(runs, engine=engine,
                                          interpret=mode.interpret,
                                          block_size=_BLOCK))

    fn = _maybe_jit(("merge_runs", engine, mode.name), call, mode.jit)
    return tuple(fn(*[jnp.asarray(x) for r in case.arrays for x in r]))


def _oracle_merge_runs(case: Case) -> tuple:
    return _lexsort_all([np.concatenate([r[i] for r in case.arrays])
                         for i in range(len(case.arrays[0]))])


# --- distribute / bucketize --------------------------------------------------

def _build_words(op: str, gen: str, dtype: str) -> Case:
    rng = np.random.default_rng(_seed(op, gen, dtype))
    words = make_words(gen, rng, max_len=_WORD_WIDTH)
    keys = pack_words(words, width=_WORD_WIDTH)
    lengths = np.array([byte_length(w) for w in words], np.int32)
    num_buckets = 4 * keys.shape[1] + 1
    # the stable-rank oracle: arrival order within each length bucket
    rank = np.zeros(len(words), np.int32)
    seen: dict = {}
    for i, l in enumerate(lengths):
        rank[i] = seen.get(int(l), 0)
        seen[int(l)] = rank[i] + 1
    counts = np.bincount(lengths, minlength=num_buckets).astype(np.int32) \
        if len(words) else np.zeros(num_buckets, np.int32)
    return Case(op, gen, dtype, (keys,),
                meta={"lengths": lengths, "rank": rank, "counts": counts,
                      "num_buckets": num_buckets})


def _run_distribute(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    del engine
    fn = _maybe_jit(("distribute", mode.name),
                    lambda k: ops.distribute(k, interpret=mode.interpret),
                    mode.jit)
    return tuple(fn(jnp.asarray(case.arrays[0])))


def _oracle_distribute(case: Case) -> tuple:
    return (case.meta["lengths"], case.meta["rank"], case.meta["counts"])


def _expected_buckets(case: Case, capacity: int) -> np.ndarray:
    """The bucket tensor at an arbitrary capacity (the op autotunes its
    own): word i lands at [dest, rank] when rank < capacity, sentinel
    elsewhere — the documented clip semantics of ``scatter_to_buckets``."""
    keys = case.arrays[0]
    nb = case.meta["num_buckets"]
    out = np.full((nb, capacity, keys.shape[1]), np.uint32(0xFFFFFFFF),
                  np.uint32)
    for i in range(keys.shape[0]):
        r = case.meta["rank"][i]
        if r < capacity:
            out[case.meta["lengths"][i], r] = keys[i]
    return out


def _run_bucketize(case: Case, engine: str, mode: ExecutionMode) -> tuple:
    keys = jnp.asarray(case.arrays[0])
    nb = case.meta["num_buckets"]
    counts = case.meta["counts"]
    cap = int(counts.max()) if counts.size and counts.max() else 0
    if not mode.jit:
        res = ops.bucketize(keys,
                            capacity=None if engine == "autotune" else cap,
                            interpret=mode.interpret)
        assert res.dropped == 0
        return res.buckets, res.counts
    # compiled mode: the traceable tier — distribute + one static-capacity
    # scatter in a single program (exactly what core.bucketing.sorted_packed
    # fuses). autotune's compiled tier is the optimistic first-shot
    # capacity; its host-synced exact-count retry is eager-only by design.
    if engine == "autotune":
        cap = ops._optimistic_capacity(int(keys.shape[0]), nb) \
            if keys.shape[0] else 0

    def program(k):
        dest, rank, cnt = ops.distribute(k, interpret=mode.interpret)
        return ops.scatter_to_buckets(k, dest, rank, num_buckets=nb,
                                      capacity=cap), cnt

    fn = _maybe_jit(("bucketize", engine, mode.name, cap), program, True)
    return tuple(fn(keys))


def _check_bucketize(case: Case, outputs: tuple):
    buckets, counts = _np(outputs[:2])
    capacity = buckets.shape[1]
    np.testing.assert_array_equal(buckets,
                                  _expected_buckets(case, capacity))
    np.testing.assert_array_equal(counts, case.meta["counts"])


# --- registry ----------------------------------------------------------------

def _const_dtypes(*dts):
    return lambda gen: dts


_NO_NAN = tuple(g for g in ("random", "dup_heavy", "sentinel", "skewed",
                            "empty", "singleton", "tile_boundary"))
_WORD_GENS = _NO_NAN  # word cases: nan is meaningless for packed bytes

CONTRACTS: dict = {}


def _register(c: OpContract):
    CONTRACTS[c.name] = c


_register(OpContract(
    name="sort", engines=_SORT_ENGINES,
    generators=("random", "dup_heavy", "sentinel", "nan", "skewed",
                "empty", "singleton", "tile_boundary"),
    dtypes_for=_sort_dtypes, build=_build_sort, run=_run_sort,
    oracle=_oracle_sort))

_register(OpContract(
    name="sort_kv", engines=_SORT_ENGINES,
    generators=("random", "dup_heavy", "sentinel", "nan", "singleton"),
    dtypes_for=lambda gen: ("float32",) if gen == "nan" else ("int32",),
    build=_build_sort_kv, run=_run_sort_kv, oracle=_oracle_sort_kv))

_register(OpContract(
    name="sort_lex", engines=("lanes", "packed"),
    generators=_NO_NAN,
    dtypes_for=_const_dtypes("uint32"),
    build=_build_sort_lex, run=_run_sort_lex, oracle=_oracle_sort_lex))

_register(OpContract(
    name="segmented_sort", engines=("fused",),
    generators=_NO_NAN,
    dtypes_for=_const_dtypes("uint32"),
    build=_build_segmented, run=_run_segmented, oracle=_oracle_segmented))

_register(OpContract(
    name="merge_sorted", engines=_MERGE_ENGINES,
    generators=("random", "dup_heavy", "sentinel", "nan", "skewed",
                "empty", "singleton", "tile_boundary"),
    dtypes_for=_merge_dtypes, build=_build_merge, run=_run_merge,
    oracle=_oracle_merge))

_register(OpContract(
    name="merge_sorted_lex", engines=_MERGE_ENGINES,
    generators=("random", "dup_heavy", "sentinel", "nan", "skewed",
                "empty", "singleton", "tile_boundary"),
    dtypes_for=lambda gen: ("float32",) if gen == "nan" else ("uint32",),
    build=_build_merge_lex, run=_run_merge_lex, oracle=_oracle_merge_lex))

_register(OpContract(
    name="merge_runs", engines=_KWAY_ENGINES,
    generators=("random", "dup_heavy", "sentinel", "nan", "empty_run"),
    dtypes_for=lambda gen: ("float32",) if gen == "nan" else ("uint32",),
    build=_build_merge_runs, run=_run_merge_runs,
    oracle=_oracle_merge_runs))

_register(OpContract(
    name="distribute", engines=("kernel",),
    generators=_WORD_GENS,
    dtypes_for=_const_dtypes("uint32"),
    build=functools.partial(_build_words, "distribute"),
    run=_run_distribute, oracle=_oracle_distribute))

_register(OpContract(
    name="bucketize", engines=("autotune", "explicit"),
    generators=_WORD_GENS,
    dtypes_for=_const_dtypes("uint32"),
    build=functools.partial(_build_words, "bucketize"),
    run=_run_bucketize, oracle=lambda case: (),
    check=_check_bucketize))


def iter_matrix(modes) -> list:
    """Expand the registry into (op, engine, mode, generator, dtype) cells —
    the parametrization of ``tests/test_conformance.py``. Applies the
    per-generator dtype restriction and dtype applicability; per-(engine,
    mode) support is resolved at run time (skip-with-reason, never silent).
    """
    cells = []
    for contract in CONTRACTS.values():
        for engine in contract.engines:
            for mode in modes:
                for gen in contract.generators:
                    for dtype in contract.dtypes_for(gen):
                        if applicable(gen, dtype):
                            cells.append((contract.name, engine, mode,
                                          gen, dtype))
    return cells
