"""Cluster runtime concerns, testable on one host: elastic failure recovery,
straggler detection, simulated failure injection, and the sort pipeline's
stage-level fault supervision (``sortfault``)."""

from .failure import (CapacityOverflow, DeviceFailure, ElasticSupervisor,
                      FailureInjector)
from .straggler import StragglerMonitor

__all__ = ["DeviceFailure", "CapacityOverflow", "ElasticSupervisor",
           "FailureInjector", "StragglerMonitor",
           "StageFailure", "StageFailureInjector", "RetryPolicy",
           "StageEvent", "SortSupervisor"]

# ``sortfault``'s supervisor drives the device pipeline, but the module
# itself is dependency-light; expose it lazily (PEP 562, the
# ``repro.pipeline`` idiom) so ``kernels``/``core`` can import the failure
# types above without re-entering this package mid-initialisation.
_LAZY = {"StageFailure": "sortfault", "StageFailureInjector": "sortfault",
         "RetryPolicy": "sortfault", "StageEvent": "sortfault",
         "SortSupervisor": "sortfault"}


def __getattr__(name):
    if name in _LAZY:
        from importlib import import_module
        return getattr(import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
