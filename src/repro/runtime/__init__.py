"""Cluster runtime concerns, testable on one host: elastic failure recovery,
straggler detection, and simulated failure injection."""

from .failure import DeviceFailure, ElasticSupervisor, FailureInjector
from .straggler import StragglerMonitor

__all__ = ["DeviceFailure", "ElasticSupervisor", "FailureInjector", "StragglerMonitor"]
