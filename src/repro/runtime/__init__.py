"""Cluster runtime concerns, testable on one host: elastic failure recovery,
straggler detection, simulated failure injection, and the sort pipeline's
stage-level fault supervision (``sortfault``)."""

from .failure import (CapacityOverflow, DeviceFailure, ElasticSupervisor,
                      FailureInjector)
from .straggler import StragglerMonitor

__all__ = ["DeviceFailure", "CapacityOverflow", "ElasticSupervisor",
           "FailureInjector", "StragglerMonitor",
           "StageFailure", "StageTimeout", "ProcessKilled",
           "SpeculationMismatch", "StageFailureInjector", "RetryPolicy",
           "StageEvent", "SpeculationPolicy", "SortSupervisor",
           "ChaosPlan", "make_plan", "apply_damages", "chaos_soak",
           "SoakReport"]

# ``sortfault``'s supervisor drives the device pipeline, but the module
# itself is dependency-light; expose it lazily (PEP 562, the
# ``repro.pipeline`` idiom) so ``kernels``/``core`` can import the failure
# types above without re-entering this package mid-initialisation. ``chaos``
# additionally imports the pipeline/device stack, so it must stay lazy.
_LAZY = {"StageFailure": "sortfault", "StageTimeout": "sortfault",
         "ProcessKilled": "sortfault", "SpeculationMismatch": "sortfault",
         "StageFailureInjector": "sortfault", "RetryPolicy": "sortfault",
         "StageEvent": "sortfault", "SpeculationPolicy": "sortfault",
         "SortSupervisor": "sortfault",
         "ChaosPlan": "chaos", "make_plan": "chaos",
         "apply_damages": "chaos", "chaos_soak": "chaos",
         "SoakReport": "chaos"}


def __getattr__(name):
    if name in _LAZY:
        from importlib import import_module
        return getattr(import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
