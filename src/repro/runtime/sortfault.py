"""Stage-level fault injection + supervised recovery for the sort engine.

``runtime/failure.py`` models whole-job elasticity for the *training* loop
(checkpoint/restart on ``DeviceFailure``). The sort pipeline fails at finer
granularity — one chunk launch, one collective exchange, one merge round —
and each stage has a cheaper recovery than a full restart:

  stage             injected fault        recovery
  ----------------- --------------------- ----------------------------------
  ingest_chunk      StageFailure          re-launch the chunk (backoff retry)
  merge_round       StageFailure          re-run the round (rounds are pure)
  run_exchange      StageFailure          re-run the whole-run exchange (the
                                          boundary split and slicing are
                                          pure functions of the runs)
  streaming_combine StageFailure          re-run the one-launch k-way merge
                                          (pure function of its input runs)
  exchange          DeviceFailure         shrink mesh, re-run the sample
                                          sort on the survivors
  exchange          CapacityOverflow      double the exchange capacity and
                                          retry (never drop elements)

:class:`StageFailureInjector` produces those faults deterministically (by
stage name + occurrence index, each fires exactly once), so tests can kill
the pipeline mid-flight and assert the recovered output is bit-identical to
the no-failure oracle. :class:`SortSupervisor` is the recovery driver:
bounded exponential-backoff retry for transient stage failures,
``ElasticSupervisor``-style mesh shrink for device loss, and capacity
doubling for overflow. Every recovery is recorded in ``events`` for
observability and test bookkeeping.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

from .failure import CapacityOverflow, DeviceFailure

__all__ = ["KNOWN_STAGES", "StageFailure", "StageFailureInjector",
           "RetryPolicy", "StageEvent", "SortSupervisor"]

log = logging.getLogger("repro.runtime")

# The stage names the engine runs through SortSupervisor.run_stage — the
# valid keys for StageFailureInjector schedules (run_stage itself is generic
# over names; this tuple documents the wired surface and lets tests catch a
# schedule keyed on a stage that no longer exists).
KNOWN_STAGES = ("ingest_chunk", "merge_round", "run_exchange",
                "streaming_combine", "exchange")


class StageFailure(RuntimeError):
    """A transient failure of one pipeline stage execution (a failed kernel
    launch, a lost RPC) — retryable in place, unlike :class:`DeviceFailure`
    which requires a mesh rebuild."""

    def __init__(self, stage: str, occurrence: int, msg: str | None = None):
        super().__init__(msg or f"injected {stage} failure "
                                f"(occurrence {occurrence})")
        self.stage = stage
        self.occurrence = occurrence


class StageFailureInjector:
    """Deterministic per-stage failure schedule.

    ``fail_at``: mapping ``stage -> iterable of occurrence indices`` that
    raise :class:`StageFailure` (transient — a supervisor retries in place).
    ``device_fail_at``: same shape, raising :class:`DeviceFailure` with
    ``failed_devices`` lost (a supervisor shrinks the mesh). ``check(stage)``
    counts every call per stage; each scheduled fault fires exactly once, so
    the retry of a failed occurrence succeeds — mirroring
    ``runtime.failure.FailureInjector``'s fire-once contract at stage
    granularity.
    """

    def __init__(self, fail_at=None, device_fail_at=None,
                 failed_devices: int = 1):
        self.fail_at = {s: set(ix) for s, ix in (fail_at or {}).items()}
        self.device_fail_at = {s: set(ix)
                               for s, ix in (device_fail_at or {}).items()}
        self.failed_devices = failed_devices
        self.occurrences: dict[str, int] = {}
        self.fired: list[tuple[str, int, str]] = []

    def check(self, stage: str):
        idx = self.occurrences.get(stage, 0)
        self.occurrences[stage] = idx + 1
        if idx in self.device_fail_at.get(stage, ()):
            self.device_fail_at[stage].discard(idx)
            self.fired.append((stage, idx, "device"))
            raise DeviceFailure(
                f"injected device failure in {stage} (occurrence {idx})",
                self.failed_devices)
        if idx in self.fail_at.get(stage, ()):
            self.fail_at[stage].discard(idx)
            self.fired.append((stage, idx, "transient"))
            raise StageFailure(stage, idx)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient stage failures. The default
    base of 0 keeps tests instant; production callers set e.g.
    ``RetryPolicy(max_retries=5, backoff_base=0.5)`` for 0.5/1/2/4/8 s."""

    max_retries: int = 3
    backoff_base: float = 0.0
    backoff_factor: float = 2.0

    def delay(self, attempt: int) -> float:
        return self.backoff_base * (self.backoff_factor ** (attempt - 1))


@dataclasses.dataclass
class StageEvent:
    """One recovery action, for observability and test assertions."""

    stage: str
    attempt: int
    action: str    # 'retry' | 'remesh' | 'capacity_double'
    detail: str


class SortSupervisor:
    """Recovery driver for the sort pipeline's stages.

    ``run_stage`` wraps one stage callable with the injector probe and the
    transient-retry policy; ``run_with_capacity`` escalates overflow into
    capacity doubling; ``run_distributed`` adds the mesh-shrink path for
    device loss during the sample-sort exchange. Pass the supervisor to
    ``pipeline.ingest.chunked_sort_*`` (which routes chunk launches and
    merge rounds through ``run_stage``) or call ``run_distributed`` around
    ``core.distributed``.
    """

    def __init__(self, policy: RetryPolicy = RetryPolicy(),
                 injector: Optional[StageFailureInjector] = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.policy = policy
        self.injector = injector
        self.events: list[StageEvent] = []
        self._sleep = sleep

    # -------------------------------------------------- transient retries

    def run_stage(self, stage: str, fn: Callable, *args, **kwargs):
        """Execute ``fn(*args, **kwargs)`` with the injector probe and
        bounded backoff retry on :class:`StageFailure`. ``DeviceFailure``
        and :class:`CapacityOverflow` are *not* retried here — they need a
        different recovery (remesh / bigger capacity) and propagate to the
        caller (``run_distributed`` / ``run_with_capacity``)."""
        attempt = 0
        while True:
            try:
                if self.injector is not None:
                    self.injector.check(stage)
                return fn(*args, **kwargs)
            except StageFailure as e:
                attempt += 1
                if attempt > self.policy.max_retries:
                    raise
                delay = self.policy.delay(attempt)
                log.warning("stage %s failed (attempt %d/%d): %s — retrying"
                            " in %.3gs", stage, attempt,
                            self.policy.max_retries, e, delay)
                self.events.append(StageEvent(stage, attempt, "retry", str(e)))
                if delay:
                    self._sleep(delay)

    # -------------------------------------------------- overflow escalation

    def run_with_capacity(self, stage: str, fn: Callable, capacity: int,
                          max_doublings: int = 8):
        """Run ``fn(capacity)`` (through the stage retry machinery),
        doubling ``capacity`` on :class:`CapacityOverflow` — the degrade
        policy that converges instead of dropping elements. When the
        overflow reports its true requirement, jump straight there."""
        for _ in range(max_doublings + 1):
            try:
                return self.run_stage(stage, fn, capacity)
            except CapacityOverflow as e:
                new_cap = max(capacity * 2, e.required or 0)
                log.warning("stage %s overflowed capacity %d — retrying at "
                            "%d", stage, capacity, new_cap)
                self.events.append(StageEvent(
                    stage, 0, "capacity_double",
                    f"capacity {capacity} -> {new_cap}"))
                capacity = new_cap
        raise CapacityOverflow(
            f"stage {stage} still overflowing after {max_doublings} "
            f"doublings", capacity)

    # -------------------------------------------------- mesh-shrink re-run

    def run_distributed(self, make_mesh: Callable[[int], object],
                        devices: int, run: Callable, *,
                        min_devices: int = 1, max_recoveries: int = 8):
        """Execute ``run(mesh)`` — typically a closure over
        ``core.distributed.distributed_sort_lex`` — rebuilding a smaller
        mesh on ``DeviceFailure`` (the ``ElasticSupervisor`` control flow,
        minus the checkpoint: a sort's input is its own checkpoint, so lost
        chunks simply re-execute on the survivors). The injector's
        ``exchange`` stage probes each dispatch."""
        recoveries = 0
        while True:
            try:
                if self.injector is not None:
                    self.injector.check("exchange")
                return run(make_mesh(devices))
            except DeviceFailure as e:
                recoveries += 1
                if recoveries > max_recoveries:
                    raise RuntimeError("exceeded max recoveries") from e
                survivors = devices - e.failed_devices
                if survivors < min_devices:
                    raise RuntimeError(
                        f"insufficient surviving devices: {survivors} < "
                        f"min_devices={min_devices}") from e
                log.warning("device failure during exchange: %d -> %d "
                            "devices — re-running on survivors",
                            devices, survivors)
                self.events.append(StageEvent(
                    "exchange", recoveries, "remesh",
                    f"{devices} -> {survivors} devices"))
                devices = survivors
