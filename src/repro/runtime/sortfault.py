"""Stage-level fault injection + supervised recovery for the sort engine.

``runtime/failure.py`` models whole-job elasticity for the *training* loop
(checkpoint/restart on ``DeviceFailure``). The sort pipeline fails at finer
granularity — one chunk launch, one collective exchange, one merge round —
and each stage has a cheaper recovery than a full restart:

  stage             injected fault        recovery
  ----------------- --------------------- ----------------------------------
  ingest_chunk      StageFailure          re-launch the chunk (backoff retry)
  merge_round       StageFailure          re-run the round (rounds are pure)
  run_exchange      StageFailure          re-run the whole-run exchange (the
                                          boundary split and slicing are
                                          pure functions of the runs)
  streaming_combine StageFailure          re-run the one-launch k-way merge
                                          (pure function of its input runs)
  any stage         StageTimeout          the stage exceeded its wall-clock
                                          deadline — abandon the launch and
                                          re-run (same retry budget as a
                                          transient failure)
  any stage         ProcessKilled         NOT recoverable in-process: the
                                          simulated SIGKILL propagates; a
                                          fresh invocation resumes from the
                                          durable stores
  exchange          DeviceFailure         shrink mesh, re-run the sample
                                          sort on the survivors
  exchange          CapacityOverflow      double the exchange capacity and
                                          retry (never drop elements)

:class:`StageFailureInjector` produces those faults deterministically (by
stage name + occurrence index, each fires exactly once), so tests can kill
the pipeline mid-flight and assert the recovered output is bit-identical to
the no-failure oracle. :class:`SortSupervisor` is the recovery driver:
bounded exponential-backoff retry (with optional seeded full jitter, so
simultaneous per-destination retries decollide deterministically) for
transient stage failures, per-stage wall-clock **deadlines** (a stage that
hangs becomes a retryable :class:`StageTimeout` instead of a stuck job),
**speculative re-execution** for straggling combine stages
(:class:`SpeculationPolicy` over ``runtime.straggler.StragglerMonitor`` —
first successful completion wins, the loser is discarded only after its
output digest matches), ``ElasticSupervisor``-style mesh shrink for device
loss, and capacity doubling for overflow. Every recovery is recorded in
``events`` for observability and test bookkeeping.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import logging
import time
import zlib
from typing import Callable, Optional

from .failure import CapacityOverflow, DeviceFailure

__all__ = ["KNOWN_STAGES", "StageFailure", "StageTimeout", "ProcessKilled",
           "SpeculationMismatch", "StageFailureInjector", "RetryPolicy",
           "StageEvent", "SpeculationPolicy", "SortSupervisor"]

log = logging.getLogger("repro.runtime")

# The stage names the engine runs through SortSupervisor.run_stage — the
# valid keys for StageFailureInjector schedules (run_stage itself is generic
# over names; this tuple documents the wired surface and lets tests catch a
# schedule keyed on a stage that no longer exists).
KNOWN_STAGES = ("ingest_chunk", "merge_round", "run_exchange",
                "streaming_combine", "exchange")


class StageFailure(RuntimeError):
    """A transient failure of one pipeline stage execution (a failed kernel
    launch, a lost RPC) — retryable in place, unlike :class:`DeviceFailure`
    which requires a mesh rebuild."""

    def __init__(self, stage: str, occurrence: int, msg: str | None = None):
        super().__init__(msg or f"injected {stage} failure "
                                f"(occurrence {occurrence})")
        self.stage = stage
        self.occurrence = occurrence


class StageTimeout(StageFailure):
    """A stage exceeded its wall-clock deadline. Subclasses
    :class:`StageFailure` because the recovery is the same — abandon the
    launch and re-run the (pure) stage under the bounded retry budget —
    while the type lets tests and operators distinguish a hang from a
    crash."""

    def __init__(self, stage: str, deadline: float, occurrence: int = -1,
                 msg: str | None = None):
        super().__init__(stage, occurrence,
                         msg or f"stage {stage} exceeded its "
                                f"{deadline:.3g}s deadline")
        self.deadline = deadline


class ProcessKilled(RuntimeError):
    """Simulated SIGKILL at a stage boundary — deliberately NOT a
    :class:`StageFailure`: no in-process recovery exists for a dead
    process, so the supervisor must not retry it. The 'job' dies holding
    only what it durably persisted; chaos tests raise this mid-pipeline and
    then prove a fresh invocation resumes bit-identically from the
    stores."""

    def __init__(self, stage: str, occurrence: int):
        super().__init__(f"process killed at {stage} "
                         f"(occurrence {occurrence})")
        self.stage = stage
        self.occurrence = occurrence


class SpeculationMismatch(RuntimeError):
    """Speculative re-execution produced a different output digest than the
    primary — the stage is supposed to be a pure function of its inputs, so
    disagreement means silent corruption on one path. Never swallowed: the
    job must fail loudly rather than pick a winner arbitrarily."""

    def __init__(self, stage: str, d_primary: int, d_backup: int):
        super().__init__(
            f"speculative {stage} outputs disagree: primary digest "
            f"{d_primary:#018x} != backup {d_backup:#018x}")
        self.stage = stage


class StageFailureInjector:
    """Deterministic per-stage failure schedule.

    ``fail_at``: mapping ``stage -> iterable of occurrence indices`` that
    raise :class:`StageFailure` (transient — a supervisor retries in place).
    ``device_fail_at``: same shape, raising :class:`DeviceFailure` with
    ``failed_devices`` lost (a supervisor shrinks the mesh).
    ``timeout_at``: same shape, raising :class:`StageTimeout` (a simulated
    deadline expiry — retried like a transient failure). ``kill_at``: same
    shape, raising :class:`ProcessKilled` (never retried — the whole
    invocation dies at the stage boundary). ``slow_at``: mapping ``stage ->
    {occurrence: seconds}`` — the stage *runs* but only after a real sleep,
    so supervisor deadlines and speculation cutoffs fire against genuine
    wall-clock slowness. ``check(stage)`` counts every call per stage; each
    scheduled fault fires exactly once, so the retry of a failed occurrence
    succeeds — mirroring ``runtime.failure.FailureInjector``'s fire-once
    contract at stage granularity. Returns the slow-sleep seconds to apply
    (or ``None``); callers that execute stages themselves may ignore it.
    """

    def __init__(self, fail_at=None, device_fail_at=None,
                 failed_devices: int = 1, timeout_at=None, kill_at=None,
                 slow_at=None):
        self.fail_at = {s: set(ix) for s, ix in (fail_at or {}).items()}
        self.device_fail_at = {s: set(ix)
                               for s, ix in (device_fail_at or {}).items()}
        self.timeout_at = {s: set(ix) for s, ix in (timeout_at or {}).items()}
        self.kill_at = {s: set(ix) for s, ix in (kill_at or {}).items()}
        self.slow_at = {s: dict(m) for s, m in (slow_at or {}).items()}
        self.failed_devices = failed_devices
        self.occurrences: dict[str, int] = {}
        self.fired: list[tuple[str, int, str]] = []

    def check(self, stage: str) -> Optional[float]:
        idx = self.occurrences.get(stage, 0)
        self.occurrences[stage] = idx + 1
        if idx in self.kill_at.get(stage, ()):
            self.kill_at[stage].discard(idx)
            self.fired.append((stage, idx, "kill"))
            raise ProcessKilled(stage, idx)
        if idx in self.device_fail_at.get(stage, ()):
            self.device_fail_at[stage].discard(idx)
            self.fired.append((stage, idx, "device"))
            raise DeviceFailure(
                f"injected device failure in {stage} (occurrence {idx})",
                self.failed_devices)
        if idx in self.timeout_at.get(stage, ()):
            self.timeout_at[stage].discard(idx)
            self.fired.append((stage, idx, "timeout"))
            raise StageTimeout(
                stage, deadline=0.0, occurrence=idx,
                msg=f"injected {stage} timeout (occurrence {idx})")
        if idx in self.fail_at.get(stage, ()):
            self.fail_at[stage].discard(idx)
            self.fired.append((stage, idx, "transient"))
            raise StageFailure(stage, idx)
        slow = self.slow_at.get(stage, {}).pop(idx, None)
        if slow is not None:
            self.fired.append((stage, idx, "slow"))
        return slow


_U64_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One splitmix64 step — the deterministic uniform stream behind the
    retry jitter (and the same finalizer ``pipeline/validate``'s digest
    uses, so the repo has exactly one PRNG idiom)."""
    x = (x + 0x9E3779B97F4A7C15) & _U64_MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _U64_MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _U64_MASK
    return (x ^ (x >> 31)) & _U64_MASK


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient stage failures. The default
    base of 0 keeps tests instant; production callers set e.g.
    ``RetryPolicy(max_retries=5, backoff_base=0.5)`` for 0.5/1/2/4/8 s.

    ``jitter`` spreads simultaneous retries: ``delay = expo * (1 - jitter *
    u)`` with ``u`` uniform in [0, 1) drawn from a seeded splitmix64 stream
    — ``jitter=1.0`` is AWS-style full jitter (delays land anywhere in
    ``(0, expo]``), ``jitter=0.0`` (default) keeps the legacy exact
    schedule. The draw is a pure function of ``(seed, stream, attempt)``,
    so two destinations retrying the same stage decollide (the supervisor
    hands each stage invocation its own ``stream``) while any given
    schedule replays bit-identically — chaos runs stay reproducible."""

    max_retries: int = 3
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    jitter: float = 0.0
    seed: int = 0

    def delay(self, attempt: int, stream: int = 0) -> float:
        expo = self.backoff_base * (self.backoff_factor ** (attempt - 1))
        if not self.jitter:
            return expo
        mix = _splitmix64((self.seed & _U64_MASK)
                          ^ ((stream & _U64_MASK) * 0x9E3779B97F4A7C15
                             & _U64_MASK)
                          ^ (attempt & _U64_MASK))
        u = mix / float(1 << 64)
        return expo * (1.0 - self.jitter * u)


@dataclasses.dataclass
class StageEvent:
    """One recovery action, for observability and test assertions."""

    stage: str
    attempt: int
    action: str    # 'retry' | 'remesh' | 'capacity_double' | 'speculate'
                   # | 'speculation_confirmed' | 'speculation_loser_failed'
    detail: str


@dataclasses.dataclass
class SpeculationPolicy:
    """Speculative re-execution policy for straggling stages (MapReduce's
    backup tasks, at combine-destination granularity). The ``monitor``
    learns the stage's healthy duration (EWMA over completed executions);
    once warmed up, a primary execution that outlives ``monitor.cutoff()``
    gets a backup launched against the same inputs — first *successful*
    completion wins, and the loser is discarded only after its output
    digest matches the winner's (disagreement raises
    :class:`SpeculationMismatch`: the stage is pure, so divergence is
    corruption, not a race). ``min_wait`` floors the cutoff so microsecond
    EWMAs never fire spurious backups."""

    monitor: object                      # runtime.straggler.StragglerMonitor
    min_wait: float = 0.05
    max_backups: int = 1


class SortSupervisor:
    """Recovery driver for the sort pipeline's stages.

    ``run_stage`` wraps one stage callable with the injector probe, the
    transient-retry policy, and (when ``deadlines`` names the stage) a
    wall-clock deadline — the stage runs on a worker thread and a
    ``future.result`` timeout converts a hang into a retryable
    :class:`StageTimeout`, the abandoned launch left to finish on its
    thread. ``run_speculative`` adds straggler-driven backup execution per
    :class:`SpeculationPolicy`. ``run_with_capacity`` escalates overflow
    into capacity doubling; ``run_distributed`` adds the mesh-shrink path
    for device loss during the sample-sort exchange. Pass the supervisor to
    ``pipeline.ingest.chunked_sort_*`` (which routes chunk launches and
    merge rounds through ``run_stage``) or call ``run_distributed`` around
    ``core.distributed``.
    """

    def __init__(self, policy: RetryPolicy = RetryPolicy(),
                 injector: Optional[StageFailureInjector] = None,
                 sleep: Callable[[float], None] = time.sleep,
                 deadlines: Optional[dict] = None,
                 speculation: Optional[SpeculationPolicy] = None):
        self.policy = policy
        self.injector = injector
        self.events: list[StageEvent] = []
        self._sleep = sleep
        self.deadlines = dict(deadlines or {})
        self.speculation = speculation
        self._stage_calls: dict[str, int] = {}

    def _next_stream(self, stage: str) -> int:
        """Per-invocation jitter stream: crc32 decorrelates stages, the
        per-stage call counter decorrelates the destinations that run the
        same stage — so full-jitter retries never re-collide, yet a replay
        of the same pipeline draws the same schedule."""
        idx = self._stage_calls.get(stage, 0)
        self._stage_calls[stage] = idx + 1
        return (zlib.crc32(stage.encode()) << 20) + idx

    def _execute(self, stage: str, fn: Callable, args, kwargs,
                 slow: Optional[float]):
        """One stage execution: apply any injected slow-sleep *inside* the
        deadline scope, and enforce the stage's deadline (if any) via a
        worker thread. ``shutdown(wait=False)`` abandons a timed-out launch
        instead of joining it — the retry must not block on the hang."""
        deadline = self.deadlines.get(stage)
        if deadline is None:
            if slow:
                time.sleep(slow)
            return fn(*args, **kwargs)

        def call():
            if slow:
                time.sleep(slow)
            return fn(*args, **kwargs)

        ex = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        try:
            fut = ex.submit(call)
            try:
                return fut.result(timeout=deadline)
            except concurrent.futures.TimeoutError:
                raise StageTimeout(stage, deadline) from None
        finally:
            ex.shutdown(wait=False)

    # -------------------------------------------------- transient retries

    def run_stage(self, stage: str, fn: Callable, *args, **kwargs):
        """Execute ``fn(*args, **kwargs)`` with the injector probe, the
        stage deadline (if configured), and bounded backoff retry on
        :class:`StageFailure` (including :class:`StageTimeout`).
        ``DeviceFailure`` and :class:`CapacityOverflow` are *not* retried
        here — they need a different recovery (remesh / bigger capacity)
        and propagate to the caller (``run_distributed`` /
        ``run_with_capacity``); :class:`ProcessKilled` propagates always
        (no in-process recovery for a dead process)."""
        stream = self._next_stream(stage)
        attempt = 0
        while True:
            try:
                slow = (self.injector.check(stage)
                        if self.injector is not None else None)
                return self._execute(stage, fn, args, kwargs, slow)
            except StageFailure as e:
                attempt += 1
                if attempt > self.policy.max_retries:
                    raise
                delay = self.policy.delay(attempt, stream=stream)
                action = ("timeout_retry" if isinstance(e, StageTimeout)
                          else "retry")
                log.warning("stage %s failed (attempt %d/%d): %s — retrying"
                            " in %.3gs", stage, attempt,
                            self.policy.max_retries, e, delay)
                self.events.append(StageEvent(stage, attempt, action, str(e)))
                if delay:
                    self._sleep(delay)

    # -------------------------------------------------- speculative backup

    def run_speculative(self, stage: str, fn: Callable, *args,
                        digest_of: Optional[Callable] = None, **kwargs):
        """Execute a (pure) stage with straggler-driven speculative backup:
        the primary runs on a worker thread; if it outlives the monitor's
        cutoff, a backup launches against the same inputs and the first
        *successful* completion wins. The loser is awaited and its output
        digest (``digest_of(out)``) compared before discarding — equality
        confirms the win, disagreement raises
        :class:`SpeculationMismatch`, and a loser that raised is recorded
        but ignored (the winner already proved the stage computable).
        Transient failures of *both* replicas fall back to the
        :class:`StageFailure` retry budget. Without a
        :class:`SpeculationPolicy` this is exactly ``run_stage`` (deadlines
        apply there; the speculative path supersedes them)."""
        if self.speculation is None:
            return self.run_stage(stage, fn, *args, **kwargs)
        stream = self._next_stream(stage)
        attempt = 0
        while True:
            try:
                slow = (self.injector.check(stage)
                        if self.injector is not None else None)
                return self._speculate_once(stage, fn, args, kwargs,
                                            digest_of, slow)
            except StageFailure as e:
                attempt += 1
                if attempt > self.policy.max_retries:
                    raise
                delay = self.policy.delay(attempt, stream=stream)
                log.warning("speculative stage %s failed (attempt %d/%d): "
                            "%s — retrying in %.3gs", stage, attempt,
                            self.policy.max_retries, e, delay)
                self.events.append(StageEvent(stage, attempt, "retry", str(e)))
                if delay:
                    self._sleep(delay)

    def _speculate_once(self, stage: str, fn: Callable, args, kwargs,
                        digest_of: Optional[Callable],
                        slow: Optional[float]):
        spec = self.speculation
        mon = spec.monitor
        step = self._stage_calls.get(stage, 0)

        def primary_call():
            # injected slowness applies to the primary only — the backup
            # models a healthy replacement worker
            if slow:
                time.sleep(slow)
            return fn(*args, **kwargs)

        ex = concurrent.futures.ThreadPoolExecutor(
            max_workers=1 + spec.max_backups)
        try:
            t0 = time.monotonic()
            primary = ex.submit(primary_call)
            cutoff = mon.cutoff()
            wait = (max(cutoff, spec.min_wait) if cutoff is not None
                    else None)
            try:
                out = primary.result(timeout=wait)
                mon.record(step, time.monotonic() - t0)
                return out
            except concurrent.futures.TimeoutError:
                pass
            except StageFailure:
                raise  # transient primary failure: no backup, just retry
            self.events.append(StageEvent(
                stage, 0, "speculate",
                f"primary exceeded cutoff {wait:.3g}s — backup launched"))
            log.warning("stage %s straggling past %.3gs — launching "
                        "speculative backup", stage, wait)
            backup = ex.submit(fn, *args, **kwargs)
            names = {primary: "primary", backup: "backup"}
            pending, winner = {primary, backup}, None
            while pending and winner is None:
                done, pending = concurrent.futures.wait(
                    pending,
                    return_when=concurrent.futures.FIRST_COMPLETED)
                for f in done:
                    if f.exception() is None:
                        winner = f
                        break
            if winner is None:
                raise primary.exception()
            out = winner.result()
            mon.record(step, time.monotonic() - t0)
            loser = backup if winner is primary else primary
            try:
                loser_out = loser.result()   # confirm before discarding
            except Exception as e:
                self.events.append(StageEvent(
                    stage, 0, "speculation_loser_failed",
                    f"{names[loser]} raised {type(e).__name__}: {e}"))
            else:
                if digest_of is not None:
                    d_w, d_l = digest_of(out), digest_of(loser_out)
                    if d_w != d_l:
                        raise SpeculationMismatch(stage, d_w, d_l)
                self.events.append(StageEvent(
                    stage, 0, "speculation_confirmed",
                    f"{names[winner]} won; loser output "
                    + ("digest-equal" if digest_of is not None
                       else "discarded unchecked")))
            return out
        finally:
            ex.shutdown(wait=False)

    # -------------------------------------------------- overflow escalation

    def run_with_capacity(self, stage: str, fn: Callable, capacity: int,
                          max_doublings: int = 8):
        """Run ``fn(capacity)`` (through the stage retry machinery),
        doubling ``capacity`` on :class:`CapacityOverflow` — the degrade
        policy that converges instead of dropping elements. When the
        overflow reports its true requirement, jump straight there."""
        for _ in range(max_doublings + 1):
            try:
                return self.run_stage(stage, fn, capacity)
            except CapacityOverflow as e:
                new_cap = max(capacity * 2, e.required or 0)
                log.warning("stage %s overflowed capacity %d — retrying at "
                            "%d", stage, capacity, new_cap)
                self.events.append(StageEvent(
                    stage, 0, "capacity_double",
                    f"capacity {capacity} -> {new_cap}"))
                capacity = new_cap
        raise CapacityOverflow(
            f"stage {stage} still overflowing after {max_doublings} "
            f"doublings", capacity)

    # -------------------------------------------------- mesh-shrink re-run

    def run_distributed(self, make_mesh: Callable[[int], object],
                        devices: int, run: Callable, *,
                        min_devices: int = 1, max_recoveries: int = 8):
        """Execute ``run(mesh)`` — typically a closure over
        ``core.distributed.distributed_sort_lex`` — rebuilding a smaller
        mesh on ``DeviceFailure`` (the ``ElasticSupervisor`` control flow,
        minus the checkpoint: a sort's input is its own checkpoint, so lost
        chunks simply re-execute on the survivors). The injector's
        ``exchange`` stage probes each dispatch."""
        recoveries = 0
        while True:
            try:
                if self.injector is not None:
                    self.injector.check("exchange")
                return run(make_mesh(devices))
            except DeviceFailure as e:
                recoveries += 1
                if recoveries > max_recoveries:
                    raise RuntimeError("exceeded max recoveries") from e
                survivors = devices - e.failed_devices
                if survivors < min_devices:
                    raise RuntimeError(
                        f"insufficient surviving devices: {survivors} < "
                        f"min_devices={min_devices}") from e
                log.warning("device failure during exchange: %d -> %d "
                            "devices — re-running on survivors",
                            devices, survivors)
                self.events.append(StageEvent(
                    "exchange", recoveries, "remesh",
                    f"{devices} -> {survivors} devices"))
                devices = survivors
