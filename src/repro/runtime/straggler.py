"""Straggler mitigation: per-step timing monitor with EWMA baseline and
z-score outlier flagging, plus a hook for backup-work dispatch.

At pod scale a straggling host shows up as a slow collective; the monitor
runs on the coordinator and flags steps whose duration deviates from the
EWMA by ``threshold`` sigma. The ``on_straggler`` hook is where a deployment
triggers its mitigation (reshard, evict, or dispatch a backup replica —
what MapReduce called speculative execution)."""

from __future__ import annotations

import logging
import math
from typing import Callable, Optional

__all__ = ["StragglerMonitor"]

log = logging.getLogger("repro.runtime")


class StragglerMonitor:
    """``rebaseline_after``: flagged steps never feed the EWMA, so after a
    *durable* regime shift (e.g. the job migrated to slower hardware) the
    frozen baseline would flag every subsequent step forever. After this many
    *consecutive* flags the monitor accepts the new regime: the baseline is
    rebuilt from the flagged durations themselves and flagging resumes
    against it. A genuine one-off straggler resets the streak on the next
    healthy step and never triggers a re-baseline."""

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 5, min_ratio: float = 1.5,
                 rebaseline_after: int = 8,
                 on_straggler: Optional[Callable] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        # relative floor: jitter within min_ratio x mean is never a straggler,
        # even when the variance estimate has collapsed on a very steady run
        self.min_ratio = min_ratio
        self.rebaseline_after = rebaseline_after
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.flagged: list[tuple[int, float]] = []
        self.rebaselines: list[int] = []   # steps at which the regime shifted
        self._streak: list[float] = []     # durations of the current flag run

    def _rebaseline(self, step: int):
        """Adopt the flagged streak as the new baseline (Welford over the
        streak, count pinned past warmup so flagging resumes immediately)."""
        self.mean = 0.0
        self.var = 0.0
        for i, d in enumerate(self._streak, start=1):
            delta = d - self.mean
            self.mean += delta / i
            self.var += delta * (d - self.mean)
        self.count = max(self.warmup, len(self._streak))
        self._streak = []
        self.rebaselines.append(step)
        log.warning("straggler monitor re-baselined at step %s: "
                    "%d consecutive flags, new mean %.4g",
                    step, self.rebaseline_after, self.mean)

    def cutoff(self) -> Optional[float]:
        """Speculation cutoff in seconds — how long a task may run before a
        backup is worth launching: ``None`` during warmup (no baseline to
        judge against yet), else ``mean * min_ratio``, the same relative
        floor :meth:`record` applies before flagging. Consumed by
        ``runtime.sortfault.SortSupervisor.run_speculative``."""
        if self.count < self.warmup or self.mean <= 0:
            return None
        return self.mean * self.min_ratio

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            # warmup: estimate baseline
            d = duration - self.mean
            self.mean += d / self.count
            self.var += d * (duration - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.count - 1, 1), 1e-12))
        z = (duration - self.mean) / std
        is_straggler = z > self.threshold and duration > self.mean * self.min_ratio
        if is_straggler:
            self.flagged.append((step, duration))
            self._streak.append(duration)
            if self.on_straggler:
                self.on_straggler(step, duration, z)
            if len(self._streak) >= self.rebaseline_after:
                self._rebaseline(step)
        else:
            # update EWMA baseline with healthy steps only
            self._streak = []
            d = duration - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
