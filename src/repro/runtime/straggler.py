"""Straggler mitigation: per-step timing monitor with EWMA baseline and
z-score outlier flagging, plus a hook for backup-work dispatch.

At pod scale a straggling host shows up as a slow collective; the monitor
runs on the coordinator and flags steps whose duration deviates from the
EWMA by ``threshold`` sigma. The ``on_straggler`` hook is where a deployment
triggers its mitigation (reshard, evict, or dispatch a backup replica —
what MapReduce called speculative execution)."""

from __future__ import annotations

import math
from typing import Callable, Optional

__all__ = ["StragglerMonitor"]


class StragglerMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 3.0,
                 warmup: int = 5, min_ratio: float = 1.5,
                 on_straggler: Optional[Callable] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        # relative floor: jitter within min_ratio x mean is never a straggler,
        # even when the variance estimate has collapsed on a very steady run
        self.min_ratio = min_ratio
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, duration: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            # warmup: estimate baseline
            d = duration - self.mean
            self.mean += d / self.count
            self.var += d * (duration - self.mean)
            return False
        std = math.sqrt(max(self.var / max(self.count - 1, 1), 1e-12))
        z = (duration - self.mean) / std
        is_straggler = z > self.threshold and duration > self.mean * self.min_ratio
        if is_straggler:
            self.flagged.append((step, duration))
            if self.on_straggler:
                self.on_straggler(step, duration, z)
        else:
            # update EWMA baseline with healthy steps only
            d = duration - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
