"""Elastic failure recovery.

On real pods a node failure surfaces as a collective timeout / RPC error;
here it is modelled by ``DeviceFailure``. The supervisor wraps the training
loop: on failure it (1) drops to the surviving device count, (2) rebuilds the
mesh via the user-provided factory, (3) restores the latest checkpoint with
the new shardings (checkpoint/manager.py reshard-on-restore), and (4)
continues from the restored step. This is the same control flow a 1000-node
deployment needs; only the failure *detector* differs.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

__all__ = ["DeviceFailure", "CapacityOverflow", "FailureInjector",
           "ElasticSupervisor"]

log = logging.getLogger("repro.runtime")


class DeviceFailure(RuntimeError):
    """Raised when a device/host is lost (simulated on CPU)."""

    def __init__(self, msg: str, failed_devices: int = 1):
        super().__init__(msg)
        self.failed_devices = failed_devices


class CapacityOverflow(ValueError):
    """A statically sized buffer (bucket tensor, exchange capacity) received
    more elements than it holds. Carries enough structure for a supervisor
    to escalate into a capacity-doubling retry instead of dropping data
    (``runtime/sortfault.py``); subclasses ``ValueError`` so pre-existing
    ``except ValueError`` overflow handling keeps working."""

    def __init__(self, msg: str, capacity: int, required: int | None = None,
                 dropped: int | None = None):
        super().__init__(msg)
        self.capacity = capacity
        self.required = required
        self.dropped = dropped


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at_steps=(), failed_devices: int = 1):
        self.fail_at = set(fail_at_steps)
        self.failed_devices = failed_devices
        self._fired = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise DeviceFailure(f"injected failure at step {step}", self.failed_devices)


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    devices_before: int
    devices_after: int


class ElasticSupervisor:
    """Run a step loop with checkpoint/restart + elastic mesh shrink.

    ``run_segment(state, start_step, devices) -> (state, next_step)`` executes
    steps until completion or raises DeviceFailure. ``remesh(devices)`` tells
    the caller to rebuild mesh/shardings/jit for the new world size and
    restore ``state`` from the checkpoint manager.

    ``restartable=True`` models single-host (or respawning-scheduler)
    recovery: a failed device is replaced by the restarted process, so the
    world size never shrinks — recovery is restore-from-checkpoint only.
    The default ``False`` is true elastic semantics: survivors only, and
    dropping below ``min_devices`` raises instead of pretending lost
    hardware still exists.
    """

    def __init__(self, ckpt_manager, initial_devices: int,
                 min_devices: int = 1, max_recoveries: int = 8,
                 restartable: bool = False):
        self.ckpt = ckpt_manager
        self.devices = initial_devices
        self.min_devices = min_devices
        self.max_recoveries = max_recoveries
        self.restartable = restartable
        self.events: list[RecoveryEvent] = []

    def run(self, run_segment: Callable, remesh: Callable, state, start_step: int = 0):
        step = start_step
        recoveries = 0
        while True:
            try:
                return run_segment(state, step, self.devices)
            except DeviceFailure as e:
                recoveries += 1
                if recoveries > self.max_recoveries:
                    raise RuntimeError("exceeded max recoveries") from e
                before = self.devices
                if self.restartable:
                    # the scheduler respawns the lost device: same world
                    # size, recovery is restore-from-checkpoint only
                    log.warning("device failure at step %s: restarting on "
                                "%s devices", step, self.devices)
                else:
                    survivors = self.devices - e.failed_devices
                    if survivors < self.min_devices:
                        # pretending min_devices still exist would run work
                        # on hardware that is gone — fail loudly instead of
                        # clamping
                        raise RuntimeError(
                            f"insufficient surviving devices: {survivors} < "
                            f"min_devices={self.min_devices}") from e
                    self.devices = survivors
                    log.warning("device failure at step %s: %s -> %s devices",
                                step, before, self.devices)
                self.ckpt.wait()  # let any in-flight snapshot land
                restored = remesh(self.devices)
                if restored is None:
                    raise RuntimeError("no checkpoint to recover from") from e
                step, state = restored
                self.events.append(RecoveryEvent(step, before, self.devices))
