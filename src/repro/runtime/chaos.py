"""Seeded chaos soak harness for the crash-anywhere distributed sort.

The fault-tolerance claim of ``core.distributed.distributed_chunked_sort_
lex`` is an *anywhere* claim: whatever combination of transient stage
failures, injected timeouts, process kills at stage boundaries, and
post-write artifact damage (torn ``.tmp`` droppings, truncated or
short-row ``.npy`` files, bit flips) hits the job, it must either complete
bit-identical to the no-fault oracle or die with a *typed* error leaving
stores from which a second invocation resumes bit-identically. Hand-picked
fault tests can't cover that product space; this module samples it:

  * :func:`make_plan` derives one randomized-but-deterministic
    :class:`ChaosPlan` per seed (``np.random.default_rng(seed)`` — same
    seed, same schedule, forever): an injector schedule over the pipeline's
    stages plus a list of post-mortem store damages;
  * :func:`apply_damages` inflicts the plan's damage on whatever artifacts
    the (possibly killed) first invocation left behind — the seeded chaos
    equivalent of a disk that lies;
  * :func:`chaos_soak` drives N seeds: invocation 1 under the injector,
    damage, then invocation 2 against the same stores with no injector —
    asserting the resume lands bit-identical to the oracle. Only *typed*
    errors (the fault taxonomy: ``StageFailure``/``StageTimeout``,
    ``DeviceFailure``, ``CapacityOverflow``, ``ProcessKilled``,
    ``ValidationError``, ``CorruptSnapshotError``) are acceptable from
    invocation 1 — a bare numpy/JAX exception is a soak failure.

Damage-kind semantics (each self-heals on resume through a different
guard, which is the point):

  ``tmp``         a half-written ``.tmp_*`` snapshot dropping — swept on
                  store open, never mistaken for landed data
  ``truncate``    a landed ``.npy`` binarily truncated (torn by external
                  damage) — ``CorruptSnapshotError`` at load, recompute
  ``short_rows``  a *valid* ``.npy`` with fewer rows than the snapshot
                  manifest records — shape-vs-manifest mismatch raises
                  ``CorruptSnapshotError``, recompute
  ``bitflip``     one flipped payload bit in a shard's ``keys.npy`` —
                  loadable, count-correct, possibly still sorted; only the
                  ``validate='full'`` digest gate can prove it wrong, so
                  plans pair bit flips with full validation (shards only:
                  the shard-resume gate recomputes on digest mismatch)
"""

from __future__ import annotations

import dataclasses
import logging
import os
from typing import Optional, Sequence, Tuple

import numpy as np

from ..checkpoint.manager import CorruptSnapshotError
from .failure import CapacityOverflow, DeviceFailure
from .sortfault import (ProcessKilled, RetryPolicy, SortSupervisor,
                        StageFailure, StageFailureInjector)

__all__ = ["TYPED_ERRORS", "ChaosPlan", "SoakReport", "make_plan",
           "apply_damages", "chaos_soak"]

log = logging.getLogger("repro.runtime")

# the full fault taxonomy — everything invocation 1 is *allowed* to die
# with (ValidationError is imported lazily to keep this module's import
# graph off the jax path until soak time)
def _typed_errors():
    from ..pipeline.validate import ValidationError
    return (StageFailure, DeviceFailure, CapacityOverflow, ProcessKilled,
            ValidationError, CorruptSnapshotError)


TYPED_ERRORS = _typed_errors  # callable: resolved at soak time


# the stages distributed_chunked_sort_lex runs through the supervisor, with
# the occurrence range a D-device soak can reach (ingest + combine run once
# per device/destination; the exchange once, plus capacity retries)
_STAGE_OCCS = {"ingest_chunk": 4, "run_exchange": 1, "streaming_combine": 4}
_DAMAGE_KINDS = ("tmp", "truncate", "short_rows", "bitflip")


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """One seed's fault schedule: deterministic injector maps (stage ->
    occurrence indices), post-run store damages (``(kind, store)`` with
    store ``'runs'`` or ``'shards'``), and the validation mode the sort
    runs under. ``make_plan(seed)`` is a pure function of the seed."""

    seed: int
    validate: str                                   # 'cheap' | 'full'
    fail_at: Tuple[Tuple[str, int], ...]            # transient
    timeout_at: Tuple[Tuple[str, int], ...]         # injected deadline hit
    kill_at: Tuple[Tuple[str, int], ...]            # simulated SIGKILL
    device_fail_at: Tuple[Tuple[str, int], ...]     # device loss (aborts)
    damages: Tuple[Tuple[str, str], ...]            # (kind, store)
    max_retries: int = 3

    def _as_map(self, pairs):
        out: dict = {}
        for stage, occ in pairs:
            out.setdefault(stage, set()).add(occ)
        return out

    def injector(self) -> StageFailureInjector:
        return StageFailureInjector(
            fail_at=self._as_map(self.fail_at),
            timeout_at=self._as_map(self.timeout_at),
            kill_at=self._as_map(self.kill_at),
            device_fail_at=self._as_map(self.device_fail_at))


def make_plan(seed: int, num_devices: int = 4) -> ChaosPlan:
    """Derive the seed's :class:`ChaosPlan`. Deterministic: the same seed
    always yields the same schedule (the soak's reproducibility contract —
    a red seed in CI replays locally verbatim)."""
    rng = np.random.default_rng(seed)
    occs = {s: min(m, max(1, num_devices))
            for s, m in _STAGE_OCCS.items()}
    stages = sorted(occs)

    def draw_faults(n):
        out = []
        for _ in range(n):
            s = stages[int(rng.integers(len(stages)))]
            out.append((s, int(rng.integers(occs[s]))))
        return tuple(out)

    # draw order is part of the plan's identity — never reorder these
    validate = "full" if rng.random() < 0.5 else "cheap"
    fail_at = draw_faults(int(rng.integers(0, 3)))
    timeout_at = draw_faults(int(rng.integers(0, 2)))
    kill_at = ()
    if rng.random() < 0.6:
        s = stages[int(rng.integers(len(stages)))]
        kill_at = ((s, int(rng.integers(occs[s]))),)
    device_fail_at = ()
    if rng.random() < 0.15:
        s = stages[int(rng.integers(len(stages)))]
        device_fail_at = ((s, int(rng.integers(occs[s]))),)
    kinds = [k for k in _DAMAGE_KINDS
             if k != "bitflip" or validate == "full"]
    damages = tuple(
        (kinds[int(rng.integers(len(kinds)))],
         "shards" if rng.random() < 0.7 else "runs")
        for _ in range(int(rng.integers(0, 3))))
    # bit flips in the ingest-run store are undetectable by construction
    # when the manifest still matches the input chunk (the sorted bytes
    # changed, the multiset digest of the *input* didn't have to) — shards
    # are where the digest gate re-proves content, so flips go there only
    damages = tuple((k, "shards" if k == "bitflip" else st)
                    for k, st in damages)
    return ChaosPlan(seed=int(seed), validate=validate, fail_at=fail_at,
                     timeout_at=timeout_at, kill_at=kill_at,
                     device_fail_at=device_fail_at, damages=damages)


def _landed_npys(directory: str, min_size: int = 0) -> list:
    out = []
    if not os.path.isdir(directory):
        return out
    for step in sorted(os.listdir(directory)):
        d = os.path.join(directory, step)
        if not step.startswith("step_") or not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            p = os.path.join(d, f)
            if f.endswith(".npy") and os.path.getsize(p) > min_size:
                out.append(p)
    return out


def apply_damages(plan: ChaosPlan, run_dir: str, shard_dir: str) -> list:
    """Inflict the plan's damages on whatever the first invocation left
    behind. Damage targets are drawn from the plan's own rng stream (offset
    by the damage index) over the files that actually exist — a kill early
    in the pipeline simply leaves less to damage. Returns ``(kind, path)``
    pairs for the damages actually applied."""
    applied = []
    for i, (kind, which) in enumerate(plan.damages):
        rng = np.random.default_rng((plan.seed << 8) + i)
        base = shard_dir if which == "shards" else run_dir
        if kind == "tmp":
            tmp = os.path.join(base, ".tmp_7")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "junk.npy"), "wb") as f:
                f.write(b"\x00" * 16)
            applied.append((kind, tmp))
            continue
        # keys.npy only: big enough to damage meaningfully, and the guards
        # under test (shape check, digest gate) all watch the key tensor
        cands = [p for p in _landed_npys(base, min_size=256)
                 if p.endswith("keys.npy")]
        if not cands:
            continue
        path = cands[int(rng.integers(len(cands)))]
        size = os.path.getsize(path)
        if kind == "truncate":
            with open(path, "r+b") as f:
                f.truncate(int(rng.integers(1, max(2, size // 2))))
        elif kind == "short_rows":
            arr = np.load(path)
            if arr.shape[0] < 2:
                continue
            np.save(path, arr[: arr.shape[0] // 2])
        elif kind == "bitflip":
            # flip one bit in the data region (past the ~128-byte header)
            off = int(rng.integers(200, size))
            with open(path, "r+b") as f:
                f.seek(off)
                byte = f.read(1)[0]
                f.seek(off)
                f.write(bytes([byte ^ (1 << int(rng.integers(8)))]))
        applied.append((kind, path))
    return applied


@dataclasses.dataclass
class SoakReport:
    """Outcome of one seed: what invocation 1 died with (``None`` = it
    completed), which faults actually fired, what damage landed, and
    whether the final state is bit-identical to the oracle."""

    seed: int
    plan: ChaosPlan
    first_error: Optional[str]
    fired: Tuple[Tuple[str, int, str], ...]
    damaged: Tuple[Tuple[str, str], ...]
    resumed: bool
    ok: bool
    detail: str = ""


def _materialize(result, validate: str):
    """Gathered ``SortedRun`` or spilled ``ShardedRun`` -> host arrays."""
    run = result.to_run(validate=validate) if hasattr(result, "to_run") \
        else result
    return np.asarray(run.lengths), np.asarray(run.keys)


def chaos_soak(keys, seeds: Sequence[int], workdir: str, devices=None,
               merge_engine: str = "auto",
               num_devices: int = 4) -> list:
    """Run the soak: for each seed, invocation 1 of
    ``distributed_chunked_sort_lex`` under the seed's injector (jittered
    retry policy, no real sleeps), then the plan's store damages, then
    invocation 2 against the same directories with no injector. Every seed
    must end bit-identical to the no-fault oracle — either directly (the
    faults were all recoverable in-process) or through the resume — and
    invocation 1 may only die with a typed error. Returns one
    :class:`SoakReport` per seed; ``all(r.ok for r in reports)`` is the
    soak verdict."""
    from ..core.distributed import distributed_chunked_sort_lex
    from ..pipeline.manifest import RunStore
    from ..pipeline.shards import ShardStore
    typed = TYPED_ERRORS()

    oracle = distributed_chunked_sort_lex(keys, devices=devices,
                                          merge_engine=merge_engine,
                                          validate="off")
    o_lengths, o_keys = np.asarray(oracle.lengths), np.asarray(oracle.keys)

    reports = []
    for seed in seeds:
        plan = make_plan(seed, num_devices=num_devices)
        run_dir = os.path.join(workdir, f"seed_{seed}", "runs")
        shard_dir = os.path.join(workdir, f"seed_{seed}", "shards")
        sup = SortSupervisor(
            policy=RetryPolicy(max_retries=plan.max_retries,
                               backoff_base=0.01, jitter=1.0, seed=seed),
            injector=plan.injector(), sleep=lambda _s: None)
        first_error, detail = None, ""
        try:
            res = distributed_chunked_sort_lex(
                keys, devices=devices, algorithm="pallas",
                store=RunStore(run_dir), shard_store=ShardStore(shard_dir),
                supervisor=sup, validate=plan.validate,
                merge_engine=merge_engine)
        except typed as e:
            first_error = type(e).__name__
            detail = str(e)
        except Exception as e:   # untyped: the soak contract is broken
            reports.append(SoakReport(
                seed=int(seed), plan=plan,
                first_error=f"UNTYPED:{type(e).__name__}",
                fired=tuple(sup.injector.fired), damaged=(),
                resumed=False, ok=False, detail=str(e)))
            continue

        damaged = tuple(apply_damages(plan, run_dir, shard_dir))
        resumed = first_error is not None or bool(damaged)
        try:
            res2 = distributed_chunked_sort_lex(
                keys, devices=devices, algorithm="pallas",
                store=RunStore(run_dir), shard_store=ShardStore(shard_dir),
                supervisor=SortSupervisor(), validate=plan.validate,
                merge_engine=merge_engine)
            lengths, kk = _materialize(res2, plan.validate)
            ok = (np.array_equal(lengths, o_lengths)
                  and np.array_equal(kk, o_keys))
            if not ok:
                detail = "resume output differs from oracle"
        except Exception as e:
            ok = False
            detail = f"resume raised {type(e).__name__}: {e}"
        reports.append(SoakReport(
            seed=int(seed), plan=plan, first_error=first_error,
            fired=tuple(sup.injector.fired), damaged=damaged,
            resumed=resumed, ok=ok, detail=detail))
        log.info("chaos seed %s: first_error=%s fired=%d damaged=%d ok=%s",
                 seed, first_error, len(sup.injector.fired), len(damaged),
                 ok)
    return reports
