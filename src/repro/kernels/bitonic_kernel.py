"""Pallas TPU kernel: bitonic network sort along vector lanes (beyond-paper).

Same layout as the OETS kernel ((ROW_BLOCK, cols) in VMEM, one bucket per
sublane row) but O(log^2 cols) phases instead of cols. The XOR-partner
shuffle is expressed as two lane ``roll``s + a bit-select, which lowers to
cheap lane permutes on the VPU — no gather. cols must be a power of two
(ops.py pads with the dtype's max sentinel).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["bitonic_rows_kernel", "bitonic_rows_kv_kernel", "bitonic_rows_pallas", "bitonic_rows_kv_pallas"]


def _stage(k, v, col, j, direction_asc):
    """Compare-exchange with partner col ^ j; ascending where mask True."""
    bit_unset = (col & j) == 0
    # partner value: col+j for bit-unset lanes (roll left), col-j otherwise.
    pk = jnp.where(bit_unset, jnp.roll(k, -j, axis=1), jnp.roll(k, j, axis=1))
    if v is None:
        gt = k > pk
        lt = pk > k
    else:
        # (key, val) lex compare: keeps the padding pair (sentinel, sentinel)
        # strictly maximal so it cannot displace a real payload when a real
        # key equals the sentinel (long-distance swaps are not stable).
        pv = jnp.where(bit_unset, jnp.roll(v, -j, axis=1), jnp.roll(v, j, axis=1))
        gt = (k > pk) | ((k == pk) & (v > pv))
        lt = (pk > k) | ((pk == k) & (pv > v))
    swap = jnp.where(direction_asc, jnp.where(bit_unset, gt, lt),
                     jnp.where(bit_unset, lt, gt))
    k = jnp.where(swap, pk, k)
    if v is None:
        return k, None
    return k, jnp.where(swap, pv, v)


def _network(k, v):
    ncols = k.shape[1]
    col = lax.broadcasted_iota(jnp.int32, k.shape, 1)
    for stage in range(1, int(math.log2(ncols)) + 1):
        kk = 1 << stage
        direction_asc = (col & kk) == 0
        for sub in reversed(range(stage)):
            k, v = _stage(k, v, col, 1 << sub, direction_asc)
    return k, v


def bitonic_rows_kernel(x_ref, o_ref):
    k, _ = _network(x_ref[...], None)
    o_ref[...] = k


def bitonic_rows_kv_kernel(k_ref, v_ref, ok_ref, ov_ref):
    k, v = _network(k_ref[...], v_ref[...])
    ok_ref[...] = k
    ov_ref[...] = v


def _row_block(rows: int) -> int:
    return min(rows, 8)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def bitonic_rows_pallas(x, *, interpret: bool = False, row_block: int | None = None):
    rows, cols = x.shape
    if cols & (cols - 1):
        raise ValueError("cols must be a power of two (pad in ops.py)")
    rb = row_block or _row_block(rows)
    return pl.pallas_call(
        bitonic_rows_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def bitonic_rows_kv_pallas(keys, vals, *, interpret: bool = False, row_block: int | None = None):
    rows, cols = keys.shape
    if cols & (cols - 1):
        raise ValueError("cols must be a power of two (pad in ops.py)")
    rb = row_block or _row_block(rows)
    return pl.pallas_call(
        bitonic_rows_kv_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(keys.shape, keys.dtype),
            jax.ShapeDtypeStruct(vals.shape, vals.dtype),
        ),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(keys, vals)
