"""Pallas TPU kernel: bitonic network sort along vector lanes (beyond-paper).

Same layout as the OETS kernel ((ROW_BLOCK, cols) in VMEM, one bucket per
sublane row) but O(log^2 cols) phases instead of cols. The XOR-partner
shuffle is expressed as two lane ``roll``s + a bit-select, which lowers to
cheap lane permutes on the VPU — no gather. cols must be a power of two
(ops.py pads with the dtype's max sentinel).

Variadic like the OETS kernel: ``bitonic_rows_lex_pallas(*arrs)`` sorts
tuples of same-shape arrays by lexicographic compare (``kernels/lex.py``);
key-only and key-value are the 1- and 2-tuple special cases.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .lex import lex_gt_lanes, select_lanes

__all__ = [
    "bitonic_rows_lex_kernel",
    "bitonic_rows_lex_pallas",
    "bitonic_rows_pallas",
    "bitonic_rows_kv_pallas",
]


def _stage(arrs, col, j, direction_asc):
    """Compare-exchange with partner col ^ j; ascending where mask True."""
    bit_unset = (col & j) == 0
    # partner value: col+j for bit-unset lanes (roll left), col-j otherwise.
    partners = [
        jnp.where(bit_unset, jnp.roll(a, -j, axis=1), jnp.roll(a, j, axis=1))
        for a in arrs
    ]
    # Full-tuple lex compare (trailing payload lanes are the tie-break):
    # keeps the all-sentinel padding tuple strictly maximal so it cannot
    # displace a real payload when a real key equals the sentinel
    # (long-distance swaps are not stable).
    gt = lex_gt_lanes(arrs, partners)
    lt = lex_gt_lanes(partners, arrs)
    swap = jnp.where(direction_asc, jnp.where(bit_unset, gt, lt),
                     jnp.where(bit_unset, lt, gt))
    return select_lanes(swap, partners, arrs)


def _network(arrs):
    ncols = arrs[0].shape[1]
    col = lax.broadcasted_iota(jnp.int32, arrs[0].shape, 1)
    for stage in range(1, int(math.log2(ncols)) + 1):
        kk = 1 << stage
        direction_asc = (col & kk) == 0
        for sub in reversed(range(stage)):
            arrs = _stage(arrs, col, 1 << sub, direction_asc)
    return arrs


def bitonic_rows_lex_kernel(*refs):
    n = len(refs) // 2
    out = _network(tuple(r[...] for r in refs[:n]))
    for r, o in zip(refs[n:], out):
        r[...] = o


def _row_block(rows: int) -> int:
    return min(rows, 8)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def bitonic_rows_lex_pallas(*arrs, interpret: bool = False,
                            row_block: int | None = None):
    """Sort each row of the (R, C) tuple ``arrs`` ascending by lexicographic
    tuple compare; C must be a power of two (pad in ops.py)."""
    rows, cols = arrs[0].shape
    if cols & (cols - 1):
        raise ValueError("cols must be a power of two (pad in ops.py)")
    rb = row_block or _row_block(rows)
    spec = pl.BlockSpec((rb, cols), lambda i: (i, 0))
    return pl.pallas_call(
        bitonic_rows_lex_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs),
        grid=(rows // rb,),
        in_specs=[spec] * len(arrs),
        out_specs=tuple([spec] * len(arrs)),
        interpret=interpret,
    )(*arrs)


def bitonic_rows_pallas(x, *, interpret: bool = False, row_block: int | None = None):
    """Key-only special case."""
    (out,) = bitonic_rows_lex_pallas(x, interpret=interpret, row_block=row_block)
    return out


def bitonic_rows_kv_pallas(keys, vals, *, interpret: bool = False,
                           row_block: int | None = None):
    """Key-value special case: the payload is the 2nd (tie-break) lane."""
    return bitonic_rows_lex_pallas(keys, vals, interpret=interpret,
                                   row_block=row_block)
