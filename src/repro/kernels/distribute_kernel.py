"""Pallas TPU kernel: the paper's *distribute* phase (phases 1-2) on device.

"Distributing the elements of the input datasets into many additional
temporary sub-arrays according to a number of characters in each word" used
to be a host-side Python dict loop (``core/bucketing.bucketize_words``).
This kernel is that loop as one sequential-grid VMEM sweep over the packed
word tensor: for every word it emits

  * its byte **length** (= destination bucket id, since buckets are dense
    per-length: bucket ``l`` holds exactly the words of length ``l``),
  * its **stable rank** within that bucket (arrival order preserved), and
  * the running per-length **histogram** (the paper's phase-1 count pass),

so the caller can place every word with a single device scatter
(``ops.bucketize``) — no gather inside the kernel, no host loop outside it.

Layout: words live along the 128-lane axis — the input is the *transposed*
packed matrix ``(lanes, n)`` so one ``(lanes, C)`` block holds C complete
words. Byte lengths come from the big-endian packing contract of
``core/packing.py``: length = position of the last non-zero byte (interior
NUL bytes therefore count toward the length, matching ``unpack_words``;
*trailing* NUL bytes are unrecoverable after packing — by design).

Stable ranks need a prefix over all earlier words, which is exactly what the
TPU grid's sequential execution provides: the histogram output block is
revisited by every grid step (its index_map is constant), so it carries the
running counts from block to block — each step reads the pre-update counts
(= ranks of its first element per bucket), adds its block histogram, and
writes back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["distribute_rows_kernel", "distribute_rows_pallas"]


def distribute_rows_kernel(keys_ref, dest_ref, rank_ref, cnt_ref, *,
                           n_valid, num_buckets, col_block):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    x = keys_ref[...]                         # (lanes, C) uint32, big-endian
    # byte length = last non-zero byte position + 1 (0 for the empty word)
    lane = lax.broadcasted_iota(jnp.int32, x.shape, 0)
    last = jnp.zeros(x.shape, jnp.int32)
    for k, shift in enumerate((24, 16, 8, 0)):
        byte = (x >> shift) & jnp.uint32(0xFF)
        last = jnp.maximum(last, jnp.where(byte != 0, 4 * lane + k + 1, 0))
    length = jnp.max(last, axis=0, keepdims=True)        # (1, C)

    col = j * col_block + lax.broadcasted_iota(jnp.int32, length.shape, 1)
    valid = col < n_valid
    dest = jnp.where(valid, length, num_buckets)         # invalid -> discard id
    dest_ref[...] = dest

    # Stable rank: within-block exclusive prefix count of same-destination
    # words, offset by the running (pre-block) histogram carried in cnt_ref.
    running = cnt_ref[...]                               # (1, B_pad)
    rank = jnp.zeros_like(dest)
    for p in range(num_buckets):                         # static, <= 4*lanes+1
        m = (dest == p).astype(jnp.int32)
        excl = jnp.cumsum(m, axis=1) - m
        rank = jnp.where(m == 1, excl + running[0, p], rank)
        cnt_ref[:, p] = running[:, p] + jnp.sum(m, axis=1)
    rank_ref[...] = rank


@functools.partial(jax.jit, static_argnames=("n_valid", "num_buckets",
                                             "interpret", "col_block"))
def distribute_rows_pallas(keys_t, *, n_valid: int, num_buckets: int,
                           interpret: bool = False, col_block: int = 128):
    """keys_t: (lanes, n_pad) uint32, words along lanes, n_pad % col_block == 0.
    Returns (dest (1, n_pad) int32, rank (1, n_pad) int32,
    counts (1, B_pad) int32) — ``dest`` is the word's byte length (bucket
    id; ``num_buckets`` marks padding columns >= ``n_valid``), ``rank`` its
    stable slot inside the bucket, ``counts[:, :num_buckets]`` the final
    length histogram."""
    lanes, n_pad = keys_t.shape
    b_pad = max(128, -(-num_buckets // 128) * 128)
    kern = functools.partial(distribute_rows_kernel, n_valid=n_valid,
                             num_buckets=num_buckets, col_block=col_block)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, b_pad), jnp.int32),
        ),
        grid=(n_pad // col_block,),
        in_specs=[pl.BlockSpec((lanes, col_block), lambda j: (0, j))],
        out_specs=(
            pl.BlockSpec((1, col_block), lambda j: (0, j)),
            pl.BlockSpec((1, col_block), lambda j: (0, j)),
            # constant index_map: the same block is revisited every step and
            # carries the running histogram (sequential TPU grid)
            pl.BlockSpec((1, b_pad), lambda j: (0, 0)),
        ),
        interpret=interpret,
    )(keys_t)
