"""Packed rank keys: order-preserving compression of lex tuples into 1-2
uint32 lanes, plus the searchsorted-fast merge-path rank primitives built on
them.

The paper's "array 3D" variant won because a flat fixed-width layout beat
pointer-chasing vectors of strings; multi-lane shortlex tuples are the
modern analogue of the *slow* layout — every merge-grade compare walks lanes
one by one, and ``lex_rank_count`` pays an O(|a|·|b|·L) broadcast compare.
This module collapses a tuple ``(length, lane0, lane1, ...)`` into at most
two uint32 *rank-key* lanes whose unsigned order equals the tuple's
``lex_gt_lanes`` order, so every merge rank becomes a searchsorted:

  * every lane first embeds into uint32 by the canonical per-lane key
    transform ``lex.to_order_bits`` (``bias_to_u32`` is its re-export):
    unsigned ints pass through, signed ints shift by 2^(bits-1), float32
    takes the IEEE total-order flip with ``-0.0`` normalised to ``+0.0``
    and every NaN canonicalised above ``+inf`` — so packed unsigned order
    *is* ``lex_gt_lanes`` order, the packed plane being the
    concatenated-bits special case of the one comparator representation;
  * biased lanes then concatenate big-endian into a 64-bit budget rendered
    as a ``(hi, lo)`` uint32 pair — or a single uint32 when the total bit
    width fits 32, which unlocks ``jnp.searchsorted`` natively. Tight widths
    come from ``max_values`` (e.g. the shortlex length lane needs
    ``bit_length(4·lanes)`` bits, not 32);
  * when the tuple does **not** fit the budget the packed pair is still an
    order-preserving *prefix* filter: compares resolve on it except for
    prefix-equal elements, which tie-break lane-wise on the first partially
    covered lane onward (``packed_cmp_lanes`` builds that minimal compare
    list, and falls back to the raw lanes when packing cannot shorten it).

Ranks on the compare list come from ``lex_searchsorted`` — a vectorised
binary search (O(log n) gather rounds) that replaces the broadcast compare
at every granularity: the pipeline run merge, the distributed sample-sort
destination step and odd-even 'take' merge, and the Pallas merge-path run
kernel's diagonal partition (``kernels/runmerge_kernel.py``).

``kernels/lex.py``'s lane-wise ``lex_rank_count``/``lex_merge_take`` remain
the differential oracle these fast paths are tested against.

Float caveats: the NaN canonicalisation collapses distinct NaN payloads
onto one order slot, and ``unpack_rank_keys`` returns ``+0.0`` for a packed
``-0.0`` and the canonical quiet NaN for the collapsed NaN slot; the packed
*sort* path in ``ops.sort_lex`` therefore conserves float bits by sorting
``(packed keys, iota)`` and gathering the original lanes through the
permutation instead of unpacking.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from .lex import from_order_bits, lex_gt_lanes, to_order_bits

__all__ = [
    "PackPlan", "PackedKeys", "plan_pack", "bias_to_u32",
    "pack_rank_keys", "unpack_rank_keys", "packed_cmp_lanes",
    "cmp_from_packed", "pack_shortlex", "shortlex_max_values",
    "lex_searchsorted", "packed_searchsorted", "merge_take_packed",
]

# two uint32 rank-key lanes — the budget the ISSUE's "u64 shortlex key" fits
# in without enabling x64 (jax keeps uint64 disabled by default)
_BUDGET_BITS = 64


class PackPlan(NamedTuple):
    """Static description of how a lane tuple maps into the rank-key budget.

    ``bits``: biased width of every input lane; ``take``: how many of those
    bits land inside the 64-bit budget (0 once exhausted); ``exact``: the
    whole tuple fits, so packed order *is* the tuple order; ``covered``:
    leading lanes whose bits are fully inside the budget (the tie-break
    suffix starts at ``lanes[covered]``); ``n_packed``: 1 when the total
    fits one uint32 lane, else 2."""

    bits: Tuple[int, ...]
    take: Tuple[int, ...]
    exact: bool
    covered: int
    n_packed: int


class PackedKeys(NamedTuple):
    """``pack_rank_keys`` result: 1-2 uint32 arrays + the static plan."""

    lanes: Tuple
    plan: PackPlan


def _lane_bits(dtype, max_value: Optional[int]) -> int:
    if max_value is not None:
        if not jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
            raise TypeError("max_values only applies to integer lanes "
                            "(a bounded float lane would pack by truncation)")
        if max_value < 0:
            raise ValueError("max_values entries must be >= 0")
        return max(1, int(max_value).bit_length())
    dtype = jnp.dtype(dtype)
    if dtype == jnp.dtype(jnp.float32):
        return 32
    if jnp.issubdtype(dtype, jnp.integer):
        bits = dtype.itemsize * 8
        if bits > 32:
            raise TypeError(f"{dtype} lanes exceed the uint32 bias range")
        return bits
    raise TypeError(f"cannot pack lanes of dtype {dtype}")


def _norm_max_values(n_lanes: int, max_values):
    if max_values is None:
        return (None,) * n_lanes
    max_values = tuple(max_values)
    if len(max_values) != n_lanes:
        raise ValueError("max_values must have one entry per lane")
    return max_values


def plan_pack(dtypes, max_values=None) -> PackPlan:
    """Pure-static packing plan for lanes of ``dtypes``.

    ``max_values``: optional per-lane upper bounds. A bounded lane promises
    its values lie in ``[0, max_value]`` (the caller's contract, like a
    bucket capacity) and packs in ``bit_length(max_value)`` bits instead of
    the full dtype width."""
    dtypes = tuple(jnp.dtype(d) for d in dtypes)
    max_values = _norm_max_values(len(dtypes), max_values)
    bits = tuple(_lane_bits(d, m) for d, m in zip(dtypes, max_values))
    budget = _BUDGET_BITS
    take, covered, partial_seen = [], 0, False
    for b in bits:
        w = min(b, budget)
        take.append(w)
        budget -= w
        if w == b and not partial_seen:
            covered += 1
        else:
            partial_seen = True
    total = sum(bits)
    return PackPlan(bits=bits, take=tuple(take), exact=total <= _BUDGET_BITS,
                    covered=covered, n_packed=1 if total <= 32 else 2)


# The bias IS the canonical key transform — it was hoisted into
# ``kernels/lex.py`` so every comparator tier (lane-wise, packed, Pallas,
# mesh) shares one definition of order bits. The names stay exported here
# because packing literature and this module's callers say "bias".
bias_to_u32 = to_order_bits
_unbias = from_order_bits


def _shl64_or(hi, lo, w: int, v):
    """(hi, lo) <<= w, then OR ``v`` (< 2^w) into the low bits. ``w`` is a
    static python int in [1, 32]; the caller's budget bookkeeping guarantees
    no real bits ever shift off the top."""
    if w == 32:
        return lo, v
    return (hi << w) | (lo >> (32 - w)), (lo << w) | v


def pack_rank_keys(lanes, max_values=None) -> PackedKeys:
    """Pack parallel lanes (lane 0 most significant) into 1-2 uint32
    rank-key arrays whose unsigned lex order equals — or, past the budget,
    prefix-filters — the lanes' ``lex_gt_lanes`` order. Works elementwise on
    any common shape."""
    lanes = list(lanes)
    if not lanes:
        raise ValueError("need at least one lane")
    max_values = _norm_max_values(len(lanes), max_values)
    plan = plan_pack([a.dtype for a in lanes], max_values)
    if plan.n_packed == 1:
        acc = None
        for a, mv, w in zip(lanes, max_values, plan.take):
            v = bias_to_u32(a, mv)
            acc = v if acc is None else ((acc << w) | v)
        return PackedKeys((acc,), plan)
    shape = jnp.broadcast_shapes(*[a.shape for a in lanes])
    hi = jnp.zeros(shape, jnp.uint32)
    lo = jnp.zeros(shape, jnp.uint32)
    for a, mv, b, w in zip(lanes, max_values, plan.bits, plan.take):
        if w == 0:
            break
        v = bias_to_u32(a, mv)
        if w < b:
            v = v >> (b - w)  # prefix filter: keep the top bits only
        hi, lo = _shl64_or(hi, lo, w, v)
    return PackedKeys((hi, lo), plan)


def unpack_rank_keys(packed_lanes, dtypes, max_values=None):
    """Invert :func:`pack_rank_keys` (exact plans only): recover the
    original lanes, bit-identical for integer dtypes (``-0.0`` comes back as
    ``+0.0`` for floats — see module docstring)."""
    dtypes = tuple(dtypes)
    max_values = _norm_max_values(len(dtypes), max_values)
    plan = plan_pack(dtypes, max_values)
    if not plan.exact:
        raise ValueError("cannot unpack a lossy (inexact) rank-key packing")
    packed_lanes = list(packed_lanes)
    if len(packed_lanes) != plan.n_packed:
        raise ValueError(f"expected {plan.n_packed} packed lanes")
    out = []
    if plan.n_packed == 1:
        acc = packed_lanes[0]
        for dt, mv, w in reversed(list(zip(dtypes, max_values, plan.take))):
            mask = jnp.uint32((1 << w) - 1) if w < 32 else jnp.uint32(0xFFFFFFFF)
            out.append(_unbias(acc & mask, dt, mv))
            acc = jnp.zeros_like(acc) if w == 32 else acc >> w
        return list(reversed(out))
    hi, lo = packed_lanes
    for dt, mv, w in reversed(list(zip(dtypes, max_values, plan.take))):
        if w == 32:
            val, hi, lo = lo, jnp.zeros_like(hi), hi
        else:
            val = lo & jnp.uint32((1 << w) - 1)
            lo = (lo >> w) | (hi << (32 - w))
            hi = hi >> w
        out.append(_unbias(val, dt, mv))
    return list(reversed(out))


def packed_cmp_lanes(lanes, max_values=None):
    """The minimal compare-lane list for ``lanes``: the packed rank keys
    alone when the packing is exact; the packed prefix + the lane-wise
    tie-break suffix (first partially covered lane onward) when it is not;
    the raw lanes when packing cannot shorten the list (including lanes of
    a dtype the bias does not support — the binary-search rank then walks
    the lanes themselves, still searchsorted-fast). Lex order over the
    result always equals ``lex_gt_lanes`` order over ``lanes``."""
    lanes = list(lanes)
    try:
        pk = pack_rank_keys(lanes, max_values)
    except TypeError:
        return lanes
    return cmp_from_packed(pk.lanes, lanes, max_values)


def cmp_from_packed(packed_lanes, lanes, max_values=None):
    """Assemble :func:`packed_cmp_lanes`'s result from rank keys packed
    earlier (e.g. inside the fused bucketize program) — same selection rule,
    no re-pack."""
    lanes = list(lanes)
    plan = plan_pack([a.dtype for a in lanes], max_values)
    packed_lanes = list(packed_lanes)
    if plan.exact:
        return packed_lanes
    cand = packed_lanes + lanes[plan.covered:]
    return cand if len(cand) <= len(lanes) else lanes


def shortlex_max_values(n_key_lanes: int):
    """``max_values`` for the pipeline's shortlex tuple ``(length, lane0,
    ..., laneL-1)``: byte length is bounded by ``4 * L`` (the packed width),
    key lanes are full uint32."""
    return (4 * n_key_lanes,) + (None,) * n_key_lanes


def pack_shortlex(lengths, keys) -> PackedKeys:
    """Pack the shortlex tuple of a sorted run — ``lengths`` (n,) int32 byte
    lengths, ``keys`` (n, L) uint32 packed words — into rank keys with the
    tight length-lane width."""
    lanes = [lengths] + [keys[:, l] for l in range(keys.shape[1])]
    return pack_rank_keys(lanes, shortlex_max_values(keys.shape[1]))


def lex_searchsorted(a_lanes, v_lanes, side: str = "left"):
    """Vectorised multi-lane ``searchsorted``: for every lex tuple of
    ``v_lanes``, its insertion point into the lex-sorted tuples of
    ``a_lanes``. O(log |a|) rounds, each one gather + compare per lane —
    the merge-path rank that replaces ``lex_rank_count``'s O(|a|·|v|·L)
    broadcast. Single-lane inputs take ``jnp.searchsorted`` natively."""
    if side not in ("left", "right"):
        raise ValueError(f"unknown side {side!r}")
    a_lanes, v_lanes = list(a_lanes), list(v_lanes)
    if len(a_lanes) != len(v_lanes):
        raise ValueError("a_lanes and v_lanes must have the same arity")
    if len(a_lanes) == 1:
        return jnp.searchsorted(a_lanes[0], v_lanes[0], side=side)
    n = a_lanes[0].shape[0]
    shape = v_lanes[0].shape
    lo = jnp.zeros(shape, jnp.int32)
    if n == 0:
        return lo
    hi = jnp.full(shape, n, jnp.int32)
    for _ in range(int(n).bit_length() + 1):
        mid = (lo + hi) >> 1
        mid_c = jnp.minimum(mid, n - 1)
        a_mid = [a[mid_c] for a in a_lanes]
        if side == "left":
            pred = lex_gt_lanes(v_lanes, a_mid)       # a[mid] <  v
        else:
            pred = ~lex_gt_lanes(a_mid, v_lanes)      # a[mid] <= v
        pred = pred & (mid < hi)                      # freeze once converged
        lo = jnp.where(pred, mid + 1, lo)
        hi = jnp.where(pred, hi, mid)
    return lo


def packed_searchsorted(a_lanes, v_lanes, side: str = "left",
                        max_values=None):
    """:func:`lex_searchsorted` over the packed compare lists of both tuple
    sets (``a_lanes`` must be lex-sorted). The shared rank step of the
    distributed destination search and every packed merge."""
    return lex_searchsorted(packed_cmp_lanes(a_lanes, max_values),
                            packed_cmp_lanes(v_lanes, max_values), side=side)


def merge_take_packed(a_lanes, b_lanes, n_cmp: Optional[int] = None,
                      max_values=None):
    """Merge two *sorted* lex-tuple runs via packed merge-path ranks + one
    scatter — the searchsorted-fast drop-in for ``lex_merge_take`` (same
    rank/tie protocol: equal tuples take a-before-b, every output slot is
    written exactly once; runs may differ in length).

    ``n_cmp``: when given, the leading ``n_cmp`` lanes are used as the
    compare list as-is (the caller pre-packed them — e.g. the pipeline
    tournament scatters rank keys alongside the data so later rounds skip
    re-packing); otherwise the compare list is packed here from *all* lanes
    (trailing payload lanes tie-break exactly as in ``lex_merge_take``)."""
    a_lanes, b_lanes = list(a_lanes), list(b_lanes)
    if len(a_lanes) != len(b_lanes):
        raise ValueError("runs must have the same lane arity")
    na, nb = a_lanes[0].shape[0], b_lanes[0].shape[0]
    if n_cmp is None:
        cmp_a = packed_cmp_lanes(a_lanes, max_values)
        cmp_b = packed_cmp_lanes(b_lanes, max_values)
    else:
        cmp_a, cmp_b = a_lanes[:n_cmp], b_lanes[:n_cmp]
    rank_a = jnp.arange(na) + lex_searchsorted(cmp_b, cmp_a, side="left")
    rank_b = jnp.arange(nb) + lex_searchsorted(cmp_a, cmp_b, side="right")
    out = []
    for a, b in zip(a_lanes, b_lanes):
        o = jnp.zeros((na + nb,), a.dtype)
        out.append(o.at[rank_a].set(a).at[rank_b].set(b))
    return out
