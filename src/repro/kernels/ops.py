"""Public jit'd wrappers around the Pallas sorting kernels.

Entry points:
  * ``sort(x)`` / ``sort_kv(keys, vals)`` — the unified front-end. Accepts
    1-D arrays or (rows, cols) batches of any width and picks the engine from
    a small cost model (``choose_plan``): single-tile rows run the OETS
    kernel, single-block pow2-padded rows the bitonic kernel, and anything
    wider the hierarchical block sort (``core/blocksort.py`` — block-local
    sort + cross-block odd-even merge rounds). ``algorithm``/``block_size``
    override the model.
  * ``sort_rows`` / ``sort_rows_kv`` — the single-block row kernels
    (every row padded to one VMEM block; width is bounded by the tile).
  * ``partition_rows`` — splitter bucketing (the paper's distribute step).

These wrappers handle everything the raw kernels require of their caller:
lane padding (cols -> multiple of 128 for OETS, next pow2 >= 128 for
bitonic) with per-dtype +inf/max sentinels so padding sinks to the row tail,
sublane padding (rows -> multiple of the 8-row block), and automatic
``interpret=True`` on CPU (this container), compiled on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .bitonic_kernel import bitonic_rows_kv_pallas, bitonic_rows_pallas
from .oets_kernel import oets_rows_kv_pallas, oets_rows_pallas
from .partition_kernel import partition_rows_pallas

__all__ = ["sort", "sort_kv", "choose_plan", "sort_rows", "sort_rows_kv",
           "partition_rows"]

_LANES = 128
_SUBLANES = 8
# widest row the single-block kernels handle before the hierarchical path
# wins: one pow2 VMEM block of 1024 lanes (bitonic: 55 phases; beyond this
# blocksort's local-sort + merge-round phase count is strictly lower).
_MAX_SINGLE_BLOCK = 1024


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _pad_cols(x, target):
    pad = target - x.shape[1]
    if pad == 0:
        return x
    fill = jnp.full((x.shape[0], pad), _sentinel(x.dtype), x.dtype)
    return jnp.concatenate([x, fill], axis=1)


def _pad_rows(x, multiple):
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0)


def _next_pow2(n):
    return 1 << max(0, (n - 1).bit_length())


def _as_rows(x):
    """Promote a 1-D array to a single kernel row; returns (2-D view, was_1d)."""
    if x.ndim == 1:
        return x[None, :], True
    if x.ndim == 2:
        return x, False
    raise ValueError("expected a 1-D or 2-D array")


def choose_plan(cols: int, algorithm: str = "auto",
                block_size: int | None = None):
    """Pick (algorithm, block_size) for ``cols``-wide rows.

    The cost model orders the engines by total comparator phases per row:
    ``oets`` (cols phases) only pays off within one lane tile where its
    padding is tightest; ``bitonic`` (log^2 phases, pow2 padding) up to one
    VMEM block; ``blocksort`` beyond, where padding to a single giant block
    would explode phase count and VMEM. Explicit ``algorithm`` overrides."""
    if algorithm != "auto":
        return algorithm, block_size
    if cols <= _LANES:
        return "oets", None
    if _next_pow2(cols) <= _MAX_SINGLE_BLOCK:
        return "bitonic", None
    return "blocksort", block_size


def sort(x, algorithm: str = "auto", block_size: int | None = None,
         interpret: bool | None = None):
    """Sort a 1-D array or each row of a (rows, cols) array ascending.

    ``algorithm``: 'auto' (cost model), 'oets', 'bitonic', or 'blocksort'.
    ``block_size``: blocksort block override (power of two >= 128).
    """
    x2, vec = _as_rows(x)
    if 0 in x2.shape:
        return x
    algo, block = choose_plan(x2.shape[1], algorithm, block_size)
    if algo == "blocksort":
        from ..core.blocksort import block_sort  # lazy: core imports kernels
        out = block_sort(x2, block_size=block, interpret=interpret)
    else:
        out = sort_rows(x2, algorithm=algo, interpret=interpret)
    return out[0] if vec else out


def sort_kv(keys, vals, algorithm: str = "auto",
            block_size: int | None = None, interpret: bool | None = None):
    """Key-value counterpart of :func:`sort`; ``vals`` rides the keys'
    permutation (equal keys may permute their payloads)."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    k2, vec = _as_rows(keys)
    v2, _ = _as_rows(vals)
    if 0 in k2.shape:
        return keys, vals
    algo, block = choose_plan(k2.shape[1], algorithm, block_size)
    if algo == "blocksort":
        from ..core.blocksort import block_sort_kv
        ok, ov = block_sort_kv(k2, v2, block_size=block, interpret=interpret)
    else:
        ok, ov = sort_rows_kv(k2, v2, algorithm=algo, interpret=interpret)
    return (ok[0], ov[0]) if vec else (ok, ov)


def sort_rows(x, algorithm: str = "oets", interpret: bool | None = None):
    """Sort each row of a (rows, cols) array ascending with a single-block
    Pallas kernel (every row padded to one VMEM block).

    ``algorithm``: 'oets' (paper-faithful) or 'bitonic' (beyond-paper).
    """
    interpret = _auto_interpret(interpret)
    rows, cols = x.shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    xp = _pad_rows(_pad_cols(x, target), _SUBLANES)
    out = fn(xp, interpret=interpret)
    return out[:rows, :cols]


def sort_rows_kv(keys, vals, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise key-value sort; ``vals`` must share ``keys``' shape/rows."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_kv_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_kv_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    kp = _pad_rows(_pad_cols(keys, target), _SUBLANES)
    # vals pad with their own sentinel on purpose: the kernels compare
    # (key, val) lexicographically, so the padding pair (max, max) stays
    # strictly maximal and can never displace a real payload even when real
    # keys equal the key sentinel. Do not "simplify" to zero padding.
    vp = _pad_rows(_pad_cols(vals, target), _SUBLANES)
    ok, ov = fn(kp, vp, interpret=interpret)
    return ok[:rows, :cols], ov[:rows, :cols]


def partition_rows(keys, splitters, interpret: bool | None = None):
    """Bucket each element of (rows, cols) int32 ``keys`` by sorted
    ``splitters`` (the paper's distribute-into-sub-arrays step).

    Returns (bucket_ids (rows, cols), counts (rows, n_buckets)) with
    n_buckets = len(splitters) + 1. bucket id = #splitters <= key."""
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    n_spl = int(splitters.shape[0])
    n_buckets = n_spl + 1
    spl_pad = jnp.full((1, max(_LANES, -(-n_spl // _LANES) * _LANES)),
                       jnp.iinfo(jnp.int32).max, jnp.int32)
    spl_pad = spl_pad.at[0, :n_spl].set(splitters.astype(jnp.int32))
    cols_p = max(_LANES, -(-cols // _LANES) * _LANES)
    xp = _pad_rows(_pad_cols(keys.astype(jnp.int32), cols_p), _SUBLANES)
    bid, cnt = partition_rows_pallas(
        xp, spl_pad, n_splitters=n_spl, n_buckets=n_buckets, interpret=interpret)
    # Padded *cols* of real rows are sentinels (int32 max) and land in the top
    # bucket — subtract them there. Padded *rows* are zero-filled (their
    # elements land in bucket 0, not the top bucket), so the correction must
    # only touch the real rows or it drives their top-bucket count negative.
    pad_cols = cols_p - cols
    if pad_cols:
        cnt = cnt.at[:rows, n_buckets - 1].add(-pad_cols)
    return bid[:rows, :cols], cnt[:rows]
