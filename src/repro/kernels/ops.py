"""Public jit'd wrappers around the Pallas sorting kernels.

Entry points:
  * ``sort(x)`` / ``sort_kv(keys, vals)`` — the unified front-end. Accepts
    1-D arrays or (rows, cols) batches of any width and picks the engine from
    a small cost model (``choose_plan``): single-tile rows run the OETS
    kernel, single-block pow2-padded rows the bitonic kernel, and anything
    wider the hierarchical block sort (``core/blocksort.py`` — block-local
    sort + cross-block odd-even merge rounds). ``algorithm``/``block_size``
    override the model.
  * ``sort_lex(keys_lanes, vals=None)`` — the variadic lexicographic
    front-end: sorts tuples of same-shape arrays lane-by-lane (lane 0 most
    significant), the multi-character word keys of the paper's pipeline
    (``core/packing.py``). Same engine tiers as ``sort``, plus an
    ``engine='auto'|'lanes'|'packed'`` routing knob: 'packed' collapses the
    tuple into 1-2 uint32 rank-key lanes (``kernels/keypack.py``), sorts
    those, and unpacks (integer tuples) or gathers the original lanes
    through the sorted permutation (float tuples, conserving every bit) —
    chosen automatically when the tuple fits the 2-lane budget with fewer
    packed than original lanes.
  * ``merge_sorted(a, b)`` / ``merge_sorted_lex(a_lanes, b_lanes)`` — the
    run-merge front-end shared by every granularity (pipeline run
    tournament, distributed 'take' merge and final combine): 'packed'
    (rank-key searchsorted + one scatter), 'kernel' (the block-parallel
    Pallas merge-path kernel, ``kernels/runmerge_kernel.py``), or 'lanes'
    (the ``lex_merge_take`` broadcast oracle).
  * ``segmented_sort(keys, counts)`` — the fused bucket pipeline: one
    batched lex kernel launch over a whole (num_buckets, capacity, lanes)
    bucket tensor with per-bucket count masking (``core/bucketing``'s
    'pallas' path).
  * ``distribute(keys)`` / ``bucketize(keys, capacity)`` — the paper's
    phases 1-2 on device: the Pallas length-histogram + stable-rank pass
    (``kernels/distribute_kernel.py``) plus one scatter places every packed
    word into its per-length bucket — the ingest counterpart of
    ``segmented_sort``, replacing the host dict loop of
    ``core/bucketing.bucketize_words``.
  * ``sort_rows`` / ``sort_rows_kv`` / ``sort_rows_lex`` — the single-block
    row kernels (every row padded to one VMEM block; width bounded by the
    tile).
  * ``partition_rows`` — splitter bucketing (the paper's distribute step).

Beyond one device, ``core/distributed.py`` lifts these same tiers to the
mesh: ``distributed_sort``/``distributed_sort_lex`` pick between odd-even
block sort and splitter sample sort with a ``choose_engine`` cost model
mirroring ``choose_plan``, and run this module's ``sort_lex`` as the
device-local sort on TPU.

These wrappers handle everything the raw kernels require of their caller:
lane padding (cols -> multiple of 128 for OETS, next pow2 >= 128 for
bitonic) with per-dtype lex-maximal sentinels so padding sinks to the row
tail, sublane padding (rows -> multiple of the 8-row block), and automatic
``interpret=True`` on CPU (this container), compiled on TPU.

Sentinel / dtype contract: padding uses the dtype's lex-maximal value under
the canonical total order of ``kernels/lex.py`` (``iinfo.max`` for ints —
including signed, where it is the positive max, never -1 — and for floats
the all-ones-bits NaN, which the order places strictly above every other
value). Real elements *equal* to the sentinel still sort correctly:
key-only outputs are sliced back to the real width, and kv/lex payload
lanes participate in the compare as final tie-breaks, keeping the
all-sentinel padding tuple strictly maximal.

float32 NaN contract (``jnp.sort``-equivalent): every engine at every tier
compares the canonical order bits of ``kernels/lex.to_order_bits``, so NaNs
— all bit patterns, either sign — sort strictly above ``+inf`` and sink to
the tail, ``-0.0`` and ``+0.0`` compare equal (either may precede the
other), and the output is always a bit-level permutation of the input:
engines compare order bits but swap the raw values, so NaN payload bits and
``-0.0`` signs are conserved, never canonicalised. Distinct NaN bit
patterns compare equal, so their relative order is unspecified — exactly
``jnp.sort``'s observable contract. ``tests/test_ops_dtypes.py`` and the
``nan`` generator of the conformance matrix (``tests/test_conformance.py``)
pin this on every (op, engine, mode) cell.
"""

from __future__ import annotations

import functools
import logging
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bitonic_kernel import bitonic_rows_lex_pallas
from .distribute_kernel import distribute_rows_pallas
from .keypack import (merge_take_packed, pack_rank_keys, plan_pack,
                      unpack_rank_keys)
from .lex import lex_merge_take, sentinel_for
from .oets_kernel import oets_rows_lex_pallas
from .partition_kernel import partition_rows_pallas
from .kway_kernel import merge_runs_kway_pallas, merge_runs_kway_take
from .runmerge_kernel import DEFAULT_MERGE_BLOCK, merge_runs_lex_pallas

__all__ = ["sort", "sort_kv", "sort_lex", "segmented_sort", "distribute",
           "bucketize", "BucketizeResult", "scatter_to_buckets",
           "choose_plan", "choose_lex_engine",
           "merge_sorted", "merge_sorted_lex", "choose_merge_engine",
           "merge_runs_lex", "choose_kway_engine",
           "pallas_lowering", "execution_provenance",
           "sort_rows", "sort_rows_kv", "sort_rows_lex", "partition_rows"]

log = logging.getLogger("repro.kernels")

_LANES = 128
_SUBLANES = 8
# widest row the single-block kernels handle before the hierarchical path
# wins: one pow2 VMEM block of 1024 lanes (bitonic: 55 phases; beyond this
# blocksort's local-sort + merge-round phase count is strictly lower).
_MAX_SINGLE_BLOCK = 1024


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def pallas_lowering(interpret: bool | None = None) -> str:
    """How the Pallas kernel bodies of this module execute for a given
    ``interpret`` request: ``'interpret'`` (the Pallas interpreter, unrolled
    into the surrounding XLA program — the only option on CPU) or
    ``'compiled'`` (native Mosaic/Triton lowering on TPU/GPU). ``None``
    resolves the same auto rule every op front-end uses."""
    return "interpret" if _auto_interpret(interpret) else "compiled"


def execution_provenance(interpret: bool | None = None,
                         mode: str | None = None) -> dict:
    """Provenance of a run through these ops on this host: the fields every
    benchmark record and conformance result is stamped with so numbers are
    only ever compared like-with-like (``benchmarks/gate.py``,
    ``repro.testing``). ``mode`` is the caller's execution-mode label (e.g.
    ``'interpret-cpu'``); when omitted it is derived from the backend and
    the resolved Pallas lowering."""
    backend = jax.default_backend()
    lowering = pallas_lowering(interpret)
    dev = jax.devices()[0]
    return {
        "backend": backend,
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "pallas": lowering,
        "mode": mode or ("interpret-" + backend if lowering == "interpret"
                         else "compiled-" + backend),
        "jax": jax.__version__,
    }


# shared with the kernel modules (kernels/lex.py holds the definition so the
# per-kernel modules never import this front-end back — no cycle)
_sentinel = sentinel_for


def _pad_cols(x, target):
    pad = target - x.shape[1]
    if pad == 0:
        return x
    fill = jnp.full((x.shape[0], pad), _sentinel(x.dtype), x.dtype)
    return jnp.concatenate([x, fill], axis=1)


def _pad_rows(x, multiple):
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0)


def _next_pow2(n):
    return 1 << max(0, (n - 1).bit_length())


def _as_rows(x):
    """Promote a 1-D array to a single kernel row; returns (2-D view, was_1d)."""
    if x.ndim == 1:
        return x[None, :], True
    if x.ndim == 2:
        return x, False
    raise ValueError("expected a 1-D or 2-D array")


def choose_plan(cols: int, algorithm: str = "auto",
                block_size: int | None = None):
    """Pick (algorithm, block_size) for ``cols``-wide rows.

    The cost model orders the engines by total comparator phases per row:
    ``oets`` (cols phases) only pays off within one lane tile where its
    padding is tightest; ``bitonic`` (log^2 phases, pow2 padding) up to one
    VMEM block; ``blocksort`` beyond, where padding to a single giant block
    would explode phase count and VMEM. The model is width-driven only —
    lex lane count scales every engine's compare cost by the same factor,
    so the tier boundaries do not move. Explicit ``algorithm`` overrides."""
    if algorithm != "auto":
        return algorithm, block_size
    if cols <= _LANES:
        return "oets", None
    if _next_pow2(cols) <= _MAX_SINGLE_BLOCK:
        return "bitonic", None
    return "blocksort", block_size


def sort(x, algorithm: str = "auto", block_size: int | None = None,
         interpret: bool | None = None):
    """Sort a 1-D array or each row of a (rows, cols) array ascending.

    ``algorithm``: 'auto' (cost model), 'oets', 'bitonic', or 'blocksort'.
    ``block_size``: blocksort block override (power of two >= 128).
    """
    (out,) = sort_lex((x,), algorithm=algorithm, block_size=block_size,
                      interpret=interpret)
    return out


def sort_kv(keys, vals, algorithm: str = "auto",
            block_size: int | None = None, interpret: bool | None = None):
    """Key-value counterpart of :func:`sort`; ``vals`` rides the keys'
    permutation as the final lex tie-break (equal (key, val) pairs are
    interchangeable)."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    lanes, ov = sort_lex((keys,), vals=vals, algorithm=algorithm,
                         block_size=block_size, interpret=interpret)
    return lanes[0], ov


def choose_lex_engine(dtypes, max_values=None, engine: str = "auto") -> str:
    """Pick the lane engine for :func:`sort_lex` — ``choose_plan``'s cost
    model at tuple granularity. 'packed' wins exactly when the rank-key
    packing is lossless *and* shrinks the comparator's lane count: every
    swap network phase moves and compares each lane, so fewer lanes is
    strictly less work, while a lossy packing would have to carry the
    original lanes as tie-breaks and lose. Float32 lanes route like any
    other: their order bits are the canonical comparator representation
    (``kernels/lex.to_order_bits``), and :func:`sort_lex` conserves their
    bits by gathering the originals through the packed permutation instead
    of unpacking. Explicit ``engine`` overrides, but never unsoundly: a
    'packed' request that the plan cannot honour exactly falls back to
    'lanes'."""
    if engine not in ("auto", "lanes", "packed"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "lanes":
        return "lanes"
    dtypes = tuple(jnp.dtype(d) for d in dtypes)
    try:
        plan = plan_pack(dtypes, max_values)
    except TypeError:
        return "lanes"
    if not plan.exact:
        return "lanes"
    if engine == "packed":
        return "packed"
    return "packed" if plan.n_packed < len(dtypes) else "lanes"


def sort_lex(keys_lanes, vals=None, algorithm: str = "auto",
             block_size: int | None = None, interpret: bool | None = None,
             engine: str = "auto", max_values=None):
    """Lexicographic sort: ``keys_lanes`` is a sequence of same-shape 1-D or
    (rows, cols) arrays, compared element-wise lane-by-lane (lane 0 most
    significant — the lane-packing contract of ``core/packing.py``). All
    lanes and the optional ``vals`` payload travel through one permutation;
    ``vals`` doubles as the final tie-break lane.

    Returns a tuple of sorted lanes, or ``(lanes_tuple, sorted_vals)`` when
    ``vals`` is given. Engine tiers are the same as :func:`sort`
    (``choose_plan`` on the row width); every tier — including the
    multi-block blocksort — runs the full tuple through one Pallas engine.

    ``engine``: 'lanes' (every key lane is its own comparator lane),
    'packed' (collapse the tuple into 1-2 uint32 rank-key lanes via
    ``kernels/keypack.py``, sort those, and unpack — or, when a float lane
    is present, sort ``(rank keys, iota)`` and gather the original lanes
    through the permutation, conserving every float bit; honoured only when
    the packing is lossless, else falls back to 'lanes'), or 'auto'
    (:func:`choose_lex_engine`). ``max_values``: optional per-lane upper
    bounds (hashable tuple) that tighten the packed widths.
    """
    lanes = list(keys_lanes)
    if not lanes:
        raise ValueError("need at least one key lane")
    arrs = lanes + ([vals] if vals is not None else [])
    if any(a.shape != arrs[0].shape for a in arrs[1:]):
        raise ValueError("all lanes (and vals) must have identical shapes")
    eng = choose_lex_engine([a.dtype for a in lanes], max_values, engine)
    if eng == "packed":
        packed = pack_rank_keys(lanes, max_values)
        if any(jnp.issubdtype(a.dtype, jnp.floating) for a in lanes):
            # The float order-bit transform is compare-only (NaN patterns
            # collapse, -0.0 normalises), so unpacking cannot restore the
            # input bits. Sort (rank keys, iota) instead and gather every
            # original lane — and vals — through the permutation: stable,
            # bit-conserving, and the iota tie-break keeps real rows that
            # equal the packed padding prefix left of the padding tail.
            x0 = lanes[0]
            iota = jax.lax.broadcasted_iota(jnp.int32, x0.shape, x0.ndim - 1)
            sorted_packed = sort_lex(tuple(packed.lanes) + (iota,),
                                     algorithm=algorithm,
                                     block_size=block_size,
                                     interpret=interpret, engine="lanes")
            perm = sorted_packed[-1]
            if x0.ndim == 1:
                gather = lambda a: a[perm]
            else:
                gather = lambda a: jnp.take_along_axis(a, perm, axis=-1)
            out = tuple(gather(a) for a in lanes)
            return out if vals is None else (out, gather(vals))
        out_packed = sort_lex(packed.lanes, vals=vals, algorithm=algorithm,
                              block_size=block_size, interpret=interpret,
                              engine="lanes")
        if vals is not None:
            out_packed, out_vals = out_packed
        out = tuple(unpack_rank_keys(out_packed,
                                     [a.dtype for a in lanes], max_values))
        return out if vals is None else (out, out_vals)
    views = [_as_rows(a) for a in arrs]
    vec = views[0][1]
    a2 = [v[0] for v in views]
    if 0 in a2[0].shape:
        out = tuple(arrs)
    else:
        algo, block = choose_plan(a2[0].shape[1], algorithm, block_size)
        if algo == "blocksort":
            from ..core.blocksort import block_sort_lex  # lazy: core imports kernels
            out = block_sort_lex(tuple(a2), block_size=block,
                                 interpret=interpret)
        else:
            out = tuple(sort_rows_lex(a2, algorithm=algo, interpret=interpret))
        if vec:
            out = tuple(o[0] for o in out)
    if vals is None:
        return out
    return out[:-1], out[-1]


def segmented_sort(keys, counts=None, algorithm: str = "auto",
                   block_size: int | None = None,
                   interpret: bool | None = None):
    """Fused on-device segmented sort over the paper's bucket tensor.

    ``keys``: (num_buckets, capacity, lanes) — the 3-D array of the paper's
    distribute step (``core/bucketing.Buckets.keys``), lane-major
    significance. ``counts``: (num_buckets,) real slots per bucket; slots at
    index >= count are masked to the dtype sentinel so they sink to every
    bucket's tail (pass ``None`` when the tensor is already sentinel-padded).

    One batched lex kernel launch sorts *all* buckets: rows = buckets,
    cols = capacity, one comparator lane per packed key lane — any lane
    count and any capacity (the blocksort tier included). Returns the sorted
    (num_buckets, capacity, lanes) tensor.
    """
    if keys.ndim != 3:
        raise ValueError("keys must be (num_buckets, capacity, lanes)")
    if 0 in keys.shape:
        return keys
    n_lanes = keys.shape[2]
    if counts is not None:
        slot = jnp.arange(keys.shape[1], dtype=jnp.int32)
        mask = slot[None, :] >= jnp.asarray(counts, jnp.int32)[:, None]
        keys = jnp.where(mask[..., None], _sentinel(keys.dtype), keys)
    sorted_lanes = sort_lex([keys[..., l] for l in range(n_lanes)],
                            algorithm=algorithm, block_size=block_size,
                            interpret=interpret)
    return jnp.stack(sorted_lanes, axis=-1)


def choose_merge_engine(total: int, engine: str = "auto") -> str:
    """Pick the run-merge engine for a ``total``-element combine —
    ``choose_plan``'s cost model at merge granularity. 'packed' (rank-key
    searchsorted + one scatter) is the jnp fast path on every backend:
    O(n log n) gathers against the broadcast's O(|a|·|b|·L). The Pallas
    merge-path 'kernel' additionally replaces the HBM-wide scatter with
    block-local VMEM merges, which only pays off compiled on TPU and past
    one output tile (below that the packed scatter is a single cheap
    launch). Lane count does not move the boundary — it scales both sides'
    compare cost equally, so the model is size- and backend-driven only.
    'lanes' — the broadcast ``lex_merge_take`` oracle — is never chosen
    automatically. Explicit ``engine`` overrides."""
    if engine != "auto":
        if engine not in ("lanes", "packed", "kernel"):
            raise ValueError(f"unknown engine {engine!r}")
        return engine
    if jax.default_backend() == "tpu" and total > 2 * DEFAULT_MERGE_BLOCK:
        return "kernel"
    return "packed"


@functools.partial(jax.jit, static_argnames=("n_arr", "n_cmp", "max_values"))
def _merge_packed_jit(*arrs, n_arr, n_cmp, max_values):
    return tuple(merge_take_packed(list(arrs[:n_arr]), list(arrs[n_arr:]),
                                   n_cmp=n_cmp, max_values=max_values))


@functools.partial(jax.jit, static_argnames=("n_arr",))
def _merge_lanes_jit(*arrs, n_arr):
    return tuple(lex_merge_take(list(arrs[:n_arr]), list(arrs[n_arr:])))


def merge_sorted_lex(a_lanes, b_lanes, engine: str = "auto",
                     n_cmp: int | None = None, max_values=None,
                     block_size: int | None = None,
                     interpret: bool | None = None):
    """Merge two *sorted* lex-tuple runs (tuples of parallel 1-D arrays, may
    differ in length) into one sorted run — the shared run-merge primitive
    of the pipeline tournament, the distributed 'take' merge, and the
    sample-sort combine.

    Every lane participates in the compare in tuple order (trailing lanes
    are payload tie-breaks, ``kernels/lex.py`` conventions); output is
    bit-identical to ``lex_merge_take`` across engines. ``engine``: 'packed'
    (rank-key searchsorted ranks + one scatter), 'kernel' (the block-parallel
    Pallas merge-path kernel), 'lanes' (the broadcast oracle), or 'auto'
    (:func:`choose_merge_engine`). 'kway' routes the pair through the k-way
    front-end :func:`merge_runs_lex` (its 2-run case — one key-sort +
    gather pass or the streaming kernel per :func:`choose_kway_engine`).
    ``n_cmp``: the leading ``n_cmp`` lanes are pre-packed compare lanes to
    rank on as-is (see ``keypack.merge_take_packed``); ``max_values``:
    per-lane packing bounds (hashable tuple).
    """
    if engine == "kway":
        return merge_runs_lex([a_lanes, b_lanes], n_cmp=n_cmp,
                              max_values=max_values, block_size=block_size,
                              interpret=interpret)
    a_lanes, b_lanes = tuple(a_lanes), tuple(b_lanes)
    if max_values is not None:
        max_values = tuple(max_values)  # static under jit: must be hashable
    if len(a_lanes) != len(b_lanes) or not a_lanes:
        raise ValueError("runs must share a non-zero lane arity")
    if any(x.ndim != 1 for x in a_lanes + b_lanes):
        raise ValueError("runs must be tuples of 1-D arrays")
    if a_lanes[0].shape[0] == 0:
        return b_lanes
    if b_lanes[0].shape[0] == 0:
        return a_lanes
    eng = choose_merge_engine(a_lanes[0].shape[0] + b_lanes[0].shape[0],
                              engine)
    if eng == "lanes":
        return _merge_lanes_jit(*a_lanes, *b_lanes, n_arr=len(a_lanes))
    if eng == "packed":
        return _merge_packed_jit(*a_lanes, *b_lanes, n_arr=len(a_lanes),
                                 n_cmp=n_cmp, max_values=max_values)
    return merge_runs_lex_pallas(a_lanes, b_lanes, n_cmp=n_cmp,
                                 max_values=max_values, block=block_size,
                                 interpret=_auto_interpret(interpret))


def choose_kway_engine(total: int, engine: str = "auto") -> str:
    """Pick the k-way combine tier — :func:`choose_merge_engine`'s model at
    k-run granularity. 'take' (one fused key sort + ONE gather per lane,
    :func:`repro.kernels.kway_kernel.merge_runs_kway_take`) is the jnp
    fast path everywhere: one data pass, one fused dispatch. The Pallas
    streaming 'kernel' additionally keeps the combine in VMEM tiles behind
    double-buffered DMA, which pays off compiled on TPU past one output
    tile, exactly like the 2-way boundary. Explicit ``engine`` overrides
    (e.g. conformance forcing 'kernel' under the interpreter)."""
    if engine != "auto":
        if engine not in ("take", "kernel"):
            raise ValueError(f"unknown k-way engine {engine!r}")
        return engine
    if jax.default_backend() == "tpu" and total > 2 * DEFAULT_MERGE_BLOCK:
        return "kernel"
    return "take"


@functools.partial(jax.jit, static_argnames=("n_arr", "n_runs", "n_cmp",
                                             "max_values"))
def _kway_take_jit(*arrs, n_arr, n_runs, n_cmp, max_values):
    runs = [list(arrs[r * n_arr:(r + 1) * n_arr]) for r in range(n_runs)]
    return merge_runs_kway_take(runs, n_cmp=n_cmp, max_values=max_values)


def merge_runs_lex(runs, engine: str = "auto", n_cmp: int | None = None,
                   max_values=None, block_size: int | None = None,
                   interpret: bool | None = None):
    """Merge k *sorted* lex-tuple runs into one sorted run in a SINGLE pass
    — the streaming replacement for the pipeline tournament's ceil(log2 k)
    pairwise rounds (each of which re-reads and re-writes all the data).

    ``runs``: sequence of equal-arity tuples of parallel 1-D arrays, any
    lengths (empty runs drop statically). ``engine``: 'take' (global
    merge-path ranks + one scatter per lane), 'kernel' (the one-launch
    streaming Pallas kernel, ``kernels/kway_kernel.py``), or 'auto'
    (:func:`choose_kway_engine`). ``n_cmp``/``max_values`` follow
    :func:`merge_sorted_lex`. Output is bit-identical to the tournament and
    the NumPy lexsort oracle across engines."""
    runs = [tuple(r) for r in runs]
    if max_values is not None:
        max_values = tuple(max_values)  # static under jit: must be hashable
    if not runs or not runs[0] or any(len(r) != len(runs[0]) for r in runs):
        raise ValueError("runs must share a non-zero lane arity")
    if any(x.ndim != 1 for r in runs for x in r):
        raise ValueError("runs must be tuples of 1-D arrays")
    nonempty = [r for r in runs if r[0].shape[0]]
    if not nonempty:
        return runs[0]
    if len(nonempty) == 1:
        return nonempty[0]
    total = sum(r[0].shape[0] for r in nonempty)
    eng = choose_kway_engine(total, engine)
    if eng == "kernel":
        return merge_runs_kway_pallas(nonempty, n_cmp=n_cmp,
                                      max_values=max_values,
                                      block=block_size,
                                      interpret=_auto_interpret(interpret))
    return _kway_take_jit(*[x for r in nonempty for x in r],
                          n_arr=len(runs[0]), n_runs=len(nonempty),
                          n_cmp=n_cmp, max_values=max_values)


def merge_sorted(a, b, engine: str = "auto", block_size: int | None = None,
                 interpret: bool | None = None):
    """Key-only special case of :func:`merge_sorted_lex`: merge two sorted
    1-D arrays into one."""
    (out,) = merge_sorted_lex((a,), (b,), engine=engine,
                              block_size=block_size, interpret=interpret)
    return out


def distribute(keys, interpret: bool | None = None):
    """Run the on-device distribute pass over packed words (the paper's
    phases 1-2: count, then assign every element its sub-array slot).

    ``keys``: (n, lanes) uint32 packed words (``core/packing.pack_words``).
    Returns ``(dest, rank, counts)``: ``dest`` (n,) int32 — each word's byte
    length, which *is* its bucket id (buckets are dense per-length, id 0 =
    the empty word); ``rank`` (n,) int32 — the word's stable slot within
    its bucket (arrival order); ``counts`` (num_buckets,) int32 — the
    length histogram, ``num_buckets = 4 * lanes + 1``. All on device; the
    kernel carries running counts across grid steps, so ranks are globally
    stable without a host prefix pass.
    """
    interpret = _auto_interpret(interpret)
    n, lanes = keys.shape
    num_buckets = 4 * lanes + 1
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_buckets,), jnp.int32))
    n_pad = max(_LANES, -(-n // _LANES) * _LANES)
    keys_t = jnp.zeros((lanes, n_pad), jnp.uint32).at[:, :n].set(
        jnp.asarray(keys, jnp.uint32).T)
    dest, rank, counts = distribute_rows_pallas(
        keys_t, n_valid=n, num_buckets=num_buckets, interpret=interpret)
    return dest[0, :n], rank[0, :n], counts[0, :num_buckets]


def _optimistic_capacity(n: int, num_buckets: int) -> int:
    """First-shot capacity for the two-tier autotune: a uniform length
    spread with 4x headroom, rounded to a power of two so repeated sizes
    share jit cache entries. Clamped at ~n/2 so a small bucket count (1-lane
    words have only 5) never degenerates the optimistic tensor to the
    worst case — a distribution skewed past half the input is exactly the
    case the exact-count retry tier exists for."""
    return max(1, min(n, _next_pow2(-(-4 * n // num_buckets)),
                      _next_pow2(-(-n // 2))))


class BucketizeResult(NamedTuple):
    """Result of :func:`bucketize`. ``buckets``
    (num_buckets, capacity, lanes) uint32 — bucket ``l`` holds the words of
    byte length ``l`` in arrival order, unused slots at the sentinel;
    ``counts`` (num_buckets,) int32 *true* per-bucket counts (never inferred
    from sentinel compares); ``dropped`` — host int, the number of elements
    clipped out of the tensor because their bucket exceeded an explicit
    ``capacity`` under ``on_overflow='clip'`` (0 on every other path).
    Indexes like the historical ``(buckets, counts)`` pair."""

    buckets: jax.Array
    counts: jax.Array
    dropped: int


def bucketize(keys, capacity: int | None = None,
              interpret: bool | None = None,
              on_overflow: str = "clip") -> BucketizeResult:
    """Scatter packed words into the paper's dense per-length bucket tensor
    — ``bucketize_words``'s host dict loop as one kernel pass + one device
    scatter.

    ``keys``: (n, lanes) uint32 packed words. ``capacity``: slots per bucket
    (static under jit). ``None`` runs the two-tier autotune: the scatter is
    dispatched immediately at an optimistic capacity (uniform spread + 4x
    headroom) *without* reading the histogram back, then the exact counts —
    already computed by the distribute kernel, never inferred from sentinel
    compares — decide whether a single retry at the true max is needed. On
    the happy path the histogram sync overlaps the in-flight scatter instead
    of blocking its launch; only a skewed length distribution pays the
    second scatter. The autotune path can never overflow.

    ``on_overflow`` is the degrade policy when an *explicit* capacity is
    exceeded — the overflow is never silent:
      * ``'clip'``  — keep the statically sized tensor, drop the excess
                      elements from it (true counts still report them), log
                      a structured warning, and report the loss in
                      ``BucketizeResult.dropped``;
      * ``'raise'`` — raise :class:`repro.runtime.CapacityOverflow` carrying
                      the required capacity, so a supervisor can escalate;
      * ``'retry'`` — re-scatter once at the exact required capacity (the
                      true counts are already on hand) and return with
                      ``dropped == 0``.
    """
    from ..runtime.failure import CapacityOverflow
    if on_overflow not in ("clip", "raise", "retry"):
        raise ValueError(f"unknown on_overflow policy {on_overflow!r}")
    n, lanes = keys.shape
    num_buckets = 4 * lanes + 1
    dest, rank, counts = distribute(keys, interpret=interpret)
    keys = jnp.asarray(keys, jnp.uint32)
    if capacity is None:
        if n == 0:
            capacity = 0
        else:
            capacity = _optimistic_capacity(n, num_buckets)
            buckets = _scatter_to_buckets(keys, dest, rank,
                                          num_buckets=num_buckets,
                                          capacity=capacity)
            true_max = int(jnp.max(counts))  # syncs after the dispatch above
            if true_max <= capacity:
                return BucketizeResult(buckets, counts, 0)
            capacity = true_max
        return BucketizeResult(
            _scatter_to_buckets(keys, dest, rank, num_buckets=num_buckets,
                                capacity=capacity), counts, 0)
    dropped = int(jnp.sum(jnp.maximum(counts - capacity, 0))) if n else 0
    if dropped:
        true_max = int(jnp.max(counts))
        if on_overflow == "raise":
            raise CapacityOverflow(
                f"bucketize overflow: largest bucket holds {true_max} and "
                f"exceeds capacity {capacity} ({dropped} element(s) would "
                f"drop)", capacity, required=true_max, dropped=dropped)
        if on_overflow == "retry":
            log.warning("bucketize overflow: capacity %d -> %d (exact-count "
                        "retry, %d element(s) would have dropped)",
                        capacity, true_max, dropped)
            capacity, dropped = true_max, 0
        else:
            log.warning("bucketize overflow: dropping %d element(s) past "
                        "capacity %d (max bucket holds %d) — pass "
                        "on_overflow='raise'|'retry' for a lossless policy",
                        dropped, capacity, true_max)
    return BucketizeResult(
        _scatter_to_buckets(keys, dest, rank, num_buckets=num_buckets,
                            capacity=capacity), counts, dropped)


@functools.partial(jax.jit, static_argnames=("num_buckets", "capacity"))
def scatter_to_buckets(keys, dest, rank, *, num_buckets, capacity):
    """The traceable core of :func:`bucketize`: one scatter placing word
    ``i`` at ``buckets[dest[i], rank[i]]``, unused slots at the uint32
    sentinel, ranks past ``capacity`` dropped into a discard slot. Pure and
    static-shaped, so it composes under an outer ``jax.jit`` — the
    compiled-mode path of the conformance kit (``repro.testing``) runs
    ``distribute`` + this in one program; :func:`bucketize` itself adds the
    host-synced capacity autotune / overflow policies around it and is
    therefore *not* traceable."""
    n, lanes = keys.shape
    flat = jnp.full((num_buckets * capacity + 1, lanes),
                    jnp.uint32(0xFFFFFFFF), jnp.uint32)
    keep = rank < capacity
    slot = jnp.where(keep, dest * capacity + rank, num_buckets * capacity)
    return flat.at[slot].set(keys)[: num_buckets * capacity].reshape(
        num_buckets, capacity, lanes)


_scatter_to_buckets = scatter_to_buckets


def sort_rows(x, algorithm: str = "oets", interpret: bool | None = None):
    """Sort each row of a (rows, cols) array ascending with a single-block
    Pallas kernel (every row padded to one VMEM block).

    ``algorithm``: 'oets' (paper-faithful) or 'bitonic' (beyond-paper).
    """
    (out,) = sort_rows_lex([x], algorithm=algorithm, interpret=interpret)
    return out


def sort_rows_kv(keys, vals, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise key-value sort; ``vals`` must share ``keys``' shape/rows."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    ok, ov = sort_rows_lex([keys, vals], algorithm=algorithm,
                           interpret=interpret)
    return ok, ov


def sort_rows_lex(arrs, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise lexicographic sort of a list of same-shape (rows, cols)
    arrays through a single-block kernel; returns the sorted list.

    Every array pads with its *own* dtype sentinel on purpose: the kernels
    compare full tuples lexicographically, so the all-sentinel padding tuple
    stays strictly maximal and can never displace a real element even when
    real leading lanes equal the sentinel. Do not "simplify" to zero padding.
    """
    interpret = _auto_interpret(interpret)
    rows, cols = arrs[0].shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_lex_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_lex_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    padded = [_pad_rows(_pad_cols(a, target), _SUBLANES) for a in arrs]
    out = fn(*padded, interpret=interpret)
    return [o[:rows, :cols] for o in out]


def partition_rows(keys, splitters, interpret: bool | None = None):
    """Bucket each element of (rows, cols) int32 ``keys`` by sorted
    ``splitters`` (the paper's distribute-into-sub-arrays step).

    Returns (bucket_ids (rows, cols), counts (rows, n_buckets)) with
    n_buckets = len(splitters) + 1. bucket id = #splitters <= key."""
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    n_spl = int(splitters.shape[0])
    n_buckets = n_spl + 1
    spl_pad = jnp.full((1, max(_LANES, -(-n_spl // _LANES) * _LANES)),
                       jnp.iinfo(jnp.int32).max, jnp.int32)
    spl_pad = spl_pad.at[0, :n_spl].set(splitters.astype(jnp.int32))
    cols_p = max(_LANES, -(-cols // _LANES) * _LANES)
    xp = _pad_rows(_pad_cols(keys.astype(jnp.int32), cols_p), _SUBLANES)
    bid, cnt = partition_rows_pallas(
        xp, spl_pad, n_splitters=n_spl, n_buckets=n_buckets, interpret=interpret)
    # Padded *cols* of real rows are sentinels (int32 max) and land in the top
    # bucket — subtract them there. Padded *rows* are zero-filled (their
    # elements land in bucket 0, not the top bucket), so the correction must
    # only touch the real rows or it drives their top-bucket count negative.
    pad_cols = cols_p - cols
    if pad_cols:
        cnt = cnt.at[:rows, n_buckets - 1].add(-pad_cols)
    return bid[:rows, :cols], cnt[:rows]
