"""Public jit'd wrappers around the Pallas sorting kernels.

Handles everything the raw kernels require of their caller:
  * lane padding (cols -> multiple of 128 for OETS, next pow2 >= 128 for bitonic)
    with per-dtype +inf/max sentinels so padding sinks to the row tail,
  * sublane padding (rows -> multiple of the 8-row block),
  * automatic ``interpret=True`` on CPU (this container), compiled on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .bitonic_kernel import bitonic_rows_kv_pallas, bitonic_rows_pallas
from .oets_kernel import oets_rows_kv_pallas, oets_rows_pallas
from .partition_kernel import partition_rows_pallas

__all__ = ["sort_rows", "sort_rows_kv", "partition_rows"]

_LANES = 128
_SUBLANES = 8


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _pad_cols(x, target):
    pad = target - x.shape[1]
    if pad == 0:
        return x
    fill = jnp.full((x.shape[0], pad), _sentinel(x.dtype), x.dtype)
    return jnp.concatenate([x, fill], axis=1)


def _pad_rows(x, multiple):
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0)


def _next_pow2(n):
    return 1 << max(0, (n - 1).bit_length())


def sort_rows(x, algorithm: str = "oets", interpret: bool | None = None):
    """Sort each row of a (rows, cols) array ascending with a Pallas kernel.

    ``algorithm``: 'oets' (paper-faithful) or 'bitonic' (beyond-paper).
    """
    interpret = _auto_interpret(interpret)
    rows, cols = x.shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    xp = _pad_rows(_pad_cols(x, target), _SUBLANES)
    out = fn(xp, interpret=interpret)
    return out[:rows, :cols]


def sort_rows_kv(keys, vals, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise key-value sort; ``vals`` must share ``keys``' shape/rows."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_kv_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_kv_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    kp = _pad_rows(_pad_cols(keys, target), _SUBLANES)
    vp = _pad_rows(_pad_cols(vals, target), _SUBLANES)  # sentinel vals ignored
    ok, ov = fn(kp, vp, interpret=interpret)
    return ok[:rows, :cols], ov[:rows, :cols]


def partition_rows(keys, splitters, interpret: bool | None = None):
    """Bucket each element of (rows, cols) int32 ``keys`` by sorted
    ``splitters`` (the paper's distribute-into-sub-arrays step).

    Returns (bucket_ids (rows, cols), counts (rows, n_buckets)) with
    n_buckets = len(splitters) + 1. bucket id = #splitters <= key."""
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    n_spl = int(splitters.shape[0])
    n_buckets = n_spl + 1
    spl_pad = jnp.full((1, max(_LANES, -(-n_spl // _LANES) * _LANES)),
                       jnp.iinfo(jnp.int32).max, jnp.int32)
    spl_pad = spl_pad.at[0, :n_spl].set(splitters.astype(jnp.int32))
    cols_p = max(_LANES, -(-cols // _LANES) * _LANES)
    xp = _pad_rows(_pad_cols(keys.astype(jnp.int32), cols_p), _SUBLANES)
    bid, cnt = partition_rows_pallas(
        xp, spl_pad, n_splitters=n_spl, n_buckets=n_buckets, interpret=interpret)
    # padded cols land in the top bucket (sentinel = int32 max); correct the
    # histogram for them before returning
    pad_cols = cols_p - cols
    if pad_cols:
        cnt = cnt.at[:, n_buckets - 1].add(-pad_cols)
    return bid[:rows, :cols], cnt[:rows]
