"""Public jit'd wrappers around the Pallas sorting kernels.

Entry points:
  * ``sort(x)`` / ``sort_kv(keys, vals)`` — the unified front-end. Accepts
    1-D arrays or (rows, cols) batches of any width and picks the engine from
    a small cost model (``choose_plan``): single-tile rows run the OETS
    kernel, single-block pow2-padded rows the bitonic kernel, and anything
    wider the hierarchical block sort (``core/blocksort.py`` — block-local
    sort + cross-block odd-even merge rounds). ``algorithm``/``block_size``
    override the model.
  * ``sort_lex(keys_lanes, vals=None)`` — the variadic lexicographic
    front-end: sorts tuples of same-shape arrays lane-by-lane (lane 0 most
    significant), the multi-character word keys of the paper's pipeline
    (``core/packing.py``). Same engine tiers as ``sort``.
  * ``segmented_sort(keys, counts)`` — the fused bucket pipeline: one
    batched lex kernel launch over a whole (num_buckets, capacity, lanes)
    bucket tensor with per-bucket count masking (``core/bucketing``'s
    'pallas' path).
  * ``distribute(keys)`` / ``bucketize(keys, capacity)`` — the paper's
    phases 1-2 on device: the Pallas length-histogram + stable-rank pass
    (``kernels/distribute_kernel.py``) plus one scatter places every packed
    word into its per-length bucket — the ingest counterpart of
    ``segmented_sort``, replacing the host dict loop of
    ``core/bucketing.bucketize_words``.
  * ``sort_rows`` / ``sort_rows_kv`` / ``sort_rows_lex`` — the single-block
    row kernels (every row padded to one VMEM block; width bounded by the
    tile).
  * ``partition_rows`` — splitter bucketing (the paper's distribute step).

Beyond one device, ``core/distributed.py`` lifts these same tiers to the
mesh: ``distributed_sort``/``distributed_sort_lex`` pick between odd-even
block sort and splitter sample sort with a ``choose_engine`` cost model
mirroring ``choose_plan``, and run this module's ``sort_lex`` as the
device-local sort on TPU.

These wrappers handle everything the raw kernels require of their caller:
lane padding (cols -> multiple of 128 for OETS, next pow2 >= 128 for
bitonic) with per-dtype +inf/max sentinels so padding sinks to the row tail,
sublane padding (rows -> multiple of the 8-row block), and automatic
``interpret=True`` on CPU (this container), compiled on TPU.

Sentinel / dtype contract: padding uses the dtype's maximum (``iinfo.max``
for ints — including signed, where it is the positive max, never -1 — and
``+inf`` for floats). Real elements *equal* to the sentinel still sort
correctly: key-only outputs are sliced back to the real width, and kv/lex
payload lanes participate in the compare as final tie-breaks, keeping the
all-sentinel padding tuple strictly maximal. float32 NaN: the comparator
networks are swap-based, so the output is always a *permutation* of the
input, but NaN compares false against everything and never moves — elements
on opposite sides of a NaN may stay unsorted relative to each other (unlike
``jnp.sort``, which sinks NaNs to the tail). Callers that may see NaNs
should quarantine them first; ``tests/test_ops_dtypes.py`` pins this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitonic_kernel import bitonic_rows_lex_pallas
from .distribute_kernel import distribute_rows_pallas
from .oets_kernel import oets_rows_lex_pallas
from .partition_kernel import partition_rows_pallas

__all__ = ["sort", "sort_kv", "sort_lex", "segmented_sort", "distribute",
           "bucketize", "choose_plan", "sort_rows", "sort_rows_kv",
           "sort_rows_lex", "partition_rows"]

_LANES = 128
_SUBLANES = 8
# widest row the single-block kernels handle before the hierarchical path
# wins: one pow2 VMEM block of 1024 lanes (bitonic: 55 phases; beyond this
# blocksort's local-sort + merge-round phase count is strictly lower).
_MAX_SINGLE_BLOCK = 1024


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _sentinel(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _pad_cols(x, target):
    pad = target - x.shape[1]
    if pad == 0:
        return x
    fill = jnp.full((x.shape[0], pad), _sentinel(x.dtype), x.dtype)
    return jnp.concatenate([x, fill], axis=1)


def _pad_rows(x, multiple):
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    fill = jnp.zeros((pad, x.shape[1]), x.dtype)
    return jnp.concatenate([x, fill], axis=0)


def _next_pow2(n):
    return 1 << max(0, (n - 1).bit_length())


def _as_rows(x):
    """Promote a 1-D array to a single kernel row; returns (2-D view, was_1d)."""
    if x.ndim == 1:
        return x[None, :], True
    if x.ndim == 2:
        return x, False
    raise ValueError("expected a 1-D or 2-D array")


def choose_plan(cols: int, algorithm: str = "auto",
                block_size: int | None = None):
    """Pick (algorithm, block_size) for ``cols``-wide rows.

    The cost model orders the engines by total comparator phases per row:
    ``oets`` (cols phases) only pays off within one lane tile where its
    padding is tightest; ``bitonic`` (log^2 phases, pow2 padding) up to one
    VMEM block; ``blocksort`` beyond, where padding to a single giant block
    would explode phase count and VMEM. The model is width-driven only —
    lex lane count scales every engine's compare cost by the same factor,
    so the tier boundaries do not move. Explicit ``algorithm`` overrides."""
    if algorithm != "auto":
        return algorithm, block_size
    if cols <= _LANES:
        return "oets", None
    if _next_pow2(cols) <= _MAX_SINGLE_BLOCK:
        return "bitonic", None
    return "blocksort", block_size


def sort(x, algorithm: str = "auto", block_size: int | None = None,
         interpret: bool | None = None):
    """Sort a 1-D array or each row of a (rows, cols) array ascending.

    ``algorithm``: 'auto' (cost model), 'oets', 'bitonic', or 'blocksort'.
    ``block_size``: blocksort block override (power of two >= 128).
    """
    (out,) = sort_lex((x,), algorithm=algorithm, block_size=block_size,
                      interpret=interpret)
    return out


def sort_kv(keys, vals, algorithm: str = "auto",
            block_size: int | None = None, interpret: bool | None = None):
    """Key-value counterpart of :func:`sort`; ``vals`` rides the keys'
    permutation as the final lex tie-break (equal (key, val) pairs are
    interchangeable)."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    lanes, ov = sort_lex((keys,), vals=vals, algorithm=algorithm,
                         block_size=block_size, interpret=interpret)
    return lanes[0], ov


def sort_lex(keys_lanes, vals=None, algorithm: str = "auto",
             block_size: int | None = None, interpret: bool | None = None):
    """Lexicographic sort: ``keys_lanes`` is a sequence of same-shape 1-D or
    (rows, cols) arrays, compared element-wise lane-by-lane (lane 0 most
    significant — the lane-packing contract of ``core/packing.py``). All
    lanes and the optional ``vals`` payload travel through one permutation;
    ``vals`` doubles as the final tie-break lane.

    Returns a tuple of sorted lanes, or ``(lanes_tuple, sorted_vals)`` when
    ``vals`` is given. Engine tiers are the same as :func:`sort`
    (``choose_plan`` on the row width); every tier — including the
    multi-block blocksort — runs the full tuple through one Pallas engine.
    """
    lanes = list(keys_lanes)
    if not lanes:
        raise ValueError("need at least one key lane")
    arrs = lanes + ([vals] if vals is not None else [])
    if any(a.shape != arrs[0].shape for a in arrs[1:]):
        raise ValueError("all lanes (and vals) must have identical shapes")
    views = [_as_rows(a) for a in arrs]
    vec = views[0][1]
    a2 = [v[0] for v in views]
    if 0 in a2[0].shape:
        out = tuple(arrs)
    else:
        algo, block = choose_plan(a2[0].shape[1], algorithm, block_size)
        if algo == "blocksort":
            from ..core.blocksort import block_sort_lex  # lazy: core imports kernels
            out = block_sort_lex(tuple(a2), block_size=block,
                                 interpret=interpret)
        else:
            out = tuple(sort_rows_lex(a2, algorithm=algo, interpret=interpret))
        if vec:
            out = tuple(o[0] for o in out)
    if vals is None:
        return out
    return out[:-1], out[-1]


def segmented_sort(keys, counts=None, algorithm: str = "auto",
                   block_size: int | None = None,
                   interpret: bool | None = None):
    """Fused on-device segmented sort over the paper's bucket tensor.

    ``keys``: (num_buckets, capacity, lanes) — the 3-D array of the paper's
    distribute step (``core/bucketing.Buckets.keys``), lane-major
    significance. ``counts``: (num_buckets,) real slots per bucket; slots at
    index >= count are masked to the dtype sentinel so they sink to every
    bucket's tail (pass ``None`` when the tensor is already sentinel-padded).

    One batched lex kernel launch sorts *all* buckets: rows = buckets,
    cols = capacity, one comparator lane per packed key lane — any lane
    count and any capacity (the blocksort tier included). Returns the sorted
    (num_buckets, capacity, lanes) tensor.
    """
    if keys.ndim != 3:
        raise ValueError("keys must be (num_buckets, capacity, lanes)")
    if 0 in keys.shape:
        return keys
    n_lanes = keys.shape[2]
    if counts is not None:
        slot = jnp.arange(keys.shape[1], dtype=jnp.int32)
        mask = slot[None, :] >= jnp.asarray(counts, jnp.int32)[:, None]
        keys = jnp.where(mask[..., None], _sentinel(keys.dtype), keys)
    sorted_lanes = sort_lex([keys[..., l] for l in range(n_lanes)],
                            algorithm=algorithm, block_size=block_size,
                            interpret=interpret)
    return jnp.stack(sorted_lanes, axis=-1)


def distribute(keys, interpret: bool | None = None):
    """Run the on-device distribute pass over packed words (the paper's
    phases 1-2: count, then assign every element its sub-array slot).

    ``keys``: (n, lanes) uint32 packed words (``core/packing.pack_words``).
    Returns ``(dest, rank, counts)``: ``dest`` (n,) int32 — each word's byte
    length, which *is* its bucket id (buckets are dense per-length, id 0 =
    the empty word); ``rank`` (n,) int32 — the word's stable slot within
    its bucket (arrival order); ``counts`` (num_buckets,) int32 — the
    length histogram, ``num_buckets = 4 * lanes + 1``. All on device; the
    kernel carries running counts across grid steps, so ranks are globally
    stable without a host prefix pass.
    """
    interpret = _auto_interpret(interpret)
    n, lanes = keys.shape
    num_buckets = 4 * lanes + 1
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                jnp.zeros((num_buckets,), jnp.int32))
    n_pad = max(_LANES, -(-n // _LANES) * _LANES)
    keys_t = jnp.zeros((lanes, n_pad), jnp.uint32).at[:, :n].set(
        jnp.asarray(keys, jnp.uint32).T)
    dest, rank, counts = distribute_rows_pallas(
        keys_t, n_valid=n, num_buckets=num_buckets, interpret=interpret)
    return dest[0, :n], rank[0, :n], counts[0, :num_buckets]


def bucketize(keys, capacity: int | None = None,
              interpret: bool | None = None):
    """Scatter packed words into the paper's dense per-length bucket tensor
    — ``bucketize_words``'s host dict loop as one kernel pass + one device
    scatter.

    ``keys``: (n, lanes) uint32 packed words. ``capacity``: slots per bucket
    (static under jit); ``None`` sizes it at the exact histogram max, which
    costs one scalar device->host sync — pass an explicit capacity to stay
    inside a single jitted program. Returns ``(buckets, counts)``:
    ``buckets`` (num_buckets, capacity, lanes) uint32 with bucket ``l``
    holding the words of byte length ``l`` in arrival order and all unused
    slots at the sentinel; ``counts`` (num_buckets,) int32 *true* counts —
    when an explicit capacity is exceeded the excess words are dropped from
    the tensor but still counted, so callers detect overflow by
    ``counts.max() > capacity`` (mirrors the distributed exact-count
    protocol: occupancy is never inferred from sentinel compares).
    """
    n, lanes = keys.shape
    num_buckets = 4 * lanes + 1
    dest, rank, counts = distribute(keys, interpret=interpret)
    if capacity is None:
        capacity = max(1, int(jnp.max(counts))) if n else 0
    return _scatter_to_buckets(jnp.asarray(keys, jnp.uint32), dest, rank,
                               num_buckets=num_buckets,
                               capacity=capacity), counts


@functools.partial(jax.jit, static_argnames=("num_buckets", "capacity"))
def _scatter_to_buckets(keys, dest, rank, *, num_buckets, capacity):
    n, lanes = keys.shape
    flat = jnp.full((num_buckets * capacity + 1, lanes),
                    jnp.uint32(0xFFFFFFFF), jnp.uint32)
    keep = rank < capacity
    slot = jnp.where(keep, dest * capacity + rank, num_buckets * capacity)
    return flat.at[slot].set(keys)[: num_buckets * capacity].reshape(
        num_buckets, capacity, lanes)


def sort_rows(x, algorithm: str = "oets", interpret: bool | None = None):
    """Sort each row of a (rows, cols) array ascending with a single-block
    Pallas kernel (every row padded to one VMEM block).

    ``algorithm``: 'oets' (paper-faithful) or 'bitonic' (beyond-paper).
    """
    (out,) = sort_rows_lex([x], algorithm=algorithm, interpret=interpret)
    return out


def sort_rows_kv(keys, vals, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise key-value sort; ``vals`` must share ``keys``' shape/rows."""
    if keys.shape != vals.shape:
        raise ValueError("keys and vals must have identical shapes")
    ok, ov = sort_rows_lex([keys, vals], algorithm=algorithm,
                           interpret=interpret)
    return ok, ov


def sort_rows_lex(arrs, algorithm: str = "oets", interpret: bool | None = None):
    """Row-wise lexicographic sort of a list of same-shape (rows, cols)
    arrays through a single-block kernel; returns the sorted list.

    Every array pads with its *own* dtype sentinel on purpose: the kernels
    compare full tuples lexicographically, so the all-sentinel padding tuple
    stays strictly maximal and can never displace a real element even when
    real leading lanes equal the sentinel. Do not "simplify" to zero padding.
    """
    interpret = _auto_interpret(interpret)
    rows, cols = arrs[0].shape
    if algorithm == "oets":
        target = max(_LANES, -(-cols // _LANES) * _LANES)
        fn = oets_rows_lex_pallas
    elif algorithm == "bitonic":
        target = max(_LANES, _next_pow2(cols))
        fn = bitonic_rows_lex_pallas
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    padded = [_pad_rows(_pad_cols(a, target), _SUBLANES) for a in arrs]
    out = fn(*padded, interpret=interpret)
    return [o[:rows, :cols] for o in out]


def partition_rows(keys, splitters, interpret: bool | None = None):
    """Bucket each element of (rows, cols) int32 ``keys`` by sorted
    ``splitters`` (the paper's distribute-into-sub-arrays step).

    Returns (bucket_ids (rows, cols), counts (rows, n_buckets)) with
    n_buckets = len(splitters) + 1. bucket id = #splitters <= key."""
    interpret = _auto_interpret(interpret)
    rows, cols = keys.shape
    n_spl = int(splitters.shape[0])
    n_buckets = n_spl + 1
    spl_pad = jnp.full((1, max(_LANES, -(-n_spl // _LANES) * _LANES)),
                       jnp.iinfo(jnp.int32).max, jnp.int32)
    spl_pad = spl_pad.at[0, :n_spl].set(splitters.astype(jnp.int32))
    cols_p = max(_LANES, -(-cols // _LANES) * _LANES)
    xp = _pad_rows(_pad_cols(keys.astype(jnp.int32), cols_p), _SUBLANES)
    bid, cnt = partition_rows_pallas(
        xp, spl_pad, n_splitters=n_spl, n_buckets=n_buckets, interpret=interpret)
    # Padded *cols* of real rows are sentinels (int32 max) and land in the top
    # bucket — subtract them there. Padded *rows* are zero-filled (their
    # elements land in bucket 0, not the top bucket), so the correction must
    # only touch the real rows or it drives their top-bucket count negative.
    pad_cols = cols_p - cols
    if pad_cols:
        cnt = cnt.at[:rows, n_buckets - 1].add(-pad_cols)
    return bid[:rows, :cols], cnt[:rows]
