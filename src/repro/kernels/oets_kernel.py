"""Pallas TPU kernel: odd-even transposition sort along vector lanes.

Layout decision (the TPU adaptation of the paper's OpenMP loop): a block of
``(ROW_BLOCK, cols)`` sits in VMEM; each sublane row is an independent
length-bucket and the ``cols`` elements live across vector lanes. One OETS
phase is two ``roll``s + compares + selects — fully lane-parallel on the VPU,
no gather/scatter. ``cols`` phases sort every row; total compare count per
row is cols*(cols-1)/2, the paper's n(n-1)/2.

The kernel is written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["oets_rows_kernel", "oets_rows_kv_kernel", "oets_rows_pallas", "oets_rows_kv_pallas"]


def _phase(x, parity, col, ncols):
    """One OETS phase on (R, C): pairs (j, j+1) for j % 2 == parity."""
    nxt = jnp.roll(x, -1, axis=1)
    prv = jnp.roll(x, 1, axis=1)
    is_left = (col % 2 == parity) & (col < ncols - 1)
    is_right = (col % 2 == 1 - parity) & (col >= 1)
    swap_with_next = is_left & (x > nxt)
    swap_with_prev = is_right & (prv > x)
    return jnp.where(swap_with_next, nxt, jnp.where(swap_with_prev, prv, x))


def oets_rows_kernel(x_ref, o_ref):
    x = x_ref[...]
    ncols = x.shape[1]
    col = lax.broadcasted_iota(jnp.int32, x.shape, 1)

    def body(p, x):
        return _phase(x, p % 2, col, ncols)

    o_ref[...] = lax.fori_loop(0, ncols, body, x)


def oets_rows_kv_kernel(k_ref, v_ref, ok_ref, ov_ref):
    k = k_ref[...]
    v = v_ref[...]
    ncols = k.shape[1]
    col = lax.broadcasted_iota(jnp.int32, k.shape, 1)

    def body(p, kv):
        k, v = kv
        parity = p % 2
        k_nxt = jnp.roll(k, -1, axis=1)
        k_prv = jnp.roll(k, 1, axis=1)
        v_nxt = jnp.roll(v, -1, axis=1)
        v_prv = jnp.roll(v, 1, axis=1)
        is_left = (col % 2 == parity) & (col < ncols - 1)
        is_right = (col % 2 == 1 - parity) & (col >= 1)
        # (key, val) lex compare: the val tie-break keeps the padding pair
        # (sentinel key, sentinel val) strictly maximal, so padding can never
        # displace a real payload when real keys equal the sentinel.
        swap_next = is_left & ((k > k_nxt) | ((k == k_nxt) & (v > v_nxt)))
        swap_prev = is_right & ((k_prv > k) | ((k_prv == k) & (v_prv > v)))
        k = jnp.where(swap_next, k_nxt, jnp.where(swap_prev, k_prv, k))
        v = jnp.where(swap_next, v_nxt, jnp.where(swap_prev, v_prv, v))
        return (k, v)

    k, v = lax.fori_loop(0, ncols, body, (k, v))
    ok_ref[...] = k
    ov_ref[...] = v


def _row_block(rows: int) -> int:
    # 8 sublanes is the fp32/i32 tile height; keep the VMEM working set small.
    return min(rows, 8)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def oets_rows_pallas(x, *, interpret: bool = False, row_block: int | None = None):
    """Sort each row of (R, C) ascending. R % row_block == 0, C lane-padded
    by the caller (see ops.py)."""
    rows, cols = x.shape
    rb = row_block or _row_block(rows)
    return pl.pallas_call(
        oets_rows_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def oets_rows_kv_pallas(keys, vals, *, interpret: bool = False, row_block: int | None = None):
    rows, cols = keys.shape
    rb = row_block or _row_block(rows)
    return pl.pallas_call(
        oets_rows_kv_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(keys.shape, keys.dtype),
            jax.ShapeDtypeStruct(vals.shape, vals.dtype),
        ),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(keys, vals)
