"""Pallas TPU kernel: odd-even transposition sort along vector lanes.

Layout decision (the TPU adaptation of the paper's OpenMP loop): a block of
``(ROW_BLOCK, cols)`` sits in VMEM; each sublane row is an independent
length-bucket and the ``cols`` elements live across vector lanes. One OETS
phase is two ``roll``s + compares + selects — fully lane-parallel on the VPU,
no gather/scatter. ``cols`` phases sort every row; total compare count per
row is cols*(cols-1)/2, the paper's n(n-1)/2.

The engine is *variadic*: ``oets_rows_lex_pallas(*arrs)`` sorts a tuple of
same-shape arrays as lexicographic tuples (lane 0 most significant, trailing
arrays double as payload/tie-break — see ``kernels/lex.py``). The key-only
and key-value entry points are the 1- and 2-tuple special cases.

The kernel is written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU with ``interpret=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .lex import lex_gt_lanes, map_lanes

__all__ = [
    "oets_rows_lex_kernel",
    "oets_rows_lex_pallas",
    "oets_rows_pallas",
    "oets_rows_kv_pallas",
]


def oets_rows_lex_kernel(*refs):
    """Variadic OETS: refs = n input refs then n output refs; every array
    swaps on the full-tuple lexicographic compare."""
    n = len(refs) // 2
    arrs = tuple(r[...] for r in refs[:n])
    ncols = arrs[0].shape[1]
    col = lax.broadcasted_iota(jnp.int32, arrs[0].shape, 1)

    def body(p, arrs):
        parity = p % 2
        nxt = map_lanes(lambda a: jnp.roll(a, -1, axis=1), arrs)
        prv = map_lanes(lambda a: jnp.roll(a, 1, axis=1), arrs)
        is_left = (col % 2 == parity) & (col < ncols - 1)
        is_right = (col % 2 == 1 - parity) & (col >= 1)
        # Full-tuple lex compare: trailing (payload) lanes are the final
        # tie-break, which keeps the all-sentinel padding tuple strictly
        # maximal, so padding can never displace a real payload when real
        # keys equal the sentinel.
        swap_next = is_left & lex_gt_lanes(arrs, nxt)
        swap_prev = is_right & lex_gt_lanes(prv, arrs)
        return tuple(
            jnp.where(swap_next, nx, jnp.where(swap_prev, pv, a))
            for a, nx, pv in zip(arrs, nxt, prv))

    out = lax.fori_loop(0, ncols, body, arrs)
    for r, o in zip(refs[n:], out):
        r[...] = o


def _row_block(rows: int) -> int:
    # 8 sublanes is the fp32/i32 tile height; keep the VMEM working set small.
    return min(rows, 8)


@functools.partial(jax.jit, static_argnames=("interpret", "row_block"))
def oets_rows_lex_pallas(*arrs, interpret: bool = False,
                         row_block: int | None = None):
    """Sort each row of the (R, C) tuple ``arrs`` ascending by lexicographic
    tuple compare. R % row_block == 0, C lane-padded by the caller (ops.py).
    Returns the sorted tuple."""
    rows, cols = arrs[0].shape
    rb = row_block or _row_block(rows)
    spec = pl.BlockSpec((rb, cols), lambda i: (i, 0))
    return pl.pallas_call(
        oets_rows_lex_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs),
        grid=(rows // rb,),
        in_specs=[spec] * len(arrs),
        out_specs=tuple([spec] * len(arrs)),
        interpret=interpret,
    )(*arrs)


def oets_rows_pallas(x, *, interpret: bool = False, row_block: int | None = None):
    """Key-only special case: sort each row of (R, C) ascending."""
    (out,) = oets_rows_lex_pallas(x, interpret=interpret, row_block=row_block)
    return out


def oets_rows_kv_pallas(keys, vals, *, interpret: bool = False,
                        row_block: int | None = None):
    """Key-value special case: the payload is the 2nd (tie-break) lane."""
    return oets_rows_lex_pallas(keys, vals, interpret=interpret,
                                row_block=row_block)
