"""Shared lexicographic comparator for the Pallas sort kernels.

Every comparator engine in this package (OETS, bitonic, cross-block merge)
reduces to the same primitive: compare two tuples of per-lane arrays
lane-by-lane and swap *all* lanes together. The paper's multi-character
words pack into multiple uint32 lanes (``core/packing.py``), so the
compare-exchange must break ties lane-by-lane — exactly the ``(key, val)``
compare the kv kernels already did, generalised to any number of lanes.

Conventions shared by all engines:

  * A sort operates on a tuple ``arrs = (k0, k1, ..., v...)`` of same-shape
    2-D arrays. *Every* array participates in the compare, in tuple order:
    leading entries are key lanes (most-significant first), trailing entries
    are payloads that double as final tie-breaks. Payloads therefore ride
    the exact permutation the keys choose, and the all-sentinel padding
    tuple stays strictly lex-maximal unless a real element equals the
    sentinel in **every** lane (see ``ops.sort_lex`` for the contract).
  * Partner selection (roll / flip / XOR-shuffle) is applied identically to
    every lane before comparing, so the helpers here take *lists* of arrays
    and return element-wise boolean masks ready for ``jnp.where``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lex_gt_lanes", "map_lanes", "select_lanes"]


def lex_gt_lanes(a_lanes, b_lanes):
    """Element-wise lexicographic ``a > b`` over parallel lane lists.

    ``a_lanes``/``b_lanes``: equal-length sequences of same-shape arrays.
    Lane 0 is most significant; later lanes break ties. Returns a boolean
    array of the common shape. Dtypes may differ per lane (each lane
    compares within its own dtype).
    """
    a0, b0 = a_lanes[0], b_lanes[0]
    gt = a0 > b0
    if len(a_lanes) == 1:
        return gt
    eq = a0 == b0
    for a, b in zip(a_lanes[1:-1], b_lanes[1:-1]):
        gt = gt | (eq & (a > b))
        eq = eq & (a == b)
    a, b = a_lanes[-1], b_lanes[-1]
    return gt | (eq & (a > b))


def map_lanes(fn, arrs):
    """Apply ``fn`` (a partner shuffle: roll/flip/...) to every lane."""
    return [fn(a) for a in arrs]


def select_lanes(mask, on_true, on_false):
    """``jnp.where`` broadcast across parallel lane lists (the swap step)."""
    return [jnp.where(mask, t, f) for t, f in zip(on_true, on_false)]
