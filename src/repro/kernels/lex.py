"""The canonical total-order key plane shared by every comparator tier.

Every comparator engine in this package (OETS, bitonic, cross-block merge,
merge-path run merge) reduces to the same primitive: compare two tuples of
per-lane arrays lane-by-lane and swap *all* lanes together. The paper's
multi-character words pack into multiple uint32 lanes (``core/packing.py``),
so the compare-exchange must break ties lane-by-lane — exactly the
``(key, val)`` compare the kv kernels already did, generalised to any
number of lanes.

There is exactly ONE definition of "less than" in this codebase, and it
lives here: :func:`to_order_bits` maps each lane into uint32 *order bits*
whose unsigned order is the lane's total order — unsigned ints pass
through, signed ints flip the sign bit (or shift, for narrow dtypes), and
float32 takes the IEEE total-order flip with ``-0.0`` normalised to
``+0.0`` and **every NaN canonicalised strictly above ``+inf``** (the
all-ones bit pattern, which is the float padding sentinel, sits strictly
above the other NaNs). ``lex_gt_lanes`` compares order bits but engines
swap the *raw* values, so outputs conserve the input bit multiset exactly
while NaNs sink to the tail — ``jnp.sort``-equivalent semantics. The
packed rank keys of ``kernels/keypack.py`` are the concatenated-bits
special case of this same representation.

Conventions shared by all engines:

  * A sort operates on a tuple ``arrs = (k0, k1, ..., v...)`` of same-shape
    2-D arrays. *Every* array participates in the compare, in tuple order:
    leading entries are key lanes (most-significant first), trailing entries
    are payloads that double as final tie-breaks. Payloads therefore ride
    the exact permutation the keys choose, and the all-sentinel padding
    tuple stays strictly lex-maximal unless a real element equals the
    sentinel in **every** lane (see ``ops.sort_lex`` for the contract).
  * Partner selection (roll / flip / XOR-shuffle) is applied identically to
    every lane before comparing, so the helpers here take *lists* of arrays
    and return element-wise boolean masks ready for ``jnp.where``.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

__all__ = ["to_order_bits", "from_order_bits", "order_view",
           "lex_gt_lanes", "lex_rank_count", "lex_merge_take", "map_lanes",
           "select_lanes", "sentinel_for"]

# Plain python ints, NOT module-level jnp scalars: these helpers run inside
# Pallas kernel bodies, which refuse closed-over array constants. The
# ``jnp.uint32(...)`` wrapping happens inside each function, where a 0-d
# scalar traces as a jaxpr literal.
_TOP = 0x80000000
# float32 order-bit layout above +inf (0xFF800000): every NaN bit pattern
# canonicalises to one slot, except the all-ones pattern — the float padding
# sentinel — which owns the strict maximum. A bijection with all ~2^24 NaN
# patterns above +inf is impossible in 32 bits, so the transform is
# compare-only for NaNs: engines compare order bits and swap raw values,
# which is exactly what conserves the bit-level multiset.
_F32_NAN_ORDER = 0xFFFFFFFE
_F32_SENTINEL_ORDER = 0xFFFFFFFF
_F32_SENTINEL_BITS = 0xFFFFFFFF
_F32_CANONICAL_NAN_BITS = 0x7FC00000  # quiet NaN, for unpacking


def sentinel_for(dtype):
    """The lex-maximal padding value of ``dtype``: ``iinfo.max`` for ints —
    including signed, where it is the positive max — and for floats the
    all-ones-bits NaN, which :func:`to_order_bits` places strictly above
    every other value *including* other NaNs, so padding can never strand
    inside a row that holds real NaNs. The padding contract every engine in
    this package shares; see ``ops.sort_lex`` for the full discussion."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.float32):
        # constructed by bitcast, never via a float literal (a python-level
        # float() round-trip would canonicalise the NaN payload)
        return lax.bitcast_convert_type(jnp.uint32(_F32_SENTINEL_BITS),
                                        jnp.float32)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.nan, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def to_order_bits(x, max_value: Optional[int] = None):
    """Order-preserving uint32 embedding of one lane — the canonical key
    transform every comparator tier shares (the packed rank keys of
    ``kernels/keypack.py`` concatenate these same bits).

    ``max_value`` asserts a ``[0, max_value]`` range on an integer lane
    (values cast directly); otherwise signed ints shift by 2^(bits-1),
    unsigned ints pass through, and float32 maps via the IEEE total-order
    flip with ``-0.0`` normalised to ``+0.0`` (order-bit equality coincides
    with ``==`` on non-NaN values) and every NaN canonicalised above
    ``+inf`` — the all-ones pattern (the padding sentinel) strictly above
    the rest. The NaN collapse makes the float transform compare-only:
    engines compare order bits but always swap the raw lanes."""
    dt = jnp.dtype(x.dtype)
    if max_value is not None:
        if not jnp.issubdtype(dt, jnp.integer):
            raise TypeError("max_values only applies to integer lanes")
        return x.astype(jnp.uint32)
    if dt == jnp.dtype(jnp.float32):
        top = jnp.uint32(_TOP)
        b = lax.bitcast_convert_type(x, jnp.uint32)
        xn = jnp.where(x == 0, jnp.zeros_like(x), x)  # -0.0 -> +0.0
        bn = lax.bitcast_convert_type(xn, jnp.uint32)
        flipped = jnp.where((bn & top) != 0, ~bn, bn | top)
        nan_slot = jnp.where(b == jnp.uint32(_F32_SENTINEL_BITS),
                             jnp.uint32(_F32_SENTINEL_ORDER),
                             jnp.uint32(_F32_NAN_ORDER))
        return jnp.where(jnp.isnan(x), nan_slot, flipped)
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return x.astype(jnp.uint32)
    if jnp.issubdtype(dt, jnp.signedinteger):
        if dt.itemsize == 4:
            return lax.bitcast_convert_type(x, jnp.uint32) ^ jnp.uint32(_TOP)
        # int8/int16: shift into [0, 2^bits) so the value fits `bits` bits
        half = 1 << (dt.itemsize * 8 - 1)
        return (x.astype(jnp.int32) + half).astype(jnp.uint32)
    raise TypeError(f"cannot order-transform lanes of dtype {dt}")


def from_order_bits(v, dtype, max_value: Optional[int] = None):
    """Invert :func:`to_order_bits` — exactly for integer lanes; for float32
    the inverse is *canonical*, not bijective: ``-0.0`` comes back as
    ``+0.0``, the sentinel order slot returns the all-ones-bits NaN, and
    the collapsed NaN slot returns the canonical quiet NaN. Callers that
    must conserve float bits carry the original lanes through the
    permutation instead of unpacking (see ``ops.sort_lex``)."""
    dt = jnp.dtype(dtype)
    if max_value is not None:
        return v.astype(dt)
    if dt == jnp.dtype(jnp.float32):
        top = jnp.uint32(_TOP)
        b = jnp.where((v & top) != 0, v ^ top, ~v)
        b = jnp.where(v == jnp.uint32(_F32_NAN_ORDER),
                      jnp.uint32(_F32_CANONICAL_NAN_BITS), b)
        b = jnp.where(v == jnp.uint32(_F32_SENTINEL_ORDER),
                      jnp.uint32(_F32_SENTINEL_BITS), b)
        return lax.bitcast_convert_type(b, jnp.float32)
    if jnp.issubdtype(dt, jnp.unsignedinteger):
        return v.astype(dt)
    if dt.itemsize == 4:
        return lax.bitcast_convert_type(v ^ jnp.uint32(_TOP), jnp.int32)
    half = 1 << (dt.itemsize * 8 - 1)
    return (v.astype(jnp.int32) - half).astype(dt)


def order_view(a):
    """The comparator's view of one lane: order bits for float lanes (NaN
    total order), the raw values for integer lanes (already totally ordered
    — the transform would only add work)."""
    if jnp.issubdtype(a.dtype, jnp.floating):
        return to_order_bits(a)
    return a


def lex_gt_lanes(a_lanes, b_lanes):
    """Element-wise lexicographic ``a > b`` over parallel lane lists —
    THE "less than" of this codebase.

    ``a_lanes``/``b_lanes``: equal-length sequences of same-shape arrays.
    Lane 0 is most significant; later lanes break ties. Returns a boolean
    array of the common shape. Dtypes may differ per lane; each lane
    compares within its own :func:`order_view`, so float lanes follow the
    canonical total order (NaNs above ``+inf``, ``-0.0 == +0.0``, padding
    sentinel strictly maximal) while integer lanes compare raw.
    """
    a_lanes = [order_view(a) for a in a_lanes]
    b_lanes = [order_view(b) for b in b_lanes]
    a0, b0 = a_lanes[0], b_lanes[0]
    gt = a0 > b0
    if len(a_lanes) == 1:
        return gt
    eq = a0 == b0
    for a, b in zip(a_lanes[1:-1], b_lanes[1:-1]):
        gt = gt | (eq & (a > b))
        eq = eq & (a == b)
    a, b = a_lanes[-1], b_lanes[-1]
    return gt | (eq & (a > b))


def lex_rank_count(a_lanes, b_lanes, strict):
    """For each element of ``b``: how many elements of ``a`` are lex-below
    it (``strict``) or lex-at-or-below it (``not strict``). O(|a|·|b|)
    broadcast compare — the merge-path rank at block granularity, kept as
    the *differential oracle* for the packed rank-key fast path
    (``kernels/keypack.py``: ``lex_searchsorted`` computes the same counts
    in O(|b| log |a|) gathers; the production merges all route there)."""
    a2 = [a[:, None] for a in a_lanes]
    b2 = [b[None, :] for b in b_lanes]
    cmp = lex_gt_lanes(b2, a2) if strict else ~lex_gt_lanes(a2, b2)
    return jnp.sum(cmp, axis=0)


def lex_merge_take(a_lanes, b_lanes):
    """Merge two *sorted* lex-tuple runs into one sorted run of length
    ``|a| + |b|`` via merge-path rank + scatter (no re-sort).

    Each element's output position is its rank in the merged sequence:
    own index + count of smaller elements in the other run — strict one way,
    non-strict the other, so equal tuples get distinct ranks and every
    output slot is written exactly once. Key-only runs rank in O(n log n)
    via ``searchsorted``; wider tuples pay the O(|a|·|b|) broadcast compare
    here — this is the lane-wise *oracle*; production merges use
    ``keypack.merge_take_packed`` / ``ops.merge_sorted_lex``, which rank
    every arity in O(n log n). Runs may have different lengths.
    """
    a_lanes, b_lanes = list(a_lanes), list(b_lanes)
    na, nb = a_lanes[0].shape[0], b_lanes[0].shape[0]
    if len(a_lanes) == 1:
        a0, b0 = order_view(a_lanes[0]), order_view(b_lanes[0])
        rank_a = jnp.arange(na) + jnp.searchsorted(b0, a0, side="left")
        rank_b = jnp.arange(nb) + jnp.searchsorted(a0, b0, side="right")
    else:
        rank_a = jnp.arange(na) + lex_rank_count(b_lanes, a_lanes, strict=True)
        rank_b = jnp.arange(nb) + lex_rank_count(a_lanes, b_lanes,
                                                 strict=False)
    out = []
    for a, b in zip(a_lanes, b_lanes):
        o = jnp.zeros((na + nb,), a.dtype)
        out.append(o.at[rank_a].set(a).at[rank_b].set(b))
    return out


def map_lanes(fn, arrs):
    """Apply ``fn`` (a partner shuffle: roll/flip/...) to every lane."""
    return [fn(a) for a in arrs]


def select_lanes(mask, on_true, on_false):
    """``jnp.where`` broadcast across parallel lane lists (the swap step)."""
    return [jnp.where(mask, t, f) for t, f in zip(on_true, on_false)]
