"""Shared lexicographic comparator for the Pallas sort kernels.

Every comparator engine in this package (OETS, bitonic, cross-block merge)
reduces to the same primitive: compare two tuples of per-lane arrays
lane-by-lane and swap *all* lanes together. The paper's multi-character
words pack into multiple uint32 lanes (``core/packing.py``), so the
compare-exchange must break ties lane-by-lane — exactly the ``(key, val)``
compare the kv kernels already did, generalised to any number of lanes.

Conventions shared by all engines:

  * A sort operates on a tuple ``arrs = (k0, k1, ..., v...)`` of same-shape
    2-D arrays. *Every* array participates in the compare, in tuple order:
    leading entries are key lanes (most-significant first), trailing entries
    are payloads that double as final tie-breaks. Payloads therefore ride
    the exact permutation the keys choose, and the all-sentinel padding
    tuple stays strictly lex-maximal unless a real element equals the
    sentinel in **every** lane (see ``ops.sort_lex`` for the contract).
  * Partner selection (roll / flip / XOR-shuffle) is applied identically to
    every lane before comparing, so the helpers here take *lists* of arrays
    and return element-wise boolean masks ready for ``jnp.where``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lex_gt_lanes", "lex_rank_count", "lex_merge_take", "map_lanes",
           "select_lanes", "sentinel_for"]


def sentinel_for(dtype):
    """The lex-maximal padding value of ``dtype`` (``iinfo.max`` for ints —
    including signed, where it is the positive max — ``+inf`` for floats).
    The padding contract every engine in this package shares; see
    ``ops.sort_lex`` for the full sentinel/dtype discussion."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def lex_gt_lanes(a_lanes, b_lanes):
    """Element-wise lexicographic ``a > b`` over parallel lane lists.

    ``a_lanes``/``b_lanes``: equal-length sequences of same-shape arrays.
    Lane 0 is most significant; later lanes break ties. Returns a boolean
    array of the common shape. Dtypes may differ per lane (each lane
    compares within its own dtype).
    """
    a0, b0 = a_lanes[0], b_lanes[0]
    gt = a0 > b0
    if len(a_lanes) == 1:
        return gt
    eq = a0 == b0
    for a, b in zip(a_lanes[1:-1], b_lanes[1:-1]):
        gt = gt | (eq & (a > b))
        eq = eq & (a == b)
    a, b = a_lanes[-1], b_lanes[-1]
    return gt | (eq & (a > b))


def lex_rank_count(a_lanes, b_lanes, strict):
    """For each element of ``b``: how many elements of ``a`` are lex-below
    it (``strict``) or lex-at-or-below it (``not strict``). O(|a|·|b|)
    broadcast compare — the merge-path rank at block granularity, kept as
    the *differential oracle* for the packed rank-key fast path
    (``kernels/keypack.py``: ``lex_searchsorted`` computes the same counts
    in O(|b| log |a|) gathers; the production merges all route there)."""
    a2 = [a[:, None] for a in a_lanes]
    b2 = [b[None, :] for b in b_lanes]
    cmp = lex_gt_lanes(b2, a2) if strict else ~lex_gt_lanes(a2, b2)
    return jnp.sum(cmp, axis=0)


def lex_merge_take(a_lanes, b_lanes):
    """Merge two *sorted* lex-tuple runs into one sorted run of length
    ``|a| + |b|`` via merge-path rank + scatter (no re-sort).

    Each element's output position is its rank in the merged sequence:
    own index + count of smaller elements in the other run — strict one way,
    non-strict the other, so equal tuples get distinct ranks and every
    output slot is written exactly once. Key-only runs rank in O(n log n)
    via ``searchsorted``; wider tuples pay the O(|a|·|b|) broadcast compare
    here — this is the lane-wise *oracle*; production merges use
    ``keypack.merge_take_packed`` / ``ops.merge_sorted_lex``, which rank
    every arity in O(n log n). Runs may have different lengths.
    """
    a_lanes, b_lanes = list(a_lanes), list(b_lanes)
    na, nb = a_lanes[0].shape[0], b_lanes[0].shape[0]
    if len(a_lanes) == 1:
        rank_a = jnp.arange(na) + jnp.searchsorted(b_lanes[0], a_lanes[0],
                                                   side="left")
        rank_b = jnp.arange(nb) + jnp.searchsorted(a_lanes[0], b_lanes[0],
                                                   side="right")
    else:
        rank_a = jnp.arange(na) + lex_rank_count(b_lanes, a_lanes, strict=True)
        rank_b = jnp.arange(nb) + lex_rank_count(a_lanes, b_lanes,
                                                 strict=False)
    out = []
    for a, b in zip(a_lanes, b_lanes):
        o = jnp.zeros((na + nb,), a.dtype)
        out.append(o.at[rank_a].set(a).at[rank_b].set(b))
    return out


def map_lanes(fn, arrs):
    """Apply ``fn`` (a partner shuffle: roll/flip/...) to every lane."""
    return [fn(a) for a in arrs]


def select_lanes(mask, on_true, on_false):
    """``jnp.where`` broadcast across parallel lane lists (the swap step)."""
    return [jnp.where(mask, t, f) for t, f in zip(on_true, on_false)]
