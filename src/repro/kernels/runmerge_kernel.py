"""Pallas TPU kernel: block-parallel merge-path combine of two sorted runs.

The MPI follow-up paper's profile (merge dominating once local sorts are
fast) is exactly our stack post-PR 4: chunked ingest produces kernel-sorted
runs, but the run *combine* was a jnp-level rank + one HBM-wide scatter.
This kernel keeps the combine in VMEM tiles instead:

  1. **Diagonal partition** (host jnp, inside the same jit): merge-path
     ranks of run ``a`` against run ``b`` come from the packed rank-key
     binary search (``kernels/keypack.py`` — O(n log n) gathers, never the
     O(|a|·|b|) broadcast), and one ``searchsorted`` over those ranks yields
     for every output block of ``block`` slots the exact source segments
     ``a[sa:ea)`` / ``b[sb:eb)`` with ``(ea-sa) + (eb-sb) == block``.
  2. **Per-block VMEM merge**: each grid step DMAs its two segments (via
     scalar-prefetched starts — the segments land at data-dependent offsets
     no BlockSpec can express), masks the tails to the lex-maximal sentinel
     tuple, and runs the same asc++asc bitonic merge network the cross-block
     kernel uses (``merge_kernel._merge_network``) on the ``2*block`` window;
     the low half is the finished output block. No HBM scatter anywhere.

Variadic like every engine in this package: lanes merge as one lex tuple
(lane 0 most significant, trailing lanes are payload tie-breaks). ``n_cmp``
lets a caller that pre-packed rank keys (the pipeline tournament) rank the
diagonal on the leading compare lanes only; the in-block network still
compares the full tuple, which is consistent because the compare prefix is
an order-preserving refinement.

Both runs are padded with ``block`` sentinel elements so every segment DMA
reads a full window; output blocks beyond ``|a|+|b|`` hold sentinel fill and
are sliced off. Equal tuples are interchangeable values, so the output is
bit-identical to the lane-wise ``lex_merge_take`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .keypack import lex_searchsorted, packed_cmp_lanes
from .lex import sentinel_for
from .merge_kernel import _merge_network

__all__ = ["DEFAULT_MERGE_BLOCK", "merge_runs_lex_pallas", "merge_runs_pallas"]

# one output tile per grid step; 2*block lanes of every array live in VMEM
DEFAULT_MERGE_BLOCK = 256


def _runmerge_kernel(starts_ref, *refs, n_arr, block):
    a_refs = refs[:n_arr]
    b_refs = refs[n_arr:2 * n_arr]
    out_refs = refs[2 * n_arr:3 * n_arr]
    scr = refs[3 * n_arr:4 * n_arr]
    sem = refs[4 * n_arr]
    k = pl.program_id(0)
    sa, ea = starts_ref[0, k], starts_ref[0, k + 1]
    sb, eb = starts_ref[1, k], starts_ref[1, k + 1]

    copies = []
    for i in range(n_arr):
        ca = pltpu.make_async_copy(a_refs[i].at[:, pl.ds(sa, block)],
                                   scr[i].at[:, 0:block], sem.at[2 * i])
        cb = pltpu.make_async_copy(b_refs[i].at[:, pl.ds(sb, block)],
                                   scr[i].at[:, block:2 * block],
                                   sem.at[2 * i + 1])
        ca.start()
        cb.start()
        copies += [ca, cb]
    for c in copies:
        c.wait()

    # window layout: a-segment in cols [0, block), b-segment in [block, 2B).
    # Positions past each segment's count are masked to the sentinel tuple
    # (lex-maximal under the full-tuple compare), so both halves stay sorted
    # ascending and the fills sink past every real element of the block.
    col = lax.broadcasted_iota(jnp.int32, (1, 2 * block), 1)
    valid = jnp.where(col < block, col < ea - sa, col - block < eb - sb)
    arrs = tuple(jnp.where(valid, s[...], sentinel_for(s.dtype)) for s in scr)
    merged = _merge_network(arrs, block)
    for r, m in zip(out_refs, merged):
        r[...] = m[:, :block]


def _pad_run(a, block):
    fill = jnp.full((block,), sentinel_for(a.dtype), a.dtype)
    return jnp.concatenate([a, fill])[None, :]


@functools.partial(jax.jit, static_argnames=("n_arr", "n_cmp", "max_values",
                                             "block", "interpret"))
def _merge_runs_jit(*arrs, n_arr, n_cmp, max_values, block, interpret):
    a_lanes = list(arrs[:n_arr])
    b_lanes = list(arrs[n_arr:])
    na, nb = a_lanes[0].shape[0], b_lanes[0].shape[0]
    total = na + nb
    nblocks = -(-total // block)

    if n_cmp is None:
        cmp_a = packed_cmp_lanes(a_lanes, max_values)
        cmp_b = packed_cmp_lanes(b_lanes, max_values)
    else:
        cmp_a, cmp_b = a_lanes[:n_cmp], b_lanes[:n_cmp]
    # merge-path ranks of a (a wins ties, mirroring lex_merge_take), then the
    # diagonal: a_starts[k] = #a-elements among the first k*block outputs.
    # rank_a ascends, so this is one searchsorted over the block boundaries.
    rank_a = jnp.arange(na, dtype=jnp.int32) + lex_searchsorted(
        cmp_b, cmp_a, side="left").astype(jnp.int32)
    bounds = jnp.arange(nblocks + 1, dtype=jnp.int32) * block
    a_starts = jnp.searchsorted(rank_a, bounds, side="left").astype(jnp.int32)
    b_starts = jnp.clip(bounds - a_starts, 0, nb).astype(jnp.int32)
    starts = jnp.stack([a_starts, b_starts])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * (2 * n_arr),
        out_specs=tuple(pl.BlockSpec((1, block), lambda k, s: (0, k))
                        for _ in range(n_arr)),
        scratch_shapes=[pltpu.VMEM((1, 2 * block), a.dtype) for a in a_lanes]
        + [pltpu.SemaphoreType.DMA((2 * n_arr,))],
    )
    out = pl.pallas_call(
        functools.partial(_runmerge_kernel, n_arr=n_arr, block=block),
        out_shape=tuple(jax.ShapeDtypeStruct((1, nblocks * block), a.dtype)
                        for a in a_lanes),
        grid_spec=grid_spec,
        interpret=interpret,
    )(starts, *[_pad_run(a, block) for a in a_lanes],
      *[_pad_run(b, block) for b in b_lanes])
    return tuple(o[0, :total] for o in out)


def merge_runs_lex_pallas(a_lanes, b_lanes, n_cmp=None, max_values=None,
                          block: int | None = None, interpret: bool = False):
    """Merge two sorted lex-tuple runs (tuples of parallel 1-D arrays, any
    lengths) into one sorted run with the block-parallel merge-path kernel.

    ``n_cmp``: rank the diagonal on the leading ``n_cmp`` pre-packed compare
    lanes (``None`` packs rank keys from all lanes here); ``max_values``:
    per-lane bounds for the packing (hashable tuple). ``block`` must be a
    power of two >= 128 (the merge network and lane tile demand it)."""
    a_lanes, b_lanes = list(a_lanes), list(b_lanes)
    if max_values is not None:
        max_values = tuple(max_values)  # static under jit: must be hashable
    if len(a_lanes) != len(b_lanes) or not a_lanes:
        raise ValueError("runs must share a non-zero lane arity")
    if any(a.ndim != 1 for a in a_lanes + b_lanes):
        raise ValueError("runs must be tuples of 1-D arrays")
    block = DEFAULT_MERGE_BLOCK if block is None else block
    if block < 128 or block & (block - 1):
        raise ValueError("block must be a power of two >= 128")
    if a_lanes[0].shape[0] == 0:
        return tuple(b_lanes)
    if b_lanes[0].shape[0] == 0:
        return tuple(a_lanes)
    return _merge_runs_jit(*a_lanes, *b_lanes, n_arr=len(a_lanes),
                           n_cmp=n_cmp, max_values=max_values, block=block,
                           interpret=interpret)


def merge_runs_pallas(a, b, block: int | None = None,
                      interpret: bool = False):
    """Key-only special case of :func:`merge_runs_lex_pallas`."""
    (out,) = merge_runs_lex_pallas([a], [b], block=block, interpret=interpret)
    return out
