"""Pallas TPU kernel: ONE-launch streaming k-way merge of sorted runs.

``pipeline/merge.py``'s tournament combines k runs in ceil(log2 k) pairwise
rounds — every round is a full pass over all the data, so the combine costs
~log2(k)x the HBM traffic of a single streaming pass (the multi-way merge
payoff the parallel-sorting survey calls out, and the merge profile of the
authors' MPI follow-up). This module collapses the combine to one launch:

  1. **k-way diagonal split** (:func:`kway_ranks`, host jnp inside the same
     jit): the merge-path ranks come from a *key tournament* — ceil(log2 k)
     pairwise rank-merge rounds (``keypack.merge_take_packed``) over the
     packed compare lanes plus a source-index lane, then one inverse-
     permutation scatter. Only the 1-3 compare lanes ever move through the
     rounds (the data lanes move exactly once, later), and the round count
     keeps the search total at O(k) binary searches — the naive all-pairs
     split is O(k^2) searches and collapses the XLA graph past k ~ 8. Ties
     resolve by run index (lower run wins, the a-before-b protocol of
     ``merge_take_packed`` applied along the tree), so the ranks are exactly
     a permutation of ``[0, total)``. One ``searchsorted`` of
     each run's ranks over the block boundaries turns them into per-block
     segment cursors — and unlike ``runmerge_kernel.py``, those cursors ride
     into the kernel as the scalar-prefetch operand of a
     ``PrefetchScalarGridSpec``: the split is consumed *in-kernel* from SMEM,
     there is no host-side gather/scatter of the data lanes at all.
  2. **2-slot double-buffered segment DMA**: each grid step starts the async
     copies for output block ``k+1`` into the alternate scratch slot before
     waiting on block ``k``'s, so the k segment fetches for the next block
     overlap the merge network of the current one and HBM latency hides
     behind compute.
  3. **Block-granularity loser tree**: the per-run cursor state lives in
     SMEM (the prefetched starts matrix); selection runs as a pairwise
     elimination tree over the k resident VMEM segments — each round merges
     two block-sorted windows with ``merge_kernel._merge_network`` and
     keeps the low ``block`` (the "winners"), so after ceil(log2 k) rounds
     the surviving window IS the output block. Tails mask to the lex-maximal
     sentinel tuple, which keeps every window sorted and makes fills
     interchangeable with sentinel-valued real elements — the output is
     bit-identical to the NumPy/tournament oracle.

Variadic over lex lane tuples like every engine here (lane 0 most
significant, trailing lanes payload tie-breaks). ``n_cmp`` ranks the split
on pre-packed leading compare lanes only; callers must pass a compare
prefix that is an order-preserving refinement of the full tuple (equal
prefix => equal tuple), which the pipeline's exact packings guarantee.

:func:`merge_runs_kway_take` is the jnp tier of the same contract: off-TPU
there is no DMA pipeline to hide latency behind, so op count is what rules —
ONE fused ``lax.sort`` over the canonical order bits of the 1-3 compare
lanes (+ an iota lane whose stable order encodes the run-index tie protocol)
yields the merge permutation in a single dispatch, then ONE gather per lane.
The data lanes move exactly once, versus the tournament's log2(k) passes of
~k separate jits over every lane. That is the engine
``ops.merge_runs_lex`` routes to off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .keypack import merge_take_packed, packed_cmp_lanes
from .lex import sentinel_for, to_order_bits
from .merge_kernel import _merge_network

__all__ = ["DEFAULT_KWAY_BLOCK", "kway_ranks", "merge_runs_kway_take",
           "merge_runs_kway_pallas", "merge_kway_pallas"]

# one output tile per grid step; 2 slots x k segments of every lane in VMEM
DEFAULT_KWAY_BLOCK = 256


def kway_ranks(cmp_runs):
    """Merge-path rank of every element of every sorted run: a list of int32
    arrays (one per run) that together form a permutation of ``[0, total)``.

    ``cmp_runs[r]`` is run r's compare-lane tuple. Compare-equal elements
    order by run index (then by in-run index), so the ranks collide nowhere.

    Computed as a key tournament: each run carries its flat source index as
    a payload lane, adjacent pairs rank-merge (``merge_take_packed``,
    a-before-b — the lower run index is always the left operand, so the tie
    protocol composes along the tree) until one key sequence remains, and
    the final ranks are its inverse permutation. ceil(log2 k) rounds moving
    only the compare lanes + one int32 lane — O(k) binary searches total,
    where ranking every run against every other would pay O(k^2)."""
    cmp_runs = [list(c) for c in cmp_runs]
    ns = [c[0].shape[0] for c in cmp_runs]
    bases, off = [], 0
    for n_r in ns:
        bases.append(off)
        off += n_r
    total = off
    if len(cmp_runs) == 1:
        return [jnp.arange(total, dtype=jnp.int32)]
    nc = len(cmp_runs[0])
    ext = [c + [base + jnp.arange(n_r, dtype=jnp.int32)]
           for c, base, n_r in zip(cmp_runs, bases, ns)]
    while len(ext) > 1:
        nxt = [merge_take_packed(ext[j], ext[j + 1], n_cmp=nc)
               for j in range(0, len(ext) - 1, 2)]
        if len(ext) % 2:
            nxt.append(ext[-1])
        ext = nxt
    src = ext[0][nc]
    ranks_flat = jnp.zeros((total,), jnp.int32).at[src].set(
        jnp.arange(total, dtype=jnp.int32), unique_indices=True)
    return [ranks_flat[b:b + n_r] for b, n_r in zip(bases, ns)]


def _cmp_runs(runs, n_cmp, max_values):
    if n_cmp is None:
        return [packed_cmp_lanes(list(r), max_values) for r in runs]
    return [tuple(r[:n_cmp]) for r in runs]


def merge_runs_kway_take(runs, n_cmp=None, max_values=None):
    """jnp k-way merge: ONE fused key sort + ONE gather per lane (a single
    data pass; the tournament re-gathers every lane log2(k) times).

    The merge permutation comes from a stable ``lax.sort`` of the
    concatenated compare lanes — each mapped through ``lex.to_order_bits``
    so unsigned sort order IS the canonical lex order (floats included:
    ``-0.0`` collapses onto ``+0.0`` and every NaN onto the canonical slot
    above ``+inf``, exactly the comparator the oracle uses) — with an iota
    lane riding along: stable ties keep concatenation order, which is run
    index then in-run index, the k-way tie protocol. One fused sort op
    beats any unrolled O(k) graph of binary-search rounds off-TPU, where
    per-op dispatch dominates. Traceable; runs are sequences of equal-arity
    lane tuples, any lengths."""
    runs = [list(r) for r in runs]
    cmp_runs = _cmp_runs(runs, n_cmp, max_values)
    nc = len(cmp_runs[0])
    total = sum(r[0].shape[0] for r in runs)
    keys = tuple(to_order_bits(jnp.concatenate([c[i] for c in cmp_runs]))
                 for i in range(nc))
    src = jnp.arange(total, dtype=jnp.int32)
    perm = lax.sort(keys + (src,), num_keys=nc, is_stable=True)[-1]
    return tuple(jnp.concatenate([r[i] for r in runs])[perm]
                 for i in range(len(runs[0])))


def _kway_kernel(starts_ref, *refs, n_arr, n_runs, block):
    in_refs = refs[:n_arr]
    out_refs = refs[n_arr:2 * n_arr]
    scr = refs[2 * n_arr:3 * n_arr]
    sem = refs[3 * n_arr]
    k = pl.program_id(0)
    nb = pl.num_programs(0)

    # starts_ref[r, j] is the ABSOLUTE offset of run r's segment for output
    # block j inside the flat (run || sentinel-pad) concatenation, so the
    # segment count is the plain difference and every read stays in bounds.
    def stage(blk, slot):
        for i in range(n_arr):
            for r in range(n_runs):
                pltpu.make_async_copy(
                    in_refs[i].at[:, pl.ds(starts_ref[r, blk], block)],
                    scr[i].at[pl.ds(slot * n_runs + r, 1), :],
                    sem.at[slot, i, r]).start()

    # 2-slot double buffer: block k+1's k segment DMAs start into the
    # alternate slot before this block's are awaited, so the fetches for the
    # next block run under this block's merge network.
    slot = lax.rem(k, 2)

    @pl.when(k == 0)
    def _():
        stage(0, 0)

    @pl.when(k + 1 < nb)
    def _():
        stage(k + 1, lax.rem(k + 1, 2))

    for i in range(n_arr):
        for r in range(n_runs):
            pltpu.make_async_copy(
                in_refs[i].at[:, pl.ds(starts_ref[r, k], block)],
                scr[i].at[pl.ds(slot * n_runs + r, 1), :],
                sem.at[slot, i, r]).wait()

    # Resident segments, tails masked to the lex-maximal sentinel tuple so
    # every window is sorted ascending and fills sink past real elements.
    col = lax.broadcasted_iota(jnp.int32, (1, block), 1)
    segs = []
    for r in range(n_runs):
        cnt = starts_ref[r, k + 1] - starts_ref[r, k]
        segs.append(tuple(
            jnp.where(col < cnt, scr[i][pl.ds(slot * n_runs + r, 1), :],
                      sentinel_for(scr[i].dtype))
            for i in range(n_arr)))

    # Loser tree at block granularity: pairwise elimination rounds; each
    # keeps the low `block` of an asc++asc merge. Real (non-fill) elements
    # of this output block number <= block in total, so no round's
    # truncation can drop one (anything truncated is sentinel fill or
    # interchangeable with it).
    while len(segs) > 1:
        nxt = []
        for j in range(0, len(segs) - 1, 2):
            cat = tuple(jnp.concatenate([a, b], axis=1)
                        for a, b in zip(segs[j], segs[j + 1]))
            nxt.append(tuple(m[:, :block]
                             for m in _merge_network(cat, block)))
        if len(segs) % 2:
            nxt.append(segs[-1])
        segs = nxt
    for ref, m in zip(out_refs, segs[0]):
        ref[...] = m


@functools.partial(jax.jit, static_argnames=("n_arr", "n_runs", "n_cmp",
                                             "max_values", "block",
                                             "interpret"))
def _kway_merge_jit(*arrs, n_arr, n_runs, n_cmp, max_values, block,
                    interpret):
    runs = [list(arrs[r * n_arr:(r + 1) * n_arr]) for r in range(n_runs)]
    ns = [r[0].shape[0] for r in runs]
    total = sum(ns)
    nblocks = -(-total // block)

    ranks = kway_ranks(_cmp_runs(runs, n_cmp, max_values))
    bounds = jnp.arange(nblocks + 1, dtype=jnp.int32) * block
    # flat layout: run r's lane at [base_r, base_r + ns[r]), then `block`
    # sentinel fill slots — every segment DMA reads a full in-bounds window.
    bases, off = [], 0
    for n_r in ns:
        bases.append(off)
        off += n_r + block
    starts = jnp.stack([
        jnp.int32(bases[r])
        + jnp.searchsorted(ranks[r], bounds, side="left").astype(jnp.int32)
        for r in range(n_runs)])
    flat = [jnp.concatenate(
        [jnp.concatenate([run[i], jnp.full((block,),
                                           sentinel_for(run[i].dtype),
                                           run[i].dtype)])
         for run in runs])[None, :] for i in range(n_arr)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n_arr,
        out_specs=tuple(pl.BlockSpec((1, block), lambda k, s: (0, k))
                        for _ in range(n_arr)),
        scratch_shapes=[pltpu.VMEM((2 * n_runs, block), x.dtype)
                        for x in runs[0]]
        + [pltpu.SemaphoreType.DMA((2, n_arr, n_runs))],
    )
    out = pl.pallas_call(
        functools.partial(_kway_kernel, n_arr=n_arr, n_runs=n_runs,
                          block=block),
        out_shape=tuple(jax.ShapeDtypeStruct((1, nblocks * block),
                                             runs[0][i].dtype)
                        for i in range(n_arr)),
        grid_spec=grid_spec,
        interpret=interpret,
    )(starts, *flat)
    return tuple(o[0, :total] for o in out)


def merge_runs_kway_pallas(runs, n_cmp=None, max_values=None,
                           block: int | None = None,
                           interpret: bool = False):
    """Merge k sorted lex-tuple runs (sequences of equal-arity tuples of
    parallel 1-D arrays, any lengths) in ONE kernel launch.

    ``n_cmp``: rank the split on the leading pre-packed compare lanes
    (``None`` packs rank keys from all lanes here); ``max_values``: per-lane
    bounds for that packing (hashable tuple). ``block`` must be a power of
    two >= 128. Empty runs drop host-side (static shapes); k == 1 returns
    the run as-is. VMEM holds 2*k segments per lane — practical k is a few
    dozen; past that, chunk the combine."""
    runs = [tuple(r) for r in runs]
    if max_values is not None:
        max_values = tuple(max_values)  # static under jit: must be hashable
    if not runs or not runs[0] or any(len(r) != len(runs[0]) for r in runs):
        raise ValueError("runs must share a non-zero lane arity")
    if any(x.ndim != 1 for r in runs for x in r):
        raise ValueError("runs must be tuples of 1-D arrays")
    block = DEFAULT_KWAY_BLOCK if block is None else block
    if block < 128 or block & (block - 1):
        raise ValueError("block must be a power of two >= 128")
    nonempty = [r for r in runs if r[0].shape[0]]
    if not nonempty:
        return runs[0]
    if len(nonempty) == 1:
        return nonempty[0]
    return _kway_merge_jit(*[x for r in nonempty for x in r],
                           n_arr=len(runs[0]), n_runs=len(nonempty),
                           n_cmp=n_cmp, max_values=max_values, block=block,
                           interpret=interpret)


def merge_kway_pallas(runs, block: int | None = None,
                      interpret: bool = False):
    """Key-only special case of :func:`merge_runs_kway_pallas`."""
    (out,) = merge_runs_kway_pallas([(r,) for r in runs], block=block,
                                    interpret=interpret)
    return out
