"""Pallas TPU kernels for the compute hot spot the paper optimizes: the
in-bucket comparator sort. ``ops`` is the public entry (``sort``/``sort_kv``
auto-pick the engine; ``sort_lex`` is the variadic lexicographic front-end
with a packed rank-key routing knob; ``segmented_sort`` the fused bucket
pipeline; ``merge_sorted``/``merge_sorted_lex`` the run-merge front-end;
``sort_rows`` the raw single-block path); ``ref`` the jnp oracle;
``keypack`` the packed rank-key subsystem (order-preserving 1-2 uint32
compression of lex tuples + searchsorted merge-path ranks); per-kernel
modules hold the pallas_call + BlockSpec definitions — all variadic over
lex lane tuples via the shared comparator in ``lex.py`` — including the
cross-block merge used by ``core/blocksort`` and the merge-path run kernel
(``runmerge_kernel``) behind ``ops.merge_sorted``."""

from .keypack import (PackedKeys, PackPlan, bias_to_u32, lex_searchsorted,
                      merge_take_packed, pack_rank_keys, pack_shortlex,
                      packed_cmp_lanes, packed_searchsorted, plan_pack,
                      shortlex_max_values, unpack_rank_keys)
from .lex import lex_gt_lanes, lex_merge_take, lex_rank_count, sentinel_for
from .merge_kernel import (merge_adjacent_kv_pallas, merge_adjacent_lex_pallas,
                           merge_adjacent_pallas)
from .ops import (bucketize, choose_lex_engine, choose_merge_engine,
                  choose_plan, distribute, execution_provenance,
                  merge_sorted, merge_sorted_lex, pallas_lowering,
                  partition_rows, scatter_to_buckets, segmented_sort, sort,
                  sort_kv, sort_lex, sort_rows, sort_rows_kv, sort_rows_lex)
from .ref import partition_rows_ref, sort_rows_kv_ref, sort_rows_ref
from .runmerge_kernel import (DEFAULT_MERGE_BLOCK, merge_runs_lex_pallas,
                              merge_runs_pallas)

__all__ = [
    "sort", "sort_kv", "sort_lex", "segmented_sort", "distribute",
    "bucketize", "scatter_to_buckets",
    "pallas_lowering", "execution_provenance",
    "choose_plan", "choose_lex_engine", "choose_merge_engine",
    "merge_sorted", "merge_sorted_lex",
    "sort_rows", "sort_rows_kv", "sort_rows_lex", "partition_rows",
    "lex_gt_lanes", "lex_merge_take", "lex_rank_count", "sentinel_for",
    "PackPlan", "PackedKeys", "plan_pack", "bias_to_u32", "pack_rank_keys",
    "unpack_rank_keys", "packed_cmp_lanes", "pack_shortlex",
    "shortlex_max_values", "lex_searchsorted", "packed_searchsorted",
    "merge_take_packed",
    "merge_adjacent_pallas", "merge_adjacent_kv_pallas",
    "merge_adjacent_lex_pallas",
    "DEFAULT_MERGE_BLOCK", "merge_runs_lex_pallas", "merge_runs_pallas",
    "sort_rows_ref", "sort_rows_kv_ref", "partition_rows_ref",
]
