"""Pallas TPU kernels for the compute hot spot the paper optimizes: the
in-bucket comparator sort. ``ops`` is the public entry (``sort``/``sort_kv``
auto-pick the engine; ``sort_lex`` is the variadic lexicographic front-end;
``segmented_sort`` the fused bucket pipeline; ``sort_rows`` the raw
single-block path); ``ref`` the jnp oracle; per-kernel modules hold the
pallas_call + BlockSpec definitions — all variadic over lex lane tuples via
the shared comparator in ``lex.py`` — including the cross-block merge used
by ``core/blocksort``."""

from .lex import lex_gt_lanes, lex_merge_take, lex_rank_count
from .merge_kernel import (merge_adjacent_kv_pallas, merge_adjacent_lex_pallas,
                           merge_adjacent_pallas)
from .ops import (bucketize, choose_plan, distribute, partition_rows,
                  segmented_sort, sort, sort_kv, sort_lex, sort_rows,
                  sort_rows_kv, sort_rows_lex)
from .ref import partition_rows_ref, sort_rows_kv_ref, sort_rows_ref

__all__ = [
    "sort", "sort_kv", "sort_lex", "segmented_sort", "distribute",
    "bucketize", "choose_plan",
    "sort_rows", "sort_rows_kv", "sort_rows_lex", "partition_rows",
    "lex_gt_lanes", "lex_merge_take", "lex_rank_count",
    "merge_adjacent_pallas", "merge_adjacent_kv_pallas",
    "merge_adjacent_lex_pallas",
    "sort_rows_ref", "sort_rows_kv_ref", "partition_rows_ref",
]
