"""Pallas TPU kernels for the compute hot spot the paper optimizes: the
in-bucket comparator sort. ``ops`` is the public entry (``sort``/``sort_kv``
auto-pick the engine; ``sort_rows`` is the raw single-block path); ``ref``
the jnp oracle; per-kernel modules hold the pallas_call + BlockSpec
definitions, including the cross-block merge used by ``core/blocksort``."""

from .merge_kernel import merge_adjacent_kv_pallas, merge_adjacent_pallas
from .ops import (choose_plan, partition_rows, sort, sort_kv, sort_rows,
                  sort_rows_kv)
from .ref import partition_rows_ref, sort_rows_kv_ref, sort_rows_ref

__all__ = [
    "sort", "sort_kv", "choose_plan",
    "sort_rows", "sort_rows_kv", "partition_rows",
    "merge_adjacent_pallas", "merge_adjacent_kv_pallas",
    "sort_rows_ref", "sort_rows_kv_ref", "partition_rows_ref",
]
