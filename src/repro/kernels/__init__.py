"""Pallas TPU kernels for the compute hot spot the paper optimizes: the
in-bucket comparator sort. ``ops`` is the public entry; ``ref`` the jnp
oracle; per-kernel modules hold the pallas_call + BlockSpec definitions."""

from .ops import sort_rows, sort_rows_kv, partition_rows
from .ref import sort_rows_ref, sort_rows_kv_ref, partition_rows_ref

__all__ = ["sort_rows", "sort_rows_kv", "partition_rows", "sort_rows_ref", "sort_rows_kv_ref", "partition_rows_ref"]
