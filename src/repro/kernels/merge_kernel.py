"""Pallas TPU kernel: cross-block odd-even *merge* passes.

This is the block-level analogue of one OETS compare-exchange: where the
in-block kernels swap neighbouring *lanes*, this kernel "swaps" neighbouring
*blocks* — each grid step loads two adjacent sorted blocks of ``block`` lanes
into VMEM and merges them, leaving the smaller half in the left block and the
larger half in the right. ``core/blocksort.py`` alternates even/odd pairings
of this kernel until the whole row is globally sorted, exactly as OETS
alternates even/odd lane pairings.

The merge itself is a bitonic merge network specialised for asc++asc input:
one reflected compare-exchange (partner ``(2B-1) - i``, i.e. the lane-reversed
array) splits the pair into a low half and a high half, then ``log2(B)``
XOR-partner stages (the same two-``roll`` bit-select as the bitonic sort
kernel) finish each half. ``log2(2B)`` phases total, all lane-parallel VPU
work, no gather/scatter. ``block`` must be a power of two (the orchestrator
guarantees it).

Variadic like the in-block kernels: ``merge_adjacent_lex_pallas(*arrs)``
merges tuples of same-shape arrays by lexicographic compare
(``kernels/lex.py``); key-only and key-value are the 1- and 2-tuple cases.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .lex import lex_gt_lanes, map_lanes, select_lanes

__all__ = [
    "merge_rows_lex_kernel",
    "merge_adjacent_lex_pallas",
    "merge_adjacent_pallas",
    "merge_adjacent_kv_pallas",
]


def _merge_network(arrs, block):
    """Merge (RB, 2*block) rows whose halves are each sorted ascending."""
    col = lax.broadcasted_iota(jnp.int32, arrs[0].shape, 1)

    # Reflected stage: compare lane i with lane (2B-1)-i, min to the low half.
    # Turns asc++asc into low-half/high-half, each bitonic. The compare is
    # full-tuple lex (see kernels/lex.py): trailing payload lanes break ties,
    # so padding tuples (sentinel, ..., sentinel) stay strictly maximal and
    # can never displace a real payload that shares the sentinel key.
    partners = map_lanes(lambda a: jnp.flip(a, axis=1), arrs)
    lower = col < block
    swap = jnp.where(lower, lex_gt_lanes(arrs, partners),
                     lex_gt_lanes(partners, arrs))
    arrs = select_lanes(swap, partners, arrs)

    # XOR-partner clean-up stages, ascending everywhere. j < block, so the
    # rolls never cross the half boundary for any lane's true partner.
    j = block // 2
    while j >= 1:
        bit_unset = (col & j) == 0
        partners = [
            jnp.where(bit_unset, jnp.roll(a, -j, axis=1), jnp.roll(a, j, axis=1))
            for a in arrs
        ]
        swap = jnp.where(bit_unset, lex_gt_lanes(arrs, partners),
                         lex_gt_lanes(partners, arrs))
        arrs = select_lanes(swap, partners, arrs)
        j //= 2
    return arrs


def merge_rows_lex_kernel(*refs, block):
    n = len(refs) // 2
    out = _merge_network(tuple(r[...] for r in refs[:n]), block)
    for r, o in zip(refs[n:], out):
        r[...] = o


def _row_block(rows: int) -> int:
    return min(rows, 8)


def _check(rows, cols, block, row_block):
    if block < 1 or block & (block - 1):
        raise ValueError("block must be a power of two")
    if cols % (2 * block):
        raise ValueError("cols must cover whole pairs of blocks")
    rb = row_block or _row_block(rows)
    if rows % rb:
        raise ValueError("rows must be a multiple of the row block")
    return rb, cols // (2 * block)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "row_block"))
def merge_adjacent_lex_pallas(*arrs, block: int, interpret: bool = False,
                              row_block: int | None = None):
    """One merge round over (R, npairs * 2 * block): pair p (cols
    [2pB, 2pB+2B)) is merged in place, comparing full lexicographic tuples.
    Each pair's halves must be sorted ascending; the caller slices the row to
    select even or odd pairing. Returns the merged tuple."""
    rows, cols = arrs[0].shape
    rb, npairs = _check(rows, cols, block, row_block)
    kern = functools.partial(merge_rows_lex_kernel, block=block)
    spec = pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j))
    return pl.pallas_call(
        kern,
        out_shape=tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs),
        grid=(rows // rb, npairs),
        in_specs=[spec] * len(arrs),
        out_specs=tuple([spec] * len(arrs)),
        interpret=interpret,
    )(*arrs)


def merge_adjacent_pallas(x, *, block: int, interpret: bool = False,
                          row_block: int | None = None):
    """Key-only special case."""
    (out,) = merge_adjacent_lex_pallas(x, block=block, interpret=interpret,
                                       row_block=row_block)
    return out


def merge_adjacent_kv_pallas(keys, vals, *, block: int, interpret: bool = False,
                             row_block: int | None = None):
    """Key-value special case: the payload is the 2nd (tie-break) lane."""
    return merge_adjacent_lex_pallas(keys, vals, block=block,
                                     interpret=interpret, row_block=row_block)
