"""Pallas TPU kernel: cross-block odd-even *merge* passes.

This is the block-level analogue of one OETS compare-exchange: where the
in-block kernels swap neighbouring *lanes*, this kernel "swaps" neighbouring
*blocks* — each grid step loads two adjacent sorted blocks of ``block`` lanes
into VMEM and merges them, leaving the smaller half in the left block and the
larger half in the right. ``core/blocksort.py`` alternates even/odd pairings
of this kernel until the whole row is globally sorted, exactly as OETS
alternates even/odd lane pairings.

The merge itself is a bitonic merge network specialised for asc++asc input:
one reflected compare-exchange (partner ``(2B-1) - i``, i.e. the lane-reversed
array) splits the pair into a low half and a high half, then ``log2(B)``
XOR-partner stages (the same two-``roll`` bit-select as the bitonic sort
kernel) finish each half. ``log2(2B)`` phases total, all lane-parallel VPU
work, no gather/scatter. ``block`` must be a power of two (the orchestrator
guarantees it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = [
    "merge_rows_kernel",
    "merge_rows_kv_kernel",
    "merge_adjacent_pallas",
    "merge_adjacent_kv_pallas",
]


def _merge_network(k, v, block):
    """Merge (RB, 2*block) rows whose halves are each sorted ascending."""
    col = lax.broadcasted_iota(jnp.int32, k.shape, 1)

    # Reflected stage: compare lane i with lane (2B-1)-i, min to the low half.
    # Turns asc++asc into low-half/high-half, each bitonic. With payloads the
    # compare is (key, val) lex — see the kv note in bitonic_kernel._stage:
    # padding pairs (sentinel, sentinel) stay strictly maximal, so they can
    # never displace a real payload that shares the sentinel key.
    pk = jnp.flip(k, axis=1)
    lower = col < block
    if v is None:
        gt, lt = k > pk, pk > k
    else:
        pv = jnp.flip(v, axis=1)
        gt = (k > pk) | ((k == pk) & (v > pv))
        lt = (pk > k) | ((pk == k) & (pv > v))
    swap = jnp.where(lower, gt, lt)
    k = jnp.where(swap, pk, k)
    if v is not None:
        v = jnp.where(swap, pv, v)

    # XOR-partner clean-up stages, ascending everywhere. j < block, so the
    # rolls never cross the half boundary for any lane's true partner.
    j = block // 2
    while j >= 1:
        bit_unset = (col & j) == 0
        pk = jnp.where(bit_unset, jnp.roll(k, -j, axis=1), jnp.roll(k, j, axis=1))
        if v is None:
            swap = jnp.where(bit_unset, k > pk, pk > k)
        else:
            pv = jnp.where(bit_unset, jnp.roll(v, -j, axis=1), jnp.roll(v, j, axis=1))
            swap = jnp.where(bit_unset,
                             (k > pk) | ((k == pk) & (v > pv)),
                             (pk > k) | ((pk == k) & (pv > v)))
        k = jnp.where(swap, pk, k)
        if v is not None:
            v = jnp.where(swap, pv, v)
        j //= 2
    return k, v


def merge_rows_kernel(x_ref, o_ref, *, block):
    k, _ = _merge_network(x_ref[...], None, block)
    o_ref[...] = k


def merge_rows_kv_kernel(k_ref, v_ref, ok_ref, ov_ref, *, block):
    k, v = _merge_network(k_ref[...], v_ref[...], block)
    ok_ref[...] = k
    ov_ref[...] = v


def _row_block(rows: int) -> int:
    return min(rows, 8)


def _check(rows, cols, block, row_block):
    if block < 1 or block & (block - 1):
        raise ValueError("block must be a power of two")
    if cols % (2 * block):
        raise ValueError("cols must cover whole pairs of blocks")
    rb = row_block or _row_block(rows)
    if rows % rb:
        raise ValueError("rows must be a multiple of the row block")
    return rb, cols // (2 * block)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "row_block"))
def merge_adjacent_pallas(x, *, block: int, interpret: bool = False,
                          row_block: int | None = None):
    """One merge round over (R, npairs * 2 * block): pair p (cols
    [2pB, 2pB+2B)) is merged in place. Each pair's halves must be sorted
    ascending; the caller slices the row to select even or odd pairing."""
    rows, cols = x.shape
    rb, npairs = _check(rows, cols, block, row_block)
    kern = functools.partial(merge_rows_kernel, block=block)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(rows // rb, npairs),
        in_specs=[pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "row_block"))
def merge_adjacent_kv_pallas(keys, vals, *, block: int, interpret: bool = False,
                             row_block: int | None = None):
    rows, cols = keys.shape
    rb, npairs = _check(rows, cols, block, row_block)
    kern = functools.partial(merge_rows_kv_kernel, block=block)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct(keys.shape, keys.dtype),
            jax.ShapeDtypeStruct(vals.shape, vals.dtype),
        ),
        grid=(rows // rb, npairs),
        in_specs=[
            pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 2 * block), lambda i, j: (i, j)),
        ),
        interpret=interpret,
    )(keys, vals)
