"""Pallas TPU kernel: splitter partitioning — the paper's *distribute
elements into sub-arrays* step as a vector kernel.

Given row-major keys and a sorted splitter list, emits each element's bucket
id (count of splitters <= key) and the per-row bucket histogram. This is the
local phase of the distributed sample sort (core/distributed.py) and the
length-histogram phase of the paper's pre-processing, fused into one VMEM
pass: bucket ids come from S broadcast compare-accumulates across lanes,
histograms from B masked popcounts — no gather/scatter, MXU-free VPU work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["partition_rows_kernel", "partition_rows_pallas"]


def partition_rows_kernel(x_ref, spl_ref, bid_ref, cnt_ref, *, n_splitters, n_buckets):
    x = x_ref[...]                       # (RB, C)
    spl = spl_ref[...]                   # (1, S_pad)
    bucket = jnp.zeros(x.shape, jnp.int32)
    for j in range(n_splitters):         # static, <= 127
        bucket = bucket + (x >= spl[0, j]).astype(jnp.int32)
    bid_ref[...] = bucket
    for p in range(n_buckets):           # static histogram over lanes
        cnt_ref[:, p] = jnp.sum((bucket == p).astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("n_splitters", "n_buckets", "interpret", "row_block"))
def partition_rows_pallas(x, splitters_padded, *, n_splitters: int,
                          n_buckets: int, interpret: bool = False,
                          row_block: int | None = None):
    """x (R, C) int32; splitters_padded (1, S_pad). Returns
    (bucket_ids (R, C) int32, counts (R, n_buckets) int32)."""
    rows, cols = x.shape
    rb = row_block or min(rows, 8)
    kern = functools.partial(
        partition_rows_kernel, n_splitters=n_splitters, n_buckets=n_buckets)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((rows, cols), jnp.int32),
            jax.ShapeDtypeStruct((rows, n_buckets), jnp.int32),
        ),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, splitters_padded.shape[1]), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((rb, n_buckets), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(x, splitters_padded)
