"""Pure-jnp oracles for the sorting kernels.

Row-wise semantics: every kernel sorts the *last* axis of a (rows, cols)
array independently per row — rows are the paper's length-buckets mapped to
TPU sublanes, columns are the elements mapped to vector lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sort_rows_ref", "sort_rows_kv_ref", "partition_rows_ref"]


def sort_rows_ref(x):
    """Ascending sort along the last axis."""
    return jnp.sort(x, axis=-1)


def partition_rows_ref(keys, splitters):
    """Oracle for the splitter-partition kernel: bucket id = #splitters <= key."""
    bid = jnp.searchsorted(splitters.astype(jnp.int32),
                           keys.astype(jnp.int32).reshape(-1),
                           side="right").reshape(keys.shape).astype(jnp.int32)
    n_buckets = splitters.shape[0] + 1
    onehot = jax.nn.one_hot(bid, n_buckets, dtype=jnp.int32)
    return bid, jnp.sum(onehot, axis=1)


def sort_rows_kv_ref(keys, vals):
    """Ascending sort of ``keys`` along the last axis, permuting ``vals``.

    Stability note: ties are broken by original position (argsort is stable),
    matching the kernels only up to equal-key permutations — tests compare
    gathered keys and value *multisets* per key group.
    """
    order = jnp.argsort(keys, axis=-1, stable=True)
    return jnp.take_along_axis(keys, order, axis=-1), jnp.take_along_axis(vals, order, axis=-1)
