"""Shared length-histogram / bucket-assignment utility — the paper's phase-1
count pass, implemented once.

Before this module, the statistic lived twice: ``data.bucketing`` derived
quantile bucket bounds with its own sort-and-index loop, and
``serve.scheduler`` walked every request through a linear bound scan. Both
now route here; the device-side rendering of the same count is the histogram
output of ``kernels/distribute_kernel.py``.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["length_histogram", "assign_buckets", "bucket_of",
           "quantile_bounds"]


def length_histogram(lengths: Sequence[int],
                     num_bins: int | None = None) -> np.ndarray:
    """Counts per exact length: ``out[l]`` = number of items of length
    ``l``. ``num_bins`` pins the output size (default: max length + 1);
    empty input gives an all-zero (or empty) histogram."""
    ls = np.asarray(lengths, dtype=np.int64)
    if num_bins is None:
        num_bins = int(ls.max()) + 1 if ls.size else 0
    return np.bincount(ls, minlength=num_bins)[:num_bins] if num_bins \
        else np.zeros((0,), np.int64)


def assign_buckets(lengths: Sequence[int], bounds: Sequence[int],
                   clamp: bool = True) -> np.ndarray:
    """Vectorized bucket assignment: item of length ``l`` goes to the first
    bucket whose upper bound is ``>= l``. Lengths beyond the last bound land
    in the last bucket when ``clamp`` (the scheduler's admission contract)
    and raise ``ValueError`` otherwise (the batcher's). ``bounds`` must
    ascend (``quantile_bounds`` output is) — the searchsorted assignment is
    meaningless on unsorted bounds, so they are rejected rather than
    silently mis-bucketed."""
    ls = np.asarray(lengths, dtype=np.int64)
    if len(bounds) == 0:
        if ls.size:
            raise ValueError("no buckets planned (empty bounds)")
        return np.zeros((0,), np.int64)
    barr = np.asarray(bounds, dtype=np.int64)
    if (np.diff(barr) < 0).any():
        raise ValueError(f"bucket bounds must be ascending, got {list(bounds)}")
    idx = np.searchsorted(barr, ls, side="left")
    over = idx >= len(bounds)
    if over.any():
        if not clamp:
            bad = int(ls[over][0])
            raise ValueError(
                f"length {bad} exceeds largest bucket {bounds[-1]}")
        idx = np.minimum(idx, len(bounds) - 1)
    return idx.astype(np.int64)


def bucket_of(length: int, bounds: Sequence[int], clamp: bool = True) -> int:
    """Scalar view of :func:`assign_buckets`."""
    return int(assign_buckets([length], bounds, clamp=clamp)[0])


def quantile_bounds(lengths: Sequence[int], n_buckets: int = 8) -> List[int]:
    """Quantile-based bucket upper bounds covering the observed lengths
    (the paper: sub-array sizes "decided by the number of elements with the
    same length"). Empty input plans no buckets — ``[]``."""
    ls = np.sort(np.asarray(lengths))
    if ls.size == 0:
        return []
    qs = np.linspace(0, 1, n_buckets + 1)[1:]
    bounds = sorted(set(
        int(ls[min(int(q * (len(ls) - 1)), len(ls) - 1)]) for q in qs))
    if bounds[-1] < ls[-1]:
        bounds.append(int(ls[-1]))
    return bounds
