"""Chunked ingest: stream datasets larger than one device launch through the
fused bucketize + segmented-sort program.

The shape is the MPI follow-up's (*Parallelize Bubble and Merge Sort
Algorithms Using MPI*): produce locally sorted runs, combine them by merge.
Here a "processor" is one device launch — each fixed-size chunk of packed
words runs ``core.bucketing.sorted_packed`` (on-device distribute ->
segmented in-bucket sort -> shortlex compaction) to yield a
:class:`SortedRun`, and runs combine with the packed rank-key merge path of
``pipeline.merge`` / ``kernels.ops.merge_sorted_lex``. The *per-launch*
working set is bounded by the chunk size — the fused program's bucket
tensor is ``O(num_buckets * chunk_capacity)`` regardless of total input
length, and every chunk reuses the same compiled executable (chunks share
one static shape; only the tail chunk re-traces). The run merge is bounded
the same way per compare: each tournament round ranks by binary search over
the packed shortlex keys (O(n log n) gathers — the fused program emits the
keys during compaction, see ``SortedRun.cmp_lanes``), never by the
O(|a|·|b|·L) broadcast the jnp-level combine used to pay.

Runs carry an explicit length lane so the merge key is the shortlex tuple
``(length, lane_0, ..., lane_L-1)`` — packed keys alone order
byte-lexicographically ("aa" < "z"), not shortlex ("z" < "aa").

The words front-end also overlaps its host work with the device: chunk
``i+1`` packs on a worker thread while chunk ``i``'s fused launch is in
flight (async dispatch already queues the device side, so the only serial
cost left was the packing loop itself).
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import packing
from ..core.bucketing import sorted_packed
from ..kernels.keypack import cmp_from_packed, packed_cmp_lanes, shortlex_max_values
from .merge import merge_runs

__all__ = ["DEFAULT_CHUNK", "SortedRun", "sorted_run",
           "chunked_sort_packed", "chunked_sort_words"]

# Chunk size balancing launch count against the fused program's bucket
# tensor footprint (num_buckets * capacity * lanes uint32 slots; capacity
# <= chunk). Any multiple of the 128-lane tile works.
DEFAULT_CHUNK = 4096


@dataclass
class SortedRun:
    """One shortlex-sorted run: ``lengths[i]`` is the byte length of the
    word packed in ``keys[i]``; rows ascend by ``(length, bytes)``.
    ``packed`` optionally holds the 1-2 uint32 rank-key lanes of the
    shortlex tuples (``kernels/keypack.py``), emitted for free by the fused
    per-chunk program."""

    lengths: jnp.ndarray   # (m,) int32
    keys: jnp.ndarray      # (m, lanes) uint32
    packed: Optional[Tuple] = None

    def lanes(self):
        """The run as a merge-ready lex tuple (length lane first)."""
        return (self.lengths,
                *(self.keys[:, l] for l in range(self.keys.shape[1])))

    def cmp_lanes(self):
        """The minimal compare-lane list for ranking this run in a merge:
        the precomputed rank keys + keypack's tie-break suffix, or a fresh
        packing when the run was built without one."""
        lanes = list(self.lanes())
        mv = shortlex_max_values(self.keys.shape[1])
        if self.packed is None:
            return packed_cmp_lanes(lanes, mv)
        return cmp_from_packed(list(self.packed), lanes, mv)

    @classmethod
    def from_lanes(cls, lanes):
        return cls(lengths=lanes[0], keys=jnp.stack(lanes[1:], axis=1))


def sorted_run(keys, algorithm: str = "pallas",
               capacity: int | None = None) -> SortedRun:
    """Sort one packed (n, lanes) chunk on device into a :class:`SortedRun`
    (the per-chunk fused bucketize + segmented-sort launch, rank keys
    included)."""
    lengths, sorted_keys, packed = sorted_packed(
        keys, algorithm=algorithm, capacity=capacity, return_packed=True)
    return SortedRun(lengths=lengths, keys=sorted_keys, packed=packed)


def _merged_run(runs) -> SortedRun:
    if len(runs) == 1:
        return runs[0]
    merged = merge_runs([r.lanes() for r in runs],
                        cmp_runs=[r.cmp_lanes() for r in runs])
    return SortedRun.from_lanes(merged)


def chunked_sort_packed(keys, chunk_size: int = DEFAULT_CHUNK,
                        algorithm: str = "pallas",
                        capacity: int | None = None) -> SortedRun:
    """Shortlex-sort a packed (n, lanes) uint32 tensor of any length by
    streaming ``chunk_size`` rows per launch and merging the sorted runs.

    ``capacity`` (per-bucket slots of the fused program) defaults to
    ``chunk_size`` for full chunks — the worst case (every word one length),
    so all full chunks share one compiled executable with no histogram sync;
    pass a smaller value to shrink the bucket tensor when the length
    distribution is known. Returns the full-input :class:`SortedRun`.
    """
    keys = jnp.asarray(keys, jnp.uint32)
    n = keys.shape[0]
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    if n == 0:
        return SortedRun(lengths=jnp.zeros((0,), jnp.int32), keys=keys)
    runs = []
    for start in range(0, n, chunk_size):
        chunk = keys[start: start + chunk_size]
        cap = capacity if capacity is not None else int(chunk.shape[0])
        runs.append(sorted_run(chunk, algorithm=algorithm, capacity=cap))
    return _merged_run(runs)


def _prefetch_map(fn, items):
    """Yield ``fn(item)`` in order, computing the *next* call on a worker
    thread while the consumer processes the current result — the
    double-buffering that keeps host packing off the critical path between
    device launches."""
    items = list(items)
    if not items:
        return
    with ThreadPoolExecutor(max_workers=1) as ex:
        fut = ex.submit(fn, items[0])
        for nxt in items[1:]:
            cur = fut.result()
            fut = ex.submit(fn, nxt)
            yield cur
        yield fut.result()


def chunked_sort_words(words, chunk_size: int = DEFAULT_CHUNK,
                       algorithm: str = "pallas",
                       capacity: int | None = None) -> list:
    """Words front-end: chunked device sort + packed-rank-key run merge,
    unpack once (egress). Returns the words in shortlex order —
    bit-identical to ``core.bucketed_sort_words`` but with per-launch device
    memory bounded by ``chunk_size``, and with each chunk packed (at the
    global width, so all runs share one lane count) on a worker thread while
    the previous chunk's fused launch is in flight."""
    words = list(words)
    if not words:
        return []
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    width = max(packing.byte_length(w) for w in words)
    chunks = [words[i: i + chunk_size]
              for i in range(0, len(words), chunk_size)]
    runs = []
    for keys in _prefetch_map(
            lambda ws: jnp.asarray(packing.pack_words(ws, width=width)),
            chunks):
        cap = capacity if capacity is not None else int(keys.shape[0])
        runs.append(sorted_run(keys, algorithm=algorithm, capacity=cap))
    run = _merged_run(runs)
    return packing.unpack_words(np.asarray(run.keys))
