"""Chunked ingest: stream datasets larger than one device launch through the
fused bucketize + segmented-sort program.

The shape is the MPI follow-up's (*Parallelize Bubble and Merge Sort
Algorithms Using MPI*): produce locally sorted runs, combine them by merge.
Here a "processor" is one device launch — each fixed-size chunk of packed
words runs ``core.bucketing.sorted_packed`` (on-device distribute ->
segmented in-bucket sort -> shortlex compaction) to yield a
:class:`SortedRun`, and runs combine with the merge-path tournament of
``pipeline.merge``. The *per-launch* working set is bounded by the chunk
size — the fused program's bucket tensor is ``O(num_buckets *
chunk_capacity)`` regardless of total input length, and every chunk reuses
the same compiled executable (chunks share one static shape; only the tail
chunk re-traces). The run *merge* is not yet similarly bounded: multi-lane
tuples take ``lex_rank_count``'s O(|a|·|b|) broadcast compare, so the final
tournament rounds dominate memory at large n — the u64 composite rank key
that would make every round searchsorted-cheap is a ROADMAP open item.

Runs carry an explicit length lane so the merge key is the shortlex tuple
``(length, lane_0, ..., lane_L-1)`` — packed keys alone order
byte-lexicographically ("aa" < "z"), not shortlex ("z" < "aa").
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core import packing
from ..core.bucketing import sorted_packed
from .merge import merge_runs

__all__ = ["DEFAULT_CHUNK", "SortedRun", "sorted_run",
           "chunked_sort_packed", "chunked_sort_words"]

# Chunk size balancing launch count against the fused program's bucket
# tensor footprint (num_buckets * capacity * lanes uint32 slots; capacity
# <= chunk). Any multiple of the 128-lane tile works.
DEFAULT_CHUNK = 4096


@dataclass
class SortedRun:
    """One shortlex-sorted run: ``lengths[i]`` is the byte length of the
    word packed in ``keys[i]``; rows ascend by ``(length, bytes)``."""

    lengths: jnp.ndarray   # (m,) int32
    keys: jnp.ndarray      # (m, lanes) uint32

    def lanes(self):
        """The run as a merge-ready lex tuple (length lane first)."""
        return (self.lengths,
                *(self.keys[:, l] for l in range(self.keys.shape[1])))

    @classmethod
    def from_lanes(cls, lanes):
        return cls(lengths=lanes[0], keys=jnp.stack(lanes[1:], axis=1))


def sorted_run(keys, algorithm: str = "pallas",
               capacity: int | None = None) -> SortedRun:
    """Sort one packed (n, lanes) chunk on device into a :class:`SortedRun`
    (the per-chunk fused bucketize + segmented-sort launch)."""
    lengths, sorted_keys = sorted_packed(keys, algorithm=algorithm,
                                         capacity=capacity)
    return SortedRun(lengths=lengths, keys=sorted_keys)


def chunked_sort_packed(keys, chunk_size: int = DEFAULT_CHUNK,
                        algorithm: str = "pallas",
                        capacity: int | None = None) -> SortedRun:
    """Shortlex-sort a packed (n, lanes) uint32 tensor of any length by
    streaming ``chunk_size`` rows per launch and merging the sorted runs.

    ``capacity`` (per-bucket slots of the fused program) defaults to
    ``chunk_size`` for full chunks — the worst case (every word one length),
    so all full chunks share one compiled executable with no histogram sync;
    pass a smaller value to shrink the bucket tensor when the length
    distribution is known. Returns the full-input :class:`SortedRun`.
    """
    keys = jnp.asarray(keys, jnp.uint32)
    n = keys.shape[0]
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    if n == 0:
        return SortedRun(lengths=jnp.zeros((0,), jnp.int32), keys=keys)
    runs = []
    for start in range(0, n, chunk_size):
        chunk = keys[start: start + chunk_size]
        cap = capacity if capacity is not None else int(chunk.shape[0])
        runs.append(sorted_run(chunk, algorithm=algorithm, capacity=cap))
    if len(runs) == 1:
        return runs[0]
    return SortedRun.from_lanes(merge_runs([r.lanes() for r in runs]))


def chunked_sort_words(words, chunk_size: int = DEFAULT_CHUNK,
                       algorithm: str = "pallas",
                       capacity: int | None = None) -> list:
    """Words front-end: pack once at the global width (ingress), chunked
    device sort + run merge, unpack once (egress). Returns the words in
    shortlex order — bit-identical to ``core.bucketed_sort_words`` but with
    per-launch device memory bounded by ``chunk_size``."""
    words = list(words)
    if not words:
        return []
    keys = jnp.asarray(packing.pack_words(words))
    run = chunked_sort_packed(keys, chunk_size=chunk_size,
                              algorithm=algorithm, capacity=capacity)
    return packing.unpack_words(np.asarray(run.keys))
