"""Chunked ingest: stream datasets larger than one device launch through the
fused bucketize + segmented-sort program.

The shape is the MPI follow-up's (*Parallelize Bubble and Merge Sort
Algorithms Using MPI*): produce locally sorted runs, combine them by merge.
Here a "processor" is one device launch — each fixed-size chunk of packed
words runs ``core.bucketing.sorted_packed`` (on-device distribute ->
segmented in-bucket sort -> shortlex compaction) to yield a
:class:`SortedRun`, and runs combine with the packed rank-key merge path of
``pipeline.merge`` / ``kernels.ops.merge_sorted_lex``. The *per-launch*
working set is bounded by the chunk size — the fused program's bucket
tensor is ``O(num_buckets * chunk_capacity)`` regardless of total input
length, and every chunk reuses the same compiled executable (chunks share
one static shape; only the tail chunk re-traces). The run merge is bounded
the same way per compare: each tournament round ranks by binary search over
the packed shortlex keys (O(n log n) gathers — the fused program emits the
keys during compaction, see ``SortedRun.cmp_lanes``), never by the
O(|a|·|b|·L) broadcast the jnp-level combine used to pay.

Runs carry an explicit length lane so the merge key is the shortlex tuple
``(length, lane_0, ..., lane_L-1)`` — packed keys alone order
byte-lexicographically ("aa" < "z"), not shortlex ("z" < "aa").

Both front-ends overlap their host work with the device through the same
single-worker double buffer (:func:`_prefetch_map`): the words path packs
chunk ``i+1`` on the worker thread while chunk ``i``'s fused launch is in
flight, and the packed path stages chunk ``i+1``'s host->device transfer
the same way (:func:`_stage_chunk`) — so neither packing nor H2D copies
sit on the critical path between launches.
"""

from __future__ import annotations

import logging
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import packing
from ..core.bucketing import sorted_packed
from ..kernels.keypack import cmp_from_packed, packed_cmp_lanes, shortlex_max_values
from .manifest import RunManifest
from .merge import merge_runs
from .validate import check_chunked, keys_digest

__all__ = ["DEFAULT_CHUNK", "SortedRun", "sorted_run",
           "chunked_sort_packed", "chunked_sort_words"]

log = logging.getLogger("repro.pipeline")

_VALIDATE_MODES = ("off", "cheap", "full")

# Chunk size balancing launch count against the fused program's bucket
# tensor footprint (num_buckets * capacity * lanes uint32 slots; capacity
# <= chunk). Any multiple of the 128-lane tile works.
DEFAULT_CHUNK = 4096


@dataclass
class SortedRun:
    """One shortlex-sorted run: ``lengths[i]`` is the byte length of the
    word packed in ``keys[i]``; rows ascend by ``(length, bytes)``.
    ``packed`` optionally holds the 1-2 uint32 rank-key lanes of the
    shortlex tuples (``kernels/keypack.py``), emitted for free by the fused
    per-chunk program."""

    lengths: jnp.ndarray   # (m,) int32
    keys: jnp.ndarray      # (m, lanes) uint32
    packed: Optional[Tuple] = None

    def lanes(self):
        """The run as a merge-ready lex tuple (length lane first)."""
        return (self.lengths,
                *(self.keys[:, l] for l in range(self.keys.shape[1])))

    def cmp_lanes(self):
        """The minimal compare-lane list for ranking this run in a merge:
        the precomputed rank keys + keypack's tie-break suffix, or a fresh
        packing when the run was built without one."""
        lanes = list(self.lanes())
        mv = shortlex_max_values(self.keys.shape[1])
        if self.packed is None:
            return packed_cmp_lanes(lanes, mv)
        return cmp_from_packed(list(self.packed), lanes, mv)

    @classmethod
    def from_lanes(cls, lanes):
        return cls(lengths=lanes[0], keys=jnp.stack(lanes[1:], axis=1))


def sorted_run(keys, algorithm: str = "pallas",
               capacity: int | None = None,
               on_overflow: str = "raise") -> SortedRun:
    """Sort one packed (n, lanes) chunk on device into a :class:`SortedRun`
    (the per-chunk fused bucketize + segmented-sort launch, rank keys
    included). ``on_overflow`` forwards to ``core.bucketing.sorted_packed``
    ('raise' | 'retry' | 'clip')."""
    lengths, sorted_keys, packed = sorted_packed(
        keys, algorithm=algorithm, capacity=capacity, return_packed=True,
        on_overflow=on_overflow)
    return SortedRun(lengths=lengths, keys=sorted_keys, packed=packed)


def _run_from_arrays(lengths, keys, packed) -> SortedRun:
    return SortedRun(
        lengths=jnp.asarray(lengths), keys=jnp.asarray(keys),
        packed=tuple(jnp.asarray(p) for p in packed) if packed else None)


def _ingest_chunk(chunk, chunk_id: int, *, algorithm: str, capacity,
                  on_overflow: str, store, supervisor, need_manifest: bool):
    """Produce one (run, manifest) for a chunk — by resuming it from the
    store when an intact matching run is already persisted, else by
    launching the fused per-chunk sort (through the supervisor's
    ``ingest_chunk`` stage when one is given) and persisting it."""
    if store is not None:
        from ..checkpoint.manager import CorruptSnapshotError
        try:
            man = store.manifest(chunk_id)
        except CorruptSnapshotError as e:
            log.warning("run store: chunk %d manifest unreadable (%s) — "
                        "re-ingesting", chunk_id, e)
            man = None
        if man is not None:
            # A stored run matches iff it holds the same multiset as the
            # incoming chunk — the digest is order-independent, so the
            # *input* chunk digests straight against the *sorted* run's
            # manifest. A mismatch means the store is stale (same path,
            # different dataset): recompute instead of merging foreign data.
            if (man.count == int(chunk.shape[0])
                    and man.digest == keys_digest(chunk)):
                try:
                    loaded = _run_from_arrays(*store.load(chunk_id))
                except CorruptSnapshotError as e:
                    # torn/truncated artifact (kill mid-write never produces
                    # this — the rename is atomic — but disk damage can):
                    # the chunk is still in hand, so recompute, don't fail
                    log.warning("run store: chunk %d unreadable (%s) — "
                                "re-ingesting", chunk_id, e)
                else:
                    if int(loaded.lengths.shape[0]) == man.count:
                        return loaded, man
                    log.warning(
                        "run store: chunk %d loaded %d row(s) but manifest "
                        "records %d — re-ingesting", chunk_id,
                        int(loaded.lengths.shape[0]), man.count)
            else:
                log.warning(
                    "run store: chunk %d manifest does not match incoming "
                    "data (stale store?) — re-ingesting", chunk_id)

    def launch():
        return sorted_run(chunk, algorithm=algorithm, capacity=capacity,
                          on_overflow=on_overflow)

    if supervisor is not None:
        run = supervisor.run_stage("ingest_chunk", launch)
    else:
        run = launch()
    man = (RunManifest.from_run(run, chunk_id)
           if (store is not None or need_manifest) else None)
    if store is not None:
        store.put(man, run)
    return run, man


def _merged_run(runs, manifests=None, supervisor=None,
                merge_engine: str = "auto") -> SortedRun:
    if len(runs) == 1:
        return runs[0]
    merged = merge_runs([r.lanes() for r in runs], engine=merge_engine,
                        cmp_runs=[r.cmp_lanes() for r in runs],
                        manifests=manifests, supervisor=supervisor)
    return SortedRun.from_lanes(merged)


def _stage_chunk(chunk):
    """Stage one pre-packed chunk onto the device. Runs on the prefetch
    worker thread, so chunk ``i+1``'s host->device transfer overlaps chunk
    ``i``'s fused launch — the device half of the ingest double buffer (the
    words front-end overlaps host packing through the same worker)."""
    return jax.device_put(jnp.asarray(chunk, jnp.uint32))


def chunked_sort_packed(keys, chunk_size: int = DEFAULT_CHUNK,
                        algorithm: str = "pallas",
                        capacity: int | None = None,
                        store=None, supervisor=None,
                        validate: str = "off",
                        on_overflow: str = "raise",
                        merge_engine: str = "auto") -> SortedRun:
    """Shortlex-sort a packed (n, lanes) uint32 tensor of any length by
    streaming ``chunk_size`` rows per launch and merging the sorted runs.

    ``capacity`` (per-bucket slots of the fused program) defaults to
    ``chunk_size`` for full chunks — the worst case (every word one length),
    so all full chunks share one compiled executable with no histogram sync;
    pass a smaller value to shrink the bucket tensor when the length
    distribution is known. Returns the full-input :class:`SortedRun`.

    Robustness knobs:

    * ``store`` — a :class:`~repro.pipeline.manifest.RunStore`. Every
      completed run persists atomically before the next chunk launches, and
      chunks whose intact runs are already stored are *loaded, not re-sorted*
      — a killed job resumes from its completed runs.
    * ``supervisor`` — a ``runtime.SortSupervisor``; chunk launches run as
      its ``ingest_chunk`` stage and merge rounds as ``merge_round``, with
      bounded retry on transient :class:`~repro.runtime.sortfault.
      StageFailure`.
    * ``validate`` — ``'off' | 'cheap' | 'full'`` invariant gate
      (``pipeline.validate.check_chunked``): per-run manifest reconciliation
      + merge count/histogram/sortedness conservation; ``'full'`` adds
      order-independent content digests.
    * ``on_overflow`` — bucket-capacity overflow policy for the per-chunk
      fused program ('raise' | 'retry' | 'clip').
    * ``merge_engine`` — run-combine strategy, forwarded to
      ``pipeline.merge.merge_runs``: 'auto'/'kway' (one streaming k-way
      pass), 'kway_kernel' (force the Pallas tier), or 'tournament' (the
      legacy pairwise tree).

    Host (NumPy) input stays host-side until its chunk stages: each chunk's
    H2D transfer runs on the prefetch worker while the previous chunk's
    launch is in flight (:func:`_stage_chunk`).
    """
    if validate not in _VALIDATE_MODES:
        raise ValueError(f"validate must be one of {_VALIDATE_MODES}")
    if not isinstance(keys, jax.Array):
        keys = np.asarray(keys, dtype=np.uint32)
    n = keys.shape[0]
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    if n == 0:
        return SortedRun(lengths=jnp.zeros((0,), jnp.int32),
                         keys=jnp.asarray(keys, jnp.uint32))
    track = store is not None or validate != "off"
    runs, manifests = [], []
    host_chunks = [keys[start: start + chunk_size]
                   for start in range(0, n, chunk_size)]
    for ci, chunk in enumerate(_prefetch_map(_stage_chunk, host_chunks)):
        cap = capacity if capacity is not None else int(chunk.shape[0])
        run, man = _ingest_chunk(
            chunk, ci, algorithm=algorithm, capacity=cap,
            on_overflow=on_overflow, store=store, supervisor=supervisor,
            need_manifest=validate != "off")
        runs.append(run)
        manifests.append(man)
    merged = _merged_run(runs, manifests=manifests if track else None,
                         supervisor=supervisor, merge_engine=merge_engine)
    if validate != "off":
        check_chunked(runs, manifests, merged, mode=validate)
    return merged


def _prefetch_map(fn, items):
    """Yield ``fn(item)`` in order, computing the *next* call on a worker
    thread while the consumer processes the current result — the
    double-buffering that keeps host packing off the critical path between
    device launches."""
    items = list(items)
    if not items:
        return
    with ThreadPoolExecutor(max_workers=1) as ex:
        fut = ex.submit(fn, items[0])
        for nxt in items[1:]:
            cur = fut.result()
            fut = ex.submit(fn, nxt)
            yield cur
        yield fut.result()


def chunked_sort_words(words, chunk_size: int = DEFAULT_CHUNK,
                       algorithm: str = "pallas",
                       capacity: int | None = None,
                       store=None, supervisor=None,
                       validate: str = "off",
                       on_overflow: str = "raise",
                       merge_engine: str = "auto") -> list:
    """Words front-end: chunked device sort + packed-rank-key run merge,
    unpack once (egress). Returns the words in shortlex order —
    bit-identical to ``core.bucketed_sort_words`` but with per-launch device
    memory bounded by ``chunk_size``, and with each chunk packed (at the
    global width, so all runs share one lane count) on a worker thread while
    the previous chunk's fused launch is in flight.

    ``store`` / ``supervisor`` / ``validate`` / ``on_overflow`` /
    ``merge_engine`` behave as on :func:`chunked_sort_packed` —
    persisted-run resume, supervised stage retry, the invariant-validation
    gate, the bucket-overflow policy, and the run-combine strategy."""
    if validate not in _VALIDATE_MODES:
        raise ValueError(f"validate must be one of {_VALIDATE_MODES}")
    words = list(words)
    if not words:
        return []
    if chunk_size < 1:
        raise ValueError("chunk_size must be >= 1")
    width = max(packing.byte_length(w) for w in words)
    chunks = [words[i: i + chunk_size]
              for i in range(0, len(words), chunk_size)]
    track = store is not None or validate != "off"
    runs, manifests = [], []
    for ci, keys in enumerate(_prefetch_map(
            lambda ws: jnp.asarray(packing.pack_words(ws, width=width)),
            chunks)):
        cap = capacity if capacity is not None else int(keys.shape[0])
        run, man = _ingest_chunk(
            keys, ci, algorithm=algorithm, capacity=cap,
            on_overflow=on_overflow, store=store, supervisor=supervisor,
            need_manifest=validate != "off")
        runs.append(run)
        manifests.append(man)
    run = _merged_run(runs, manifests=manifests if track else None,
                      supervisor=supervisor, merge_engine=merge_engine)
    if validate != "off":
        check_chunked(runs, manifests, run, mode=validate)
    return packing.unpack_words(np.asarray(run.keys))
