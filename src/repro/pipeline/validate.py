"""Invariant-validation gate for the sort engine: cheap host-side checks
that catch silent corruption (a flipped element, a dropped run, a
double-counted bucket) before it propagates into downstream consumers.

The sort pipeline's end-to-end contract decomposes into three invariants,
each checkable far cheaper than a full oracle re-sort:

  * **sortedness** — every run / merge / exchange output is lex
    non-decreasing row to row (one vectorised adjacent compare, O(n·L));
  * **count conservation** — element counts reconcile exactly across every
    boundary: chunk -> run (manifest count), runs -> merge (sum), shard ->
    exchange (the exact-count protocol's matrix);
  * **multiset conservation** — the *content* survives, checked via an
    order-independent digest: each row hashes through a lane-FNV +
    splitmix64 finalizer and the digests **sum mod 2^64**, so the digest of
    a union of runs is the sum of their digests — merge output reconciles
    against its inputs with no re-scan of them. (Probabilistic with
    collision odds ~2^-64 per check; a permutation plus sortedness implies
    a correct sort.) The per-length histogram rides along as a second,
    structure-aware conservation check. Float lanes digest through the
    canonical order-bits view (:func:`order_bits_view`, the numpy mirror of
    ``kernels.lex.to_order_bits``) so engines that compare canonically —
    ``-0.0 == +0.0``, NaN payloads interchangeable — reconcile against
    raw-bit oracles on comparator equality, not bit identity.

Both the sortedness compare and the digest run on the same order-bits view,
so "sorted" and "same multiset" here mean exactly what the engines'
comparator (``kernels/lex.py``) means.

``validate='off'|'cheap'|'full'`` on ``pipeline.ingest.chunked_sort_*`` and
``core.distributed.distributed_sort_lex`` maps to: nothing / sortedness +
count + histogram reconciliation / all of that + content digests. All
checks raise :class:`ValidationError` (never assert — the gate is a
production path, tests pin it with seeded corruption).
"""

from __future__ import annotations

import numpy as np

__all__ = ["ValidationError", "order_bits_view", "multiset_digest",
           "keys_digest", "length_histogram_of", "check_lanes_sorted",
           "check_multiset", "check_run", "check_chunked", "check_sharded"]

_U64 = np.uint64
_FNV_PRIME = _U64(0x100000001B3)
_FNV_OFFSET = _U64(0xCBF29CE484222325)
_MIX1 = _U64(0xBF58476D1CE4E5B9)
_MIX2 = _U64(0x94D049BB133111EB)


class ValidationError(RuntimeError):
    """An invariant of the sort pipeline was violated (corruption, loss, or
    duplication detected by the validation gate)."""


def order_bits_view(lane) -> np.ndarray:
    """Numpy mirror of ``kernels.lex.to_order_bits`` for float32 lanes —
    uint32 order bits whose unsigned order is the canonical total order
    (``-0.0`` normalised to ``+0.0``, every NaN above ``+inf``, the
    all-ones pattern strictly maximal). Non-float32 lanes pass through
    unchanged (integers are already totally ordered raw). A differential
    test pins this equal to the jax transform bit for bit on every value
    class except denormals, where XLA flushes to zero in compares and this
    mirror follows IEEE instead."""
    a = np.asarray(lane)
    if a.dtype != np.dtype(np.float32):
        return a
    top = np.uint32(0x80000000)
    b = np.ascontiguousarray(a).view(np.uint32)
    bn = np.where(a == 0, np.uint32(0), b)  # -0.0 -> +0.0 (NaN compares false)
    flipped = np.where((bn & top) != 0, ~bn, bn | top)
    nan_slot = np.where(b == np.uint32(0xFFFFFFFF),
                        np.uint32(0xFFFFFFFF), np.uint32(0xFFFFFFFE))
    return np.where(np.isnan(a), nan_slot, flipped)


def _as_u64(lane) -> np.ndarray:
    """Canonical-bit view of a 1-D lane as uint64: float32 lanes first map
    through :func:`order_bits_view` (so the digest equates exactly what the
    comparator equates — ``-0.0``/``+0.0``, NaN payloads), integer lanes
    reinterpret raw."""
    a = np.ascontiguousarray(order_bits_view(lane))
    if a.dtype.itemsize == 8:
        return a.view(_U64)
    if a.dtype.itemsize == 4:
        return a.view(np.uint32).astype(_U64)
    if a.dtype.itemsize == 2:
        return a.view(np.uint16).astype(_U64)
    return a.view(np.uint8).astype(_U64)


def _mix(h: np.ndarray) -> np.ndarray:
    # splitmix64 finalizer, vectorised (uint64 arithmetic wraps mod 2^64)
    h = h ^ (h >> _U64(30))
    h = h * _MIX1
    h = h ^ (h >> _U64(27))
    h = h * _MIX2
    return h ^ (h >> _U64(31))


def multiset_digest(lanes) -> int:
    """Order-independent content digest of a tuple of parallel 1-D lanes
    (rows are the multiset members). Additive: the digest of a concatenation
    equals the sum of the digests mod 2^64 — the property the merge
    reconciliation leans on."""
    lanes = [np.asarray(l) for l in lanes]
    if not lanes or lanes[0].size == 0:
        return 0
    h = np.full(lanes[0].shape[0], _FNV_OFFSET, _U64)
    for lane in lanes:
        h = (h * _FNV_PRIME) ^ _as_u64(lane)
    return int(np.sum(_mix(h), dtype=_U64))


def keys_digest(keys) -> int:
    """Digest of an (n, lanes) packed word tensor — the per-column lane
    view of :func:`multiset_digest`, shared by pre-sort chunks and sorted
    runs so ingest conservation checks compare like with like."""
    keys = np.asarray(keys)
    return multiset_digest([keys[:, l] for l in range(keys.shape[1])])


def length_histogram_of(lengths, num_buckets: int) -> np.ndarray:
    """Dense per-length element counts (bucket id == byte length)."""
    return np.bincount(np.asarray(lengths), minlength=num_buckets
                       ).astype(np.int64)


def check_lanes_sorted(lanes, what: str = "output"):
    """Raise unless the row tuples of the parallel 1-D ``lanes`` are lex
    non-decreasing (lane 0 most significant) under the canonical total
    order: float lanes compare by :func:`order_bits_view`, so a NaN out of
    tail position *fails* (a raw compare would silently pass — NaN decides
    neither ``<`` nor ``>``). Error messages report the raw values."""
    lanes = [np.asarray(l) for l in lanes]
    n = lanes[0].shape[0]
    if n < 2:
        return
    decided_lt = np.zeros(n - 1, bool)
    decided_gt = np.zeros(n - 1, bool)
    for lane in map(order_bits_view, lanes):
        a, b = lane[:-1], lane[1:]
        undecided = ~(decided_lt | decided_gt)
        decided_gt |= undecided & (a > b)
        decided_lt |= undecided & (a < b)
    if decided_gt.any():
        i = int(np.argmax(decided_gt))
        raise ValidationError(
            f"{what} is not sorted: row {i} > row {i + 1} "
            f"({[l[i] for l in lanes]} > {[l[i + 1] for l in lanes]})")


def check_multiset(in_lanes, out_lanes, what: str = "output"):
    """Raise unless input and output hold the same element multiset
    (count + order-independent digest)."""
    n_in = int(np.asarray(in_lanes[0]).shape[0])
    n_out = int(np.asarray(out_lanes[0]).shape[0])
    if n_in != n_out:
        raise ValidationError(f"{what}: element count changed "
                              f"{n_in} -> {n_out}")
    d_in, d_out = multiset_digest(in_lanes), multiset_digest(out_lanes)
    if d_in != d_out:
        raise ValidationError(
            f"{what}: content digest mismatch ({d_in:#018x} != "
            f"{d_out:#018x}) — elements were altered, not permuted")


def check_run(run, manifest, mode: str = "cheap"):
    """Reconcile one sorted run against its :class:`~repro.pipeline.manifest.
    RunManifest`: exact count, per-length histogram, sortedness, and (mode
    ``'full'``) the content digest. The gate a resuming job runs before
    trusting a stored run, and the per-chunk gate of
    ``chunked_sort_*(validate=...)``."""
    lengths = np.asarray(run.lengths)
    keys = np.asarray(run.keys)
    if lengths.shape[0] != manifest.count:
        raise ValidationError(
            f"run {manifest.chunk_id}: count {lengths.shape[0]} != manifest "
            f"count {manifest.count}")
    hist = length_histogram_of(lengths, len(manifest.length_histogram))
    if hist.tolist() != list(manifest.length_histogram):
        raise ValidationError(
            f"run {manifest.chunk_id}: length histogram mismatch "
            f"{hist.tolist()} != {list(manifest.length_histogram)}")
    check_lanes_sorted(
        [lengths] + [keys[:, l] for l in range(keys.shape[1])],
        what=f"run {manifest.chunk_id}")
    if mode == "full" and keys_digest(keys) != manifest.digest:
        raise ValidationError(
            f"run {manifest.chunk_id}: content digest mismatch — run "
            f"elements differ from the manifested multiset")


def check_chunked(runs, manifests, merged, mode: str = "cheap"):
    """The end-to-end gate of ``chunked_sort_*``: every run reconciles
    against its manifest, and the merged output conserves the runs' total
    count, per-length histogram, and (``'full'``) summed content digest —
    catching a dropped run, a double-counted bucket, or a flipped element
    without re-sorting anything."""
    for run, man in zip(runs, manifests):
        check_run(run, man, mode)
    m_lengths = np.asarray(merged.lengths)
    m_keys = np.asarray(merged.keys)
    total = sum(m.count for m in manifests)
    if m_lengths.shape[0] != total:
        raise ValidationError(
            f"merge lost or duplicated elements: output count "
            f"{m_lengths.shape[0]} != sum of run counts {total}")
    nb = max((len(m.length_histogram) for m in manifests), default=1)
    want_hist = np.zeros(nb, np.int64)
    for m in manifests:
        want_hist[: len(m.length_histogram)] += np.asarray(
            m.length_histogram, np.int64)
    got_hist = length_histogram_of(m_lengths, nb)
    if got_hist.tolist() != want_hist.tolist():
        raise ValidationError(
            f"merge length histogram mismatch: {got_hist.tolist()} != "
            f"{want_hist.tolist()}")
    check_lanes_sorted(
        [m_lengths] + [m_keys[:, l] for l in range(m_keys.shape[1])],
        what="merged output")
    if mode == "full":
        want_digest = sum(m.digest for m in manifests) % (1 << 64)
        got_digest = keys_digest(m_keys)
        if got_digest != want_digest:
            raise ValidationError(
                "merged output content digest mismatch — elements were "
                "altered across the merge")


def check_sharded(run_manifests, shard_manifests, mode: str = "cheap"):
    """Metadata-only gate for a shard-spilled distributed sort: prove the
    shards jointly ARE the sorted union of the ingest runs without
    rescanning any data. Checks (all on manifests):

      * **count conservation** — sum of shard counts == sum of run counts;
      * **histogram conservation** — per-length counts reconcile the same
        way (structure-aware: a swap between length buckets that preserves
        the total still fails);
      * **boundary ordering** — shard *i*'s max key tuple lex<= shard
        *i+1*'s min key tuple (shards are keyed by destination order, so
        their concatenation is globally sorted iff each is internally
        sorted — which :func:`check_run` proves per shard — and the
        boundaries are ordered);
      * (mode ``'full'``) **digest conservation** — shard digests sum mod
        2^64 to the run digests' sum (the additive multiset property: the
        union's digest is the sum, no rescan needed).

    ``shard_manifests`` come ordered by destination. Raises
    :class:`ValidationError` naming the first violated invariant."""
    shard_manifests = list(shard_manifests)
    run_manifests = list(run_manifests)
    total_runs = sum(m.count for m in run_manifests)
    total_shards = sum(m.count for m in shard_manifests)
    if total_shards != total_runs:
        raise ValidationError(
            f"shard combine lost or duplicated elements: shard counts sum "
            f"to {total_shards} != run counts sum {total_runs}")
    nb = max((len(m.length_histogram)
              for m in run_manifests + shard_manifests), default=1)
    want = np.zeros(nb, np.int64)
    got = np.zeros(nb, np.int64)
    for m in run_manifests:
        want[: len(m.length_histogram)] += np.asarray(m.length_histogram,
                                                      np.int64)
    for m in shard_manifests:
        got[: len(m.length_histogram)] += np.asarray(m.length_histogram,
                                                     np.int64)
    if got.tolist() != want.tolist():
        raise ValidationError(
            f"shard length histogram mismatch: {got.tolist()} != "
            f"{want.tolist()}")
    occupied = [m for m in shard_manifests if m.count]
    for a, b in zip(occupied, occupied[1:]):
        if tuple(a.max_key) > tuple(b.min_key):
            raise ValidationError(
                f"shard boundary disorder: shard {a.chunk_id} max key "
                f"{a.max_key} > shard {b.chunk_id} min key {b.min_key}")
    if mode == "full":
        want_digest = sum(m.digest for m in run_manifests) % (1 << 64)
        got_digest = sum(m.digest for m in shard_manifests) % (1 << 64)
        if got_digest != want_digest:
            raise ValidationError(
                "shard content digest mismatch: shard digests sum to "
                f"{got_digest:#018x} != run digests sum "
                f"{want_digest:#018x} — elements were altered across the "
                "combine")
