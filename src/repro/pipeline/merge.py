"""Run combiner: k-way merge of sorted lex-tuple runs on device.

A *run* here is a tuple of parallel 1-D arrays already sorted by the
lane-by-lane lexicographic order (``kernels/lex.py`` conventions — for the
word pipeline the tuple is ``(length, key_lane_0, ..., key_lane_L-1)``, i.e.
shortlex). The default combine is the ONE-launch streaming k-way merge
(``kernels.ops.merge_runs_lex`` over ``kernels/kway_kernel.py``): global
merge-path ranks split the output into blocks once, and the data streams
through a single pass — one scatter per lane off-TPU, or the
double-buffered Pallas streaming kernel on TPU.

The pre-PR-9 tournament tree (``engine='tournament'``: ceil(log2 k) rounds
of pairwise ``merge_sorted_lex``) is kept as the fallback and as the
differential oracle the tests hold the streaming path against — every round
is a full pass over all the data, which is exactly the log2(k)x HBM-traffic
multiple the streaming merge removes.

Both paths work in the *extended* representation: each run's packed compare
lanes (1-2 uint32 rank keys + keypack's minimal tie-break suffix) ride
alongside the data lanes, so ranking never re-packs. ``cmp_runs`` lets the
chunked ingest hand over rank keys the fused bucketize program already
computed.
"""

from __future__ import annotations

from ..kernels.keypack import packed_cmp_lanes
from ..kernels.ops import merge_runs_lex, merge_sorted_lex

__all__ = ["merge_two", "merge_runs"]

_ENGINES = ("auto", "kway", "kway_kernel", "tournament")


def merge_two(a_lanes, b_lanes, engine: str = "auto", max_values=None):
    """Merge two sorted lex-tuple runs (tuples of parallel 1-D arrays, may
    differ in length) into one sorted run. Thin alias of
    ``kernels.ops.merge_sorted_lex``, which validates arity and
    short-circuits empty runs without device work."""
    return merge_sorted_lex(tuple(a_lanes), tuple(b_lanes), engine=engine,
                            max_values=max_values)


def merge_runs(runs, engine: str = "auto", max_values=None, cmp_runs=None,
               manifests=None, supervisor=None,
               interpret: bool | None = None,
               block_size: int | None = None):
    """k-way merge of sorted runs into one. ``runs``: list of sorted
    lex-tuple runs of equal arity; an empty list returns ``()`` and a single
    run is returned as-is — both without touching the device.

    ``engine`` picks the combine strategy:

    - ``'kway'`` (and ``'auto'``, which always resolves to it): ONE call
      into ``ops.merge_runs_lex`` — a single streaming pass for any k,
      executed through the supervisor stage ``'streaming_combine'``.
    - ``'kway_kernel'``: same, but forces the Pallas streaming kernel tier
      even where ``choose_kway_engine`` would pick the jnp scatter (the
      conformance matrix uses this to run the kernel under the interpreter).
    - ``'tournament'``: the legacy pairwise tree, ceil(log2 k) rounds each
      through supervisor stage ``'merge_round'`` — the fallback and the
      differential oracle; outputs are bit-identical across engines.

    ``cmp_runs``: optional parallel list of pre-packed compare-lane lists
    (e.g. ``SortedRun.cmp_lanes()`` — rank keys the fused per-chunk program
    already emitted); ``None`` packs them here via
    ``keypack.packed_cmp_lanes`` with ``max_values``. ``manifests``:
    optional parallel list of ``RunManifest``-likes; each run's element
    count is reconciled against its manifest *before* any device work, so a
    truncated/stale run (e.g. loaded from a resume store) fails loudly
    instead of merging short. ``supervisor``: optional
    ``runtime.SortSupervisor`` — combine stages are pure functions of their
    input runs, so a failed stage simply re-executes. ``interpret`` /
    ``block_size`` forward to the kernel tiers (``None`` = auto)."""
    if engine not in _ENGINES:
        raise ValueError(f"unknown merge_runs engine {engine!r}")
    runs = [tuple(r) for r in runs]
    if manifests is not None:
        from .validate import ValidationError
        if len(manifests) != len(runs):
            raise ValueError("manifests must parallel runs")
        for r, m in zip(runs, manifests):
            if r and int(r[0].shape[0]) != m.count:
                raise ValidationError(
                    f"run {m.chunk_id}: {int(r[0].shape[0])} element(s) "
                    f"but manifest records {m.count} — refusing to merge")
    if not runs:
        return ()
    if len(runs) == 1:
        return runs[0]
    arity = len(runs[0])
    if any(len(r) != arity for r in runs):
        raise ValueError("runs must have the same lane arity")
    if cmp_runs is None:
        cmp_runs = [packed_cmp_lanes(list(r), max_values) for r in runs]
    ext = [tuple(c) + r for c, r in zip(cmp_runs, runs)]
    n_cmp = len(ext[0]) - arity

    if engine != "tournament":
        ops_engine = "kernel" if engine == "kway_kernel" else "auto"

        def combine(ext_rs):
            return merge_runs_lex(ext_rs, engine=ops_engine, n_cmp=n_cmp,
                                  block_size=block_size,
                                  interpret=interpret)

        if supervisor is None:
            merged = combine(ext)
        else:
            merged = supervisor.run_stage("streaming_combine", combine, ext)
        return tuple(merged[n_cmp:])

    def one_round(ext_rs):
        nxt = [merge_sorted_lex(ext_rs[i], ext_rs[i + 1], n_cmp=n_cmp,
                                interpret=interpret)
               for i in range(0, len(ext_rs) - 1, 2)]
        if len(ext_rs) % 2:
            nxt.append(ext_rs[-1])
        return nxt

    while len(ext) > 1:
        if supervisor is None:
            ext = one_round(ext)
        else:
            ext = supervisor.run_stage("merge_round", one_round, ext)
    return ext[0][n_cmp:]
