"""Run combiner: k-way merge of sorted lex-tuple runs on device.

A *run* here is a tuple of parallel 1-D arrays already sorted by the
lane-by-lane lexicographic order (``kernels/lex.py`` conventions — for the
word pipeline the tuple is ``(length, key_lane_0, ..., key_lane_L-1)``, i.e.
shortlex). Two runs combine through ``kernels.ops.merge_sorted_lex`` — the
packed rank-key merge path (``kernels/keypack.py``: searchsorted ranks +
one scatter, or the Pallas merge-path run kernel on TPU), the same
primitive ``core/distributed``'s 'take' merge and sample-sort combine use —
so every round costs O(n log n) gathers instead of ``lex_rank_count``'s
O(|a|·|b|·L) broadcast. k runs combine as a tournament tree, log2(k) rounds
of pairwise merges.

The tournament works in the *extended* representation: each run's packed
compare lanes (1-2 uint32 rank keys + keypack's minimal tie-break suffix)
ride the scatter alongside the data lanes, so later rounds rank without
re-packing. ``cmp_runs`` lets the chunked ingest hand over rank keys the
fused bucketize program already computed.
"""

from __future__ import annotations

from ..kernels.keypack import packed_cmp_lanes
from ..kernels.ops import merge_sorted_lex

__all__ = ["merge_two", "merge_runs"]


def merge_two(a_lanes, b_lanes, engine: str = "auto", max_values=None):
    """Merge two sorted lex-tuple runs (tuples of parallel 1-D arrays, may
    differ in length) into one sorted run. Thin alias of
    ``kernels.ops.merge_sorted_lex``, which validates arity and
    short-circuits empty runs without device work."""
    return merge_sorted_lex(tuple(a_lanes), tuple(b_lanes), engine=engine,
                            max_values=max_values)


def merge_runs(runs, engine: str = "auto", max_values=None, cmp_runs=None,
               manifests=None, supervisor=None):
    """Tournament-tree k-way merge: pairwise merge rounds until one run
    remains. ``runs``: list of sorted lex-tuple runs of equal arity; an
    empty list returns ``()`` and a single run is returned as-is — both
    without touching the device. Chunked ingest produces at most two
    distinct run lengths (full chunks + one tail), so the tree re-traces
    only O(log k) shapes.

    ``cmp_runs``: optional parallel list of pre-packed compare-lane lists
    (e.g. ``SortedRun.cmp_lanes()`` — rank keys the fused per-chunk program
    already emitted); ``None`` packs them here via
    ``keypack.packed_cmp_lanes`` with ``max_values``. Either way the compare
    lanes are scattered through every round alongside the data, so no round
    re-packs.

    ``manifests``: optional parallel list of ``RunManifest``-likes; each
    run's element count is reconciled against its manifest *before* any
    round runs, so a truncated/stale run (e.g. loaded from a resume store)
    fails loudly instead of merging short. ``supervisor``: optional
    ``runtime.SortSupervisor`` — each merge round executes through
    ``run_stage('merge_round', ...)``, and because rounds are pure functions
    of their input runs, a failed round simply re-executes."""
    runs = [tuple(r) for r in runs]
    if manifests is not None:
        from .validate import ValidationError
        if len(manifests) != len(runs):
            raise ValueError("manifests must parallel runs")
        for r, m in zip(runs, manifests):
            if r and int(r[0].shape[0]) != m.count:
                raise ValidationError(
                    f"run {m.chunk_id}: {int(r[0].shape[0])} element(s) "
                    f"but manifest records {m.count} — refusing to merge")
    if not runs:
        return ()
    if len(runs) == 1:
        return runs[0]
    arity = len(runs[0])
    if any(len(r) != arity for r in runs):
        raise ValueError("runs must have the same lane arity")
    if cmp_runs is None:
        cmp_runs = [packed_cmp_lanes(list(r), max_values) for r in runs]
    ext = [tuple(c) + r for c, r in zip(cmp_runs, runs)]
    n_cmp = len(ext[0]) - arity

    def one_round(ext_rs):
        nxt = [merge_sorted_lex(ext_rs[i], ext_rs[i + 1], engine=engine,
                                n_cmp=n_cmp)
               for i in range(0, len(ext_rs) - 1, 2)]
        if len(ext_rs) % 2:
            nxt.append(ext_rs[-1])
        return nxt

    while len(ext) > 1:
        if supervisor is None:
            ext = one_round(ext)
        else:
            ext = supervisor.run_stage("merge_round", one_round, ext)
    return ext[0][n_cmp:]
