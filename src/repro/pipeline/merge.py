"""Run combiner: k-way merge of sorted lex-tuple runs on device.

A *run* here is a tuple of parallel 1-D arrays already sorted by the
lane-by-lane lexicographic order (``kernels/lex.py`` conventions — for the
word pipeline the tuple is ``(length, key_lane_0, ..., key_lane_L-1)``, i.e.
shortlex). Two runs combine with one merge-path take
(``kernels.lex.lex_merge_take``: rank = own index + cross-run rank count,
then a single scatter — no re-sort), the same primitive the distributed
odd-even engine's 'take' merge uses on its block exchanges; k runs combine
as a tournament tree, log2(k) rounds of pairwise merges, so total compare
work is O(n log k) in the searchsorted (key-only) regime.
"""

from __future__ import annotations

import jax

from ..kernels.lex import lex_merge_take

__all__ = ["merge_two", "merge_runs"]


@jax.jit
def _merge2(a_lanes, b_lanes):
    return tuple(lex_merge_take(list(a_lanes), list(b_lanes)))


def merge_two(a_lanes, b_lanes):
    """Merge two sorted lex-tuple runs (tuples of parallel 1-D arrays, may
    differ in length) into one sorted run. Jitted per (shape, arity)."""
    a_lanes, b_lanes = tuple(a_lanes), tuple(b_lanes)
    if len(a_lanes) != len(b_lanes):
        raise ValueError("runs must have the same lane arity")
    if a_lanes[0].shape[0] == 0:
        return b_lanes
    if b_lanes[0].shape[0] == 0:
        return a_lanes
    return _merge2(a_lanes, b_lanes)


def merge_runs(runs):
    """Tournament-tree k-way merge: pairwise :func:`merge_two` rounds until
    one run remains. ``runs``: non-empty list of sorted lex-tuple runs of
    equal arity. Chunked ingest produces at most two distinct run lengths
    (full chunks + one tail), so the tree re-traces only O(log k) shapes."""
    runs = [tuple(r) for r in runs]
    if not runs:
        raise ValueError("need at least one run")
    while len(runs) > 1:
        nxt = [merge_two(runs[i], runs[i + 1])
               for i in range(0, len(runs) - 1, 2)]
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]
