"""Streaming ingest pipeline: the paper's pre-processing at beyond-launch
scale.

One device launch bounds how much the fused bucketize + segmented-sort
program can swallow (the bucket tensor is ``num_buckets * capacity * lanes``
in VMEM-bounded tiles). This subsystem streams arbitrarily large inputs
through it in fixed-size chunks — the MPI follow-up's shape (locally sorted
runs combined by merge) rendered as a host-side driver over the same device
kernels:

  ``ingest``     chunked sort: pack -> per-chunk fused bucketize+segmented
                 sort (``core.bucketing.sorted_packed``) -> sorted runs ->
                 k-way merge; ``chunked_sort_words`` is the words front-end.
  ``merge``      the run combiner: tournament tree of packed rank-key
                 merge-path takes over shortlex lex tuples
                 (``kernels.ops.merge_sorted_lex`` / ``kernels/keypack.py``
                 — the same primitive ``core/distributed``'s 'take' merge
                 uses; rank keys ride the scatter between rounds).
  ``histogram``  the shared length-histogram / bucket-assignment utility
                 that ``data.bucketing`` planning and ``serve.scheduler``
                 admission both consume (one implementation of the paper's
                 phase-1 count, three call sites).
  ``manifest``   per-run invariant summaries (:class:`RunManifest`) and the
                 atomic resumable run store (:class:`RunStore`) behind
                 ``chunked_sort_*(store=...)``.
  ``validate``   the invariant-validation gate: sortedness, count /
                 histogram conservation, order-independent content digests
                 (``validate='off'|'cheap'|'full'``).
"""

from .histogram import (assign_buckets, bucket_of, length_histogram,
                        quantile_bounds)

__all__ = [
    "DEFAULT_CHUNK", "SortedRun", "sorted_run",
    "chunked_sort_packed", "chunked_sort_words",
    "merge_runs", "merge_two",
    "RunManifest", "RunStore", "ShardStore", "ShardedRun",
    "ValidationError", "multiset_digest", "keys_digest",
    "check_lanes_sorted", "check_multiset", "check_run", "check_chunked",
    "check_sharded",
    "length_histogram", "assign_buckets", "bucket_of", "quantile_bounds",
]

# ``histogram`` is a numpy-only leaf the data/serve layers import on their
# hot import path; the ingest/merge device stack (jax + kernels + core)
# loads lazily (PEP 562) so ``from repro.pipeline import assign_buckets``
# never pays the jax import.
_LAZY = {
    "DEFAULT_CHUNK": "ingest", "SortedRun": "ingest", "sorted_run": "ingest",
    "chunked_sort_packed": "ingest", "chunked_sort_words": "ingest",
    "merge_runs": "merge", "merge_two": "merge",
    "RunManifest": "manifest", "RunStore": "manifest",
    "ShardStore": "shards", "ShardedRun": "shards",
    "ValidationError": "validate", "multiset_digest": "validate",
    "keys_digest": "validate", "check_lanes_sorted": "validate",
    "check_multiset": "validate", "check_run": "validate",
    "check_chunked": "validate", "check_sharded": "validate",
}


def __getattr__(name):
    if name in _LAZY:
        from importlib import import_module
        return getattr(import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
