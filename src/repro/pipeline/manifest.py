"""Run manifests + resumable run storage for the chunked ingest pipeline.

A multi-minute out-of-core sort is only as trustworthy as its weakest
chunk: a killed job must resume from the runs it already produced, and the
merge must be able to prove those runs are intact before combining them.
Each completed :class:`~repro.pipeline.ingest.SortedRun` therefore gets a
:class:`RunManifest` — chunk id, exact element count, dense per-length
histogram, shortlex min/max key, and an order-independent content digest
(``pipeline/validate.py``) — and optionally persists through
:class:`RunStore`, which rides ``checkpoint/manager.py``'s atomic
tmp-then-rename snapshots (a crash mid-write can never leave a torn run;
the manifest lives in the snapshot's ``extra`` metadata and is readable
without loading any array).

Resume protocol (``chunked_sort_*(store=...)``): for each chunk, if the
store holds a manifest whose count **and input digest** match the incoming
chunk, the stored run is loaded instead of re-ingesting — the digest check
makes a stale store (same path, different dataset) recompute instead of
silently merging foreign data. ``pipeline/merge`` then reconciles every
run's manifest count before any merge round runs.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Optional, Tuple

import numpy as np

from ..checkpoint import manager as ckpt
from .validate import keys_digest, length_histogram_of

__all__ = ["RunManifest", "RunStore"]


@dataclass(frozen=True)
class RunManifest:
    """Invariant summary of one sorted run — everything the merge and the
    validation gate need to reconcile the run without rescanning it."""

    chunk_id: int
    count: int
    lanes: int                           # uint32 key lanes per word
    length_histogram: Tuple[int, ...]    # dense per-byte-length counts
    min_key: Optional[Tuple[int, ...]]   # (length, *lanes) of the first row
    max_key: Optional[Tuple[int, ...]]   # (length, *lanes) of the last row
    digest: int                          # order-independent content digest

    @classmethod
    def from_run(cls, run, chunk_id: int) -> "RunManifest":
        """Summarise a :class:`~repro.pipeline.ingest.SortedRun` (syncs the
        run to host once; O(count) host work)."""
        lengths = np.asarray(run.lengths)
        keys = np.asarray(run.keys)
        n, lanes = keys.shape
        hist = length_histogram_of(lengths, 4 * lanes + 1)
        row = lambda i: (int(lengths[i]), *(int(v) for v in keys[i]))  # noqa: E731
        return cls(chunk_id=int(chunk_id), count=int(n), lanes=int(lanes),
                   length_histogram=tuple(int(c) for c in hist),
                   min_key=row(0) if n else None,
                   max_key=row(n - 1) if n else None,
                   digest=keys_digest(keys))

    def to_json(self) -> dict:
        return asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "RunManifest":
        return cls(chunk_id=int(d["chunk_id"]), count=int(d["count"]),
                   lanes=int(d["lanes"]),
                   length_histogram=tuple(d["length_histogram"]),
                   min_key=tuple(d["min_key"]) if d["min_key"] is not None
                   else None,
                   max_key=tuple(d["max_key"]) if d["max_key"] is not None
                   else None,
                   digest=int(d["digest"]))


class RunStore:
    """Directory of completed sorted runs keyed by chunk id.

    Each run is one ``checkpoint`` snapshot (``step_<chunk_id>/``):
    ``lengths`` + ``keys`` (+ the packed rank-key lanes the fused program
    emitted, so a resumed run re-enters the merge without re-packing), with
    the :class:`RunManifest` in the snapshot's ``extra`` metadata. Writes
    are atomic (tmp dir + one ``os.replace``), so every manifest the store
    reports corresponds to a fully landed run — the resume discovery needs
    no journal."""

    def __init__(self, directory: str):
        import os
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        # a job killed mid-save leaves .tmp_<N> droppings short of their
        # atomic rename; sweep them on open so they never accumulate and a
        # resume only ever sees fully landed snapshots
        swept = ckpt.sweep_tmp(directory)
        if swept:
            import logging
            logging.getLogger("repro.pipeline").warning(
                "%s: swept %d half-written snapshot(s) %s on open",
                type(self).__name__, len(swept), swept)

    def completed(self) -> list:
        """Chunk ids with fully landed runs, ascending."""
        return ckpt.list_steps(self.directory)

    def manifest(self, chunk_id: int) -> Optional[RunManifest]:
        if chunk_id not in set(ckpt.list_steps(self.directory)):
            return None
        extra = ckpt.read_manifest(self.directory, chunk_id).get("extra")
        return RunManifest.from_json(extra) if extra is not None else None

    def put(self, manifest: RunManifest, run) -> None:
        """Persist one completed run (synchronous + atomic: when this
        returns, the run survives a kill)."""
        tree = {"lengths": np.asarray(run.lengths),
                "keys": np.asarray(run.keys)}
        if run.packed is not None:
            for i, p in enumerate(run.packed):
                tree[f"packed{i}"] = np.asarray(p)
        ckpt.save(self.directory, manifest.chunk_id, tree,
                  extra=manifest.to_json())

    def load(self, chunk_id: int):
        """Load a stored run's arrays: ``(lengths, keys, packed_or_None)``
        (the caller — ``pipeline.ingest`` — rebuilds its ``SortedRun``)."""
        man = ckpt.read_manifest(self.directory, chunk_id)
        names = [e["name"] for e in man["leaves"]]
        target = {e["name"]: np.empty(e["shape"], dtype=e["dtype"])
                  for e in man["leaves"]}
        tree = ckpt.restore(self.directory, chunk_id, target)
        packed_names = sorted(n for n in names if n.startswith("packed"))
        packed = tuple(tree[n] for n in packed_names) or None
        return tree["lengths"], tree["keys"], packed
