"""Sharded spill storage for the distributed combine: per-destination
sorted outputs as atomic disk shards.

``core.distributed.distributed_chunked_sort_lex`` used to funnel every
destination's merged output back to one home device — fine while the sorted
result fits that device, fatal beyond it (and a job killed during the
combine lost every finished destination). :class:`ShardStore` rides the
same atomic tmp-then-rename snapshots as the ingest
:class:`~repro.pipeline.manifest.RunStore` (it *is* one, keyed by
destination index instead of chunk id), so each destination's output lands
durably the moment its k-way merge completes:

  * the per-shard manifest is a :class:`~repro.pipeline.manifest.
    RunManifest` — count, shortlex min/max key, per-length histogram, and
    the order-independent additive content digest — exactly the metadata a
    resume needs to decide "this shard is done" without loading it, and a
    global gate (``pipeline.validate.check_sharded``) needs to prove
    boundary ordering + count/digest conservation without rescanning data;
  * resume is shard-granular: a killed combine reloads completed shards
    (matched by incoming count + summed sub-run digest) and recomputes only
    the in-flight ones — a torn or tampered shard fails its load/validate
    and silently falls back to recompute;
  * :class:`ShardedRun` is the spilled result handle: shard-at-a-time
    access for out-of-core consumers, or :meth:`ShardedRun.to_run` to
    materialise the full sorted run when it does fit.
"""

from __future__ import annotations

import os
import shutil
from dataclasses import dataclass
from typing import Tuple

from .manifest import RunManifest, RunStore
from .validate import check_run

__all__ = ["ShardStore", "ShardedRun"]


class ShardStore(RunStore):
    """Directory of per-destination output shards, keyed by destination
    index. Identical snapshot format and atomicity to :class:`~repro.
    pipeline.manifest.RunStore` (``step_<dest>/manifest.json + *.npy``, one
    ``os.replace`` per shard, ``.tmp_*`` droppings swept on open); the
    separate type keeps ingest-run and output-shard directories from being
    confused for one another in call sites and error messages."""

    def drop(self, shard_id: int) -> None:
        """Remove one landed shard (e.g. after it failed validation and
        must recompute, or after a consumer has drained it)."""
        shutil.rmtree(os.path.join(self.directory, f"step_{shard_id}"),
                      ignore_errors=True)


@dataclass(frozen=True)
class ShardedRun:
    """The spilled result of a shard-combining distributed sort: the
    destination-ordered shard manifests plus the store they landed in. The
    concatenation of the shards in manifest order is the globally sorted
    output; consumers stream it shard at a time (:meth:`load_shard`) or
    materialise it whole (:meth:`to_run`)."""

    store: ShardStore
    manifests: Tuple[RunManifest, ...]

    @property
    def count(self) -> int:
        return sum(m.count for m in self.manifests)

    def load_shard(self, i: int, validate: str = "off"):
        """Load destination ``i``'s :class:`~repro.pipeline.ingest.
        SortedRun` (``validate``: ``'off'|'cheap'|'full'`` reconciles it
        against its manifest via ``check_run`` first)."""
        from .ingest import _run_from_arrays
        man = self.manifests[i]
        run = _run_from_arrays(*self.store.load(man.chunk_id))
        if validate != "off":
            check_run(run, man, mode=validate)
        return run

    def to_run(self, validate: str = "off"):
        """Materialise the full sorted run (host concat of all shards in
        destination order) — the gather the spill path deferred, for
        results that do fit one host after all."""
        import jax.numpy as jnp
        import numpy as np

        from .ingest import SortedRun
        runs = [self.load_shard(i, validate=validate)
                for i in range(len(self.manifests))]
        lengths = np.concatenate([np.asarray(r.lengths) for r in runs]) \
            if runs else np.zeros((0,), np.int32)
        keys = np.concatenate([np.asarray(r.keys) for r in runs]) \
            if runs else np.zeros((0, 0), np.uint32)
        return SortedRun(lengths=jnp.asarray(lengths),
                         keys=jnp.asarray(keys))
