"""Training step construction: loss -> grads -> clip -> AdamW, with
microbatch gradient accumulation."""

from .steps import Hyper, make_train_step, make_eval_step

__all__ = ["Hyper", "make_train_step", "make_eval_step"]
