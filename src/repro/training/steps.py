"""jit-able train/eval steps.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with donated params/opt_state. Microbatch gradient
accumulation (``Hyper.accum``) runs as a lax.scan over batch slices so the
HLO stays O(1) in the accumulation factor.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import lm_loss
from ..optim import AdamWConfig, adamw_update, clip_by_global_norm, cosine_schedule
from ..parallel.sharding import Rules

__all__ = ["Hyper", "make_train_step", "make_eval_step"]


@dataclasses.dataclass(frozen=True)
class Hyper:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    accum: int = 1              # microbatch gradient accumulation factor
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    sort_impl: str = "xla"


def _split_microbatches(batch, accum: int):
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} not divisible by accum {accum}"
        return x.reshape((accum, b // accum) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, rules: Rules, hyper: Hyper):
    schedule = cosine_schedule(hyper.lr, hyper.warmup, hyper.total_steps)

    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, rules, sort_impl=hyper.sort_impl)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if hyper.accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, hyper.accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / hyper.accum, g_sum)
            loss = l_sum / hyper.accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        grads, gnorm = clip_by_global_norm(grads, hyper.clip_norm)
        lr = schedule(step)
        params, opt_state = adamw_update(grads, opt_state, params, lr, hyper.adamw)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, rules: Rules, sort_impl: str = "xla"):
    def eval_step(params, batch):
        loss, metrics = lm_loss(cfg, params, batch, rules, sort_impl=sort_impl)
        return dict(metrics, loss=loss)

    return eval_step
