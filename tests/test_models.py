"""Model zoo: per-arch smoke tests (reduced configs, CPU), decode/forward
equivalence, and oracle checks for the nontrivial numerics (SSD chunking,
MLA absorption, MoE dispatch)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, cells_for
from repro.models import decode_step, forward, init_cache, init_lm, lm_loss
from repro.models.config import SSMCfg
from repro.models.moe import moe, init_moe, capacity
from repro.models.param import Builder, finalize
from repro.models.ssm import ssd_reference, _ssd_chunked
from repro.parallel.sharding import Rules

RULES = Rules()
KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY, b=B, s=S):
    if cfg.input_kind == "tokens":
        return {
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
    return {
        "frames": jax.random.normal(key, (b, s, cfg.d_model)),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    """Mandated per-arch smoke: reduced config, one forward + loss,
    output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params, _ = init_lm(cfg, KEY)
    batch = _batch(cfg)
    logits, aux, _ = forward(cfg, params, batch, RULES)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = lm_loss(cfg, params, batch, RULES)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch):
    """Step-by-step decode == teacher-forced forward (caches, absorption,
    recurrences all consistent). MoE capacity raised so no tokens drop."""
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = init_lm(cfg, KEY)
    batch = _batch(cfg)
    inp = batch.get("tokens", batch.get("frames"))
    ref, _, _ = forward(cfg, params, {k: v for k, v in batch.items() if k != "labels"}, RULES)
    cache, _ = init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, tok, t: decode_step(cfg, p, c, tok, t, RULES))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, inp[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - ref)))
    assert err < 2e-3, err


def test_full_configs_have_exact_dims():
    """The published dimensions, verbatim."""
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == \
        (126, 16384, 128, 8, 53248, 128256)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.moe.n_experts, c.moe.top_k) == \
        (60, 5120, 128, 160, 6)
    assert c.mla.kv_lora == 512
    c = get_config("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (96, 18432, 73728, 256000)
    assert c.mlp_act == "relu2"
    c = get_config("mamba2-370m")
    assert c.ssm.d_state == 128 and c.attn is None
    c = get_config("zamba2-1.2b")
    assert c.hybrid_period == 6 and c.ssm.d_state == 64
    c = get_config("granite-moe-1b-a400m")
    assert c.moe.n_experts == 32 and c.moe.top_k == 8 and c.vocab_size == 49155
    c = get_config("glm4-9b")
    assert c.n_kv_heads == 2 and c.rope_pct == 0.5
    c = get_config("qwen2-vl-2b")
    assert c.rope_kind == "mrope" and c.vocab_size == 151936
    c = get_config("musicgen-large")
    assert c.vocab_size == 2048 and c.input_kind == "frames"
    c = get_config("minicpm3-4b")
    assert c.mla is not None and c.vocab_size == 73448


def test_long_500k_eligibility():
    names = {get_config(a).name: [c.name for c in cells_for(get_config(a))]
             for a in ARCH_IDS}
    assert "long_500k" in names["mamba2-370m"]
    assert "long_500k" in names["zamba2-1.2b"]
    for a in ("llama3-405b", "glm4-9b", "nemotron-4-340b", "musicgen-large"):
        assert "long_500k" not in names[a]


def test_ssd_chunked_matches_recurrence():
    """Mamba2 chunked training path == naive O(T) recurrence oracle."""
    rng = np.random.default_rng(0)
    b, t, h, p, n, chunk = 2, 32, 4, 8, 16, 8
    x = jnp.asarray(rng.normal(size=(b, t, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(b, t, h))).astype(np.float32) * 0.1)
    A = jnp.asarray(-np.abs(rng.normal(size=(h,))).astype(np.float32))
    B_ = jnp.asarray(rng.normal(size=(b, t, 1, n)).astype(np.float32))
    C_ = jnp.asarray(rng.normal(size=(b, t, 1, n)).astype(np.float32))
    y_chunk, s_chunk = _ssd_chunked(x, dt, A, B_, C_, chunk)
    y_ref, s_ref = ssd_reference(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_ref), rtol=2e-4, atol=2e-4)


def test_moe_sort_vs_einsum_dispatch():
    """The paper-technique dispatch and the one-hot baseline agree."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b = Builder(KEY, dtype=jnp.float32)
    p, _ = finalize(init_moe(b, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_sort, aux_s = moe(cfg.replace(moe=dataclasses.replace(cfg.moe, impl="sort")),
                        p, x, RULES)
    y_ein, aux_e = moe(cfg.replace(moe=dataclasses.replace(cfg.moe, impl="einsum")),
                       p, x, RULES)
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_ein), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_e), rtol=1e-5)


def test_moe_sort_impls_agree():
    """XLA argsort vs our OETS/bitonic comparator networks inside dispatch."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b = Builder(KEY, dtype=jnp.float32)
    p, _ = finalize(init_moe(b, cfg))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, cfg.d_model))
    ys = [moe(cfg, p, x, RULES, sort_impl=s)[0]
          for s in ("xla", "oets", "bitonic", "pallas")]
    for y in ys[1:]:
        np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y), rtol=2e-4, atol=2e-5)


def test_moe_conservation_without_drops():
    """With huge capacity, every token gets exactly its top-k experts:
    renormalized gates sum to 1 so the combine is a convex mixture."""
    cfg = get_smoke_config("granite-moe-1b-a400m")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=16.0, router_renorm=True))
    assert capacity(cfg, 16) >= 16 * cfg.moe.top_k // cfg.moe.n_experts
    b = Builder(KEY, dtype=jnp.float32)
    p, _ = finalize(init_moe(b, cfg))
    # identical tokens => identical routing => identical outputs
    x = jnp.tile(jax.random.normal(jax.random.PRNGKey(3), (1, 1, cfg.d_model)), (1, 8, 1))
    y, _ = moe(cfg, p, x, RULES)
    np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(y[0, 5]), rtol=1e-4, atol=1e-5)


def test_mla_cache_is_compressed():
    cfg = get_smoke_config("deepseek-v2-236b")
    cache, axes = init_cache(cfg, batch=2, seq=32)
    # MLA cache stores the latent + shared rope key, NOT per-head k/v
    leaf_names = set(cache["blocks"].keys())
    assert leaf_names == {"ckv", "kr"}
    assert cache["blocks"]["ckv"].shape[-1] == cfg.mla.kv_lora


def test_ssm_cache_constant_in_context():
    cfg = get_smoke_config("mamba2-370m")
    c32, _ = init_cache(cfg, batch=2, seq=32)
    c64k, _ = init_cache(cfg, batch=2, seq=65536)
    assert jax.tree.map(lambda a: a.shape, c32) == jax.tree.map(lambda a: a.shape, c64k)


def test_hybrid_shared_cache_count():
    cfg = get_config("zamba2-1.2b")
    cache, _ = init_cache(cfg, batch=1, seq=8, abstract=True)
    assert cache["shared"]["k"].shape[0] == 7  # ceil(38/6) applications


def test_chunked_attention_matches_full():
    """Streaming (flash-style) attention == full-score attention."""
    for arch in ("glm4-9b", "nemotron-4-340b"):
        cfg = get_smoke_config(arch)
        params, _ = init_lm(cfg, KEY)
        batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
        ref, _, _ = forward(cfg, params, batch, RULES)
        chunked, _, _ = forward(cfg.replace(attn_kv_chunk=8), params, batch, RULES)
        err = float(jnp.max(jnp.abs(ref - chunked)))
        assert err < 1e-4, (arch, err)
