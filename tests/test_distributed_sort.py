"""Distributed engine coverage on 8 fake host devices (subprocess so the
XLA device-count flag cannot leak into other tests).

Pins the ISSUE-3 contracts: engine-vs-``jnp.sort`` differential over random /
duplicate-heavy / sentinel-colliding inputs for both engines and every
odd-even merge strategy; the exact-count exchange protocol (real
``UINT32_MAX`` / ``+inf`` elements are counted, capacity overflow is flagged
instead of silently dropped); pad-and-slice for non-divisible sizes; and the
lex/kv permutation invariants. Host-level pieces (engine cost model, lex
merge networks) run in-process; a hypothesis sweep rides the slow tier.
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.bitonic import bitonic_merge_lex
from repro.core.distributed import (_MERGES_LEX, choose_engine, local_merge)


def _run_multidev(script, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------------ host side

def test_choose_engine_cost_model():
    """Mirrors kernels.ops.choose_plan: explicit overrides win; auto picks
    odd_even only where its round count is trivial (P <= 2)."""
    assert choose_engine(1, 4096) == "odd_even"
    assert choose_engine(2, 4096) == "odd_even"
    assert choose_engine(4, 4096) == "sample"
    assert choose_engine(8, 64) == "sample"
    assert choose_engine(8, 64, engine="odd_even") == "odd_even"
    assert choose_engine(2, 64, engine="sample") == "sample"
    with pytest.raises(ValueError):
        choose_engine(8, 64, engine="quantum")


def test_bitonic_merge_lex_matches_sorted_concat():
    rng = np.random.default_rng(0)
    a0 = np.sort(rng.integers(0, 50, 64).astype(np.int32))
    b0 = np.sort(rng.integers(0, 50, 64).astype(np.int32))
    av, bv = rng.permutation(64).astype(np.int32), \
        rng.permutation(64).astype(np.int32)
    # payload order inside the merge must follow the full-tuple compare
    a = sorted(zip(a0.tolist(), av.tolist()))
    b = sorted(zip(b0.tolist(), bv.tolist()))
    out = bitonic_merge_lex(
        [jnp.asarray([k for k, _ in a]), jnp.asarray([v for _, v in a])],
        [jnp.asarray([k for k, _ in b]), jnp.asarray([v for _, v in b])])
    got = list(zip(np.asarray(out[0]).tolist(), np.asarray(out[1]).tolist()))
    assert got == sorted(a + b)


@pytest.mark.parametrize("strategy", ["resort", "bitonic", "take"])
def test_lex_merge_strategies_duplicate_heavy(strategy):
    """Every merge strategy produces the sorted concatenation, including on
    duplicate-heavy blocks where rank collisions would double-write slots."""
    rng = np.random.default_rng(1)
    a = np.sort(rng.integers(0, 4, 128).astype(np.int32))
    b = np.sort(rng.integers(0, 4, 128).astype(np.int32))
    out = _MERGES_LEX[strategy](
        [jnp.asarray(a)], [jnp.asarray(b)],
        lambda ls: [jnp.sort(ls[0])])
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.sort(np.concatenate([a, b])))
    np.testing.assert_array_equal(
        np.asarray(local_merge(jnp.asarray(a), jnp.asarray(b), strategy)),
        np.sort(np.concatenate([a, b])))


# -------------------------------------------------------------- 8-device side

_ENGINES_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_sort, distributed_sort_kv, distributed_sort_lex
from repro.parallel.compat import AxisType, make_mesh

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)

def cases(n):
    yield "random", rng.integers(-10**6, 10**6, n).astype(np.int32)
    yield "dup", rng.integers(0, 5, n).astype(np.int32)
    s = np.full(n, np.iinfo(np.int32).max, np.int32)
    s[: n // 2] = rng.integers(0, 100, n // 2)
    yield "sentinel", s
    yield "skew", np.full(n, 42, np.int32)  # over-capacity: one splitter bucket

for n in (8 * 128, 1000, 13):  # divisible, non-divisible, n < P*8
    for tag, x in cases(n):
        want = np.sort(x)
        for merge in ("resort", "bitonic", "take"):
            out = distributed_sort(jnp.asarray(x), mesh, axis="d",
                                   engine="odd_even", merge=merge)
            assert (np.asarray(out) == want).all(), ("odd_even", merge, tag, n)
        out = distributed_sort(jnp.asarray(x), mesh, axis="d", engine="sample")
        assert (np.asarray(out) == want).all(), ("sample", tag, n)
        out = distributed_sort(jnp.asarray(x), mesh, axis="d", engine="auto")
        assert (np.asarray(out) == want).all(), ("auto", tag, n)

# kv permutation invariant: keys sorted AND the (k, v) multiset preserved
k = rng.integers(0, 7, 1001).astype(np.uint32)
v = np.arange(1001, dtype=np.uint32)
for eng in ("odd_even", "sample"):
    ok, ov = distributed_sort_kv(jnp.asarray(k), jnp.asarray(v), mesh,
                                 axis="d", engine=eng)
    assert list(zip(np.asarray(ok).tolist(), np.asarray(ov).tolist())) == \
        sorted(zip(k.tolist(), v.tolist())), eng

# lex invariant: 2 x uint32 lanes == one uint64 sort
full = rng.integers(0, 1 << 63, 999, dtype=np.uint64)
hi, lo = (full >> 32).astype(np.uint32), (full & 0xFFFFFFFF).astype(np.uint32)
for eng in ("odd_even", "sample"):
    shi, slo = distributed_sort_lex([jnp.asarray(hi), jnp.asarray(lo)],
                                    mesh, axis="d", engine=eng)
    got = (np.asarray(shi).astype(np.uint64) << 32) | np.asarray(slo)
    assert (got == np.sort(full)).all(), eng

# float lanes: +/-inf through the sample exchange
f = rng.normal(size=555).astype(np.float32)
f[::7], f[1::9] = np.inf, -np.inf
out = distributed_sort(jnp.asarray(f), mesh, axis="d", engine="sample")
assert (np.asarray(out) == np.sort(f)).all()
print("ENGINES_OK")
"""


def test_engines_differential_multidevice():
    """Both engines x all merge strategies == np.sort on adversarial inputs
    (random / duplicate-heavy / sentinel-colliding / over-capacity skew),
    divisible and non-divisible sizes, key-only + kv + lex."""
    assert "ENGINES_OK" in _run_multidev(_ENGINES_SCRIPT)


_NAN_MESH_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_sort_lex
from repro.parallel.compat import AxisType, make_mesh
from repro.pipeline.validate import order_bits_view

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(7)

n = 8 * 64
x = rng.normal(scale=4.0, size=n).astype(np.float32)
x[rng.random(n) < 0.15] = np.nan
x[rng.random(n) < 0.10] = np.float32(-0.0)
x[rng.random(n) < 0.10] = np.inf
x[rng.random(n) < 0.05] = -np.inf
# distinct payloads but NOT the all-ones sentinel pattern: real elements at
# the padding sentinel are the one documented carve-out of the sort_lex
# contract (they are indistinguishable from padding in every lane)
pats = np.array([0x7FC00001, 0xFFC00000, 0x7F800001],
                np.uint32).view(np.float32)
mask = rng.random(n) < 0.10
x[mask] = pats[rng.integers(0, len(pats), int(mask.sum()))]
v = np.arange(n, dtype=np.uint32)

for eng in ("odd_even", "sample"):
    ok, ov = distributed_sort_lex(
        [jnp.asarray(x), jnp.asarray(v)], mesh, axis="d", engine=eng,
        validate="full")
    ok, ov = np.asarray(ok), np.asarray(ov)
    # bit-level multiset of (key, val) rows conserved: NaN payloads and
    # -0.0 signs survive the mesh exchange
    got = sorted(zip(ok.view(np.uint32).tolist(), ov.tolist()))
    want = sorted(zip(x.view(np.uint32).tolist(), v.tolist()))
    assert got == want, eng
    # canonical total order: NaNs at the tail, order bits non-decreasing
    ob = order_bits_view(ok).astype(np.int64)
    assert np.all(np.diff(ob) >= 0), eng
    assert np.isnan(ok).sum() == np.isnan(x).sum(), eng
print("NAN_MESH_OK")
"""


def test_nan_total_order_multidevice():
    """float32 NaN/±inf/±0.0 data through the full 8-device mesh sort (both
    engines, validate='full' so the production gate also signs off): the
    jnp.sort-equivalent contract holds across splitter selection, the exact
    -count exchange, and the local Pallas sorts."""
    assert "NAN_MESH_OK" in _run_multidev(_NAN_MESH_SCRIPT)


_PROTOCOL_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.distributed import sample_sort, sample_sort_lex
from repro.parallel.compat import AxisType, make_mesh, shard_map

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)

def run_key(x, **kw):
    def body(blk):
        vals, count = sample_sort(blk, axis_name="d", **kw)
        return vals, count[None]
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                           out_specs=(P("d"), P("d"))))
    vals, counts = fn(jnp.asarray(x))
    vals, counts = np.asarray(vals).reshape(8, -1), np.asarray(counts)
    return np.concatenate([vals[i, :counts[i]] for i in range(8)]), counts

# regression (ISSUE 3): real elements AT the sentinel value must be counted —
# the old protocol inferred counts from `out < sentinel` / isfinite(out)
u = np.full(8 * 64, np.iinfo(np.uint32).max, np.uint32)
u[:100] = rng.integers(0, 50, 100)
got, counts = run_key(u)
assert counts.sum() == u.size, counts
assert (got == np.sort(u)).all()

f = rng.normal(size=8 * 32).astype(np.float32)
f[::3] = np.inf
got, counts = run_key(f)
assert counts.sum() == f.size, counts
assert (got == np.sort(f)).all()

i = np.full(8 * 32, np.iinfo(np.int32).max, np.int32)
got, counts = run_key(i)
assert counts.sum() == i.size and (got == i[0]).all()

# capacity overflow is FLAGGED, never silent: all-equal input routes every
# element to one destination, capacity 8 < B=64 must clip and report
def body(blk):
    res = sample_sort_lex([blk], axis_name="d", capacity=8)
    return res.lanes[0], res.count[None], res.overflow[None]
fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                       out_specs=(P("d"), P("d"), P("d"))))
_, _, ovf = fn(jnp.asarray(np.full(8 * 64, 7, np.int32)))
assert np.asarray(ovf).any()

# default capacity: same skew, zero loss, overflow False everywhere
def body2(blk):
    res = sample_sort_lex([blk], axis_name="d")
    return res.lanes[0], res.count[None], res.overflow[None]
fn2 = jax.jit(shard_map(body2, mesh=mesh, in_specs=P("d"),
                        out_specs=(P("d"), P("d"), P("d"))))
vals, counts, ovf = fn2(jnp.asarray(np.full(8 * 64, 7, np.int32)))
assert not np.asarray(ovf).any()
assert np.asarray(counts).sum() == 8 * 64
print("PROTOCOL_OK")
"""


def test_exchange_protocol_exact_counts():
    """The exact-count exchange protocol: sentinel-valued reals counted,
    overflow flagged, zero loss at default capacity."""
    assert "PROTOCOL_OK" in _run_multidev(_PROTOCOL_SCRIPT)


_PALLAS_LOCAL_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_sort, distributed_sort_lex
from repro.parallel.compat import AxisType, make_mesh

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
x = rng.integers(0, 10**6, 8 * 64).astype(np.int32)
for eng in ("sample", "odd_even"):
    out = distributed_sort(jnp.asarray(x), mesh, axis="d", engine=eng,
                           merge="resort", local_sort="pallas")
    assert (np.asarray(out) == np.sort(x)).all(), eng
k = rng.integers(0, 9, 8 * 64).astype(np.uint32)
v = np.arange(8 * 64, dtype=np.uint32)
(ok,), ov = distributed_sort_lex([jnp.asarray(k)], mesh, axis="d",
                                 vals=jnp.asarray(v), engine="sample",
                                 local_sort="pallas")
assert list(zip(np.asarray(ok).tolist(), np.asarray(ov).tolist())) == \
    sorted(zip(k.tolist(), v.tolist()))
print("PALLAS_LOCAL_OK")
"""


def test_pallas_local_sort_in_mesh():
    """Device-local sorting through the Pallas ``ops.sort_lex`` front-end
    (interpret mode) composes with both mesh engines."""
    assert "PALLAS_LOCAL_OK" in _run_multidev(_PALLAS_LOCAL_SCRIPT)


_ADMISSION_SCRIPT = r"""
import numpy as np, jax
from repro.parallel.compat import AxisType, make_mesh
from repro.serve.scheduler import BucketedScheduler, Request

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
rs = [Request(i, list(rng.integers(1, 40, rng.integers(1, 20))))
      for i in range(200)]
single = BucketedScheduler._order_by_length(rs)
sharded = BucketedScheduler._order_by_length(rs, mesh=mesh, axis="d")
# same shortlex admission order whether sorted on one device or the mesh
assert [r.request_id for r in sharded] == [r.request_id for r in single]
print("ADMISSION_OK")
"""


def test_sharded_admission_matches_single_device():
    """BucketedScheduler(admission_mesh=...) must admit in exactly the order
    the single-device lex sort produces."""
    assert "ADMISSION_OK" in _run_multidev(_ADMISSION_SCRIPT)


# ------------------------------------------------------------------ slow tier

_SWEEP_SCRIPT = r"""
import sys, numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_sort
from repro.parallel.compat import AxisType, make_mesh

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
xs = np.asarray([int(t) for t in sys.argv[1].split(",")], np.int32)
engine = sys.argv[2]
out = distributed_sort(jnp.asarray(xs), mesh, axis="d", engine=engine)
assert (np.asarray(out) == np.sort(xs)).all()
print("SWEEP_OK")
"""


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(
    xs=st.lists(st.integers(-(2**31), 2**31 - 1), min_size=1, max_size=300),
    engine=st.sampled_from(["odd_even", "sample"]),
)
def test_engine_vs_sort_hypothesis(xs, engine):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", _SWEEP_SCRIPT,
         ",".join(str(x) for x in xs), engine],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SWEEP_OK" in out.stdout
