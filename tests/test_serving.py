"""Serving engine: batched variable-length generation must equal unbatched
per-prompt generation (the strong test of per-request cache indexing), plus
scheduler bucketing stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_lm
from repro.parallel.sharding import Rules
from repro.serve import BucketedScheduler, Engine, Request

RULES = Rules()


def _engine(arch, max_seq=64):
    cfg = get_smoke_config(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, RULES, max_seq=max_seq), cfg


@pytest.mark.parametrize("arch", ["glm4-9b", "minicpm3-4b", "mamba2-370m", "zamba2-1.2b"])
def test_batched_equals_unbatched(arch):
    """Mixed-length prompts decoded together == each decoded alone."""
    engine, cfg = _engine(arch)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, n)) for n in (3, 7, 12, 5)]
    together = engine.generate(prompts, max_new=6)
    for p, want in zip(prompts, together):
        alone = engine.generate([p], max_new=6)[0]
        assert alone == want, (p, alone, want)


def test_greedy_continuation_consistency():
    """The token decoded at step t must equal the argmax of a fresh forward
    over prompt+generated[:t] (KV cache == recompute)."""
    from repro.models import forward
    engine, cfg = _engine("glm4-9b")
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(1, cfg.vocab_size, 5))
    out = engine.generate([prompt], max_new=5)[0]
    seq = list(prompt)
    for tok in out:
        logits, _, _ = forward(cfg, engine.params,
                               {"tokens": jnp.asarray([seq])}, RULES)
        assert int(jnp.argmax(logits[0, -1])) == tok
        seq.append(tok)


def test_eos_stops_request():
    engine, cfg = _engine("glm4-9b")
    rng = np.random.default_rng(2)
    prompt = list(rng.integers(1, cfg.vocab_size, 4))
    free = engine.generate([prompt], max_new=4)[0]
    engine.eos_id = free[1]
    stopped = engine.generate([prompt], max_new=4)[0]
    # generation must stop at the first occurrence of the eos token
    cut = free.index(engine.eos_id) + 1
    assert stopped == free[:cut]


def test_scheduler_routes_by_bucket():
    engine, cfg = _engine("glm4-9b")
    sched = BucketedScheduler(engine, batch_size=4, bounds=[8, 16, 32])
    rng = np.random.default_rng(3)
    reqs = [Request(i, list(rng.integers(1, cfg.vocab_size, rng.integers(2, 30))), max_new=3)
            for i in range(10)]
    results = sched.run(reqs)
    assert sorted(r.request_id for r in results) == list(range(10))
    assert all(len(r.tokens) == 3 for r in results)
    stats = BucketedScheduler.padding_stats(reqs, [8, 16, 32])
    assert stats["bucketed_waste"] <= stats["global_waste"] + 1e-9


def test_padding_stats_overlong_request_clamped():
    """Regression (ISSUE 3): a request longer than every bound used to add
    *negative* padding (bound - l < 0), understating bucketed waste — its
    contribution must clamp to zero."""
    reqs = [Request(0, list(range(4))),    # pads 8 - 4 = 4
            Request(1, list(range(50)))]   # longer than max(bounds): pads 0
    stats = BucketedScheduler.padding_stats(reqs, [8, 16])
    padded = 4  # the overlong request must contribute 0, not 16 - 50 = -34
    want = padded / (padded + 4 + 50)
    assert abs(stats["bucketed_waste"] - want) < 1e-12
    assert stats["bucketed_waste"] > 0
