"""Properties of the core sort library (the paper's contribution)."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    bitonic_merge,
    bitonic_sort,
    bitonic_sort_kv,
    bucketed_sort_words,
    bucketize_words,
    lex_gt,
    oets_argsort,
    oets_sort,
    oets_sort_kv,
    pack_words,
    sort_buckets,
    unpack_words,
)

ints = st.lists(st.integers(-(2**31), 2**31 - 1), min_size=0, max_size=64)
words = st.lists(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
                         min_size=0, max_size=20), min_size=0, max_size=60)


@settings(max_examples=25, deadline=None)
@given(ints)
def test_oets_sorts_any_ints(xs):
    x = jnp.asarray(np.array(xs, np.int64).astype(np.int32))
    out = np.asarray(oets_sort(x))
    assert (out == np.sort(np.asarray(x))).all()


@settings(max_examples=25, deadline=None)
@given(ints)
def test_bitonic_sorts_any_ints(xs):
    x = jnp.asarray(np.array(xs, np.int64).astype(np.int32))
    out = np.asarray(bitonic_sort(x))
    assert (out == np.sort(np.asarray(x))).all()


@settings(max_examples=20, deadline=None)
@given(ints)
def test_oets_kv_is_permutation(xs):
    x = jnp.asarray(np.array(xs, np.int64).astype(np.int32))
    vals = jnp.arange(x.shape[0], dtype=jnp.int32)
    sk, sv = oets_sort_kv(x, vals)
    # values are a permutation and gather the sorted keys
    assert sorted(np.asarray(sv).tolist()) == list(range(x.shape[0]))
    assert (np.asarray(x)[np.asarray(sv)] == np.asarray(sk)).all()


@settings(max_examples=20, deadline=None)
@given(words)
def test_packing_roundtrip_and_order(ws):
    ws = [w.encode()[:20].decode(errors="ignore").replace("\x00", "") for w in ws]
    keys = pack_words(ws)
    assert unpack_words(keys) == ws
    if len(ws) >= 2:
        perm = np.asarray(oets_argsort(jnp.asarray(keys)))
        got = [ws[i] for i in perm]
        assert [w.encode() for w in got] == sorted(w.encode() for w in ws)


@settings(max_examples=20, deadline=None)
@given(words)
def test_bucketed_sort_is_shortlex(ws):
    ws = [w for w in ws if w]
    got = bucketed_sort_words(ws, algorithm="oets")
    assert [w.encode() for w in got] == sorted(
        (w.encode() for w in ws), key=lambda b: (len(b), b))


def test_multilane_lex_order():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 2**32, (64, 3), dtype=np.uint32))
    out = np.asarray(oets_sort(k))
    order = sorted(range(64), key=lambda i: tuple(np.asarray(k)[i]))
    assert (out == np.asarray(k)[order]).all()
    out2 = np.asarray(bitonic_sort(k))
    assert (out2 == out).all()


def test_bitonic_merge_matches_sorted_concat():
    rng = np.random.default_rng(1)
    a = jnp.sort(jnp.asarray(rng.integers(0, 100, 64).astype(np.int32)))
    b = jnp.sort(jnp.asarray(rng.integers(0, 100, 64).astype(np.int32)))
    m = bitonic_merge(a, b)
    assert (np.asarray(m) == np.sort(np.concatenate([a, b]))).all()


def test_bitonic_kv_carries_payload():
    rng = np.random.default_rng(2)
    k = jnp.asarray(rng.integers(0, 40, 50).astype(np.int32))
    v = jnp.arange(50, dtype=jnp.int32)
    sk, sv = bitonic_sort_kv(k, v)
    assert (np.asarray(k)[np.asarray(sv)] == np.asarray(sk)).all()


def test_bucket_structure_matches_histogram():
    ws = ["a", "bb", "cc", "ddd", "x", "yy", "zzz", "q"]
    b = bucketize_words(ws)
    assert b.lengths.tolist() == [1, 2, 3]
    assert b.counts.tolist() == [3, 3, 2]
    sorted_keys = sort_buckets(jnp.asarray(b.keys), "oets")
    flat = []
    for i in range(sorted_keys.shape[0]):
        flat.extend(unpack_words(np.asarray(sorted_keys)[i, : b.counts[i]]))
    assert flat == sorted(ws, key=lambda w: (len(w), w))


def test_truncated_network_is_partial_sort():
    # fewer phases => possibly unsorted; n phases => always sorted
    x = jnp.asarray(np.arange(63, -1, -1, dtype=np.int32))  # worst case
    full = oets_sort(x)
    assert (np.asarray(full) == np.arange(64)).all()


def test_lex_gt_scalar_and_lanes():
    a = jnp.asarray(np.array([[1, 2], [3, 1]], np.uint32))
    b = jnp.asarray(np.array([[1, 3], [2, 9]], np.uint32))
    assert np.asarray(lex_gt(a, b)).tolist() == [False, True]
    assert bool(lex_gt(jnp.int32(5), jnp.int32(3)))
