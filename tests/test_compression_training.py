"""int8 + error-feedback gradient compression in a REAL data-parallel
training loop (8 devices): compressed-psum training must converge like
uncompressed-psum training. This closes the loop on EXPERIMENTS §Perf
iter 4, which models the collective-byte savings — here we show the
optimizer quality is preserved."""

import os
import subprocess
import sys

_COMPRESS_TRAIN = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax import lax
from repro.parallel.compat import AxisType, make_mesh, shard_map
from repro.parallel.compression import compressed_psum

mesh = make_mesh((8,), ("dp",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)

D, H, STEPS, B_LOC = 16, 32, 200, 8
w_true = (rng.normal(size=(D,)) * 0.3).astype(np.float32)
X = rng.normal(size=(STEPS, 8, B_LOC, D)).astype(np.float32)
Y = (X @ w_true + 0.01 * rng.normal(size=(STEPS, 8, B_LOC))).astype(np.float32)

p0 = {
    "w1": jnp.asarray(rng.normal(size=(D, H)).astype(np.float32) * 0.3),
    "w2": jnp.asarray(rng.normal(size=(H, 1)).astype(np.float32) * 0.3),
}

def predict(p, x):
    return (jnp.tanh(x @ p["w1"]) @ p["w2"])[..., 0]

def local_loss(p, x, y):
    return jnp.mean((predict(p, x) - y) ** 2)

def make_train(compressed):
    def train(p, xs, ys):  # shard_map body; xs (STEPS, B_LOC, D) local
        res = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), p)

        def body(carry, xy):
            p, res = carry
            x, y = xy
            loss, g = jax.value_and_grad(local_loss)(p, x, y)
            if compressed:
                flat_g, td = jax.tree.flatten(g)
                flat_r = td.flatten_up_to(res)
                outs = [compressed_psum(gi, "dp", ri) for gi, ri in zip(flat_g, flat_r)]
                g = jax.tree.unflatten(td, [o[0] for o in outs])
                res = jax.tree.unflatten(td, [o[1] for o in outs])
            else:
                g = jax.tree.map(lambda gi: lax.pmean(gi, "dp"), g)
            p = jax.tree.map(lambda pi, gi: pi - 0.02 * gi, p, g)
            gl = lax.pmean(loss, "dp")
            return (p, res), gl

        (p, _), losses = lax.scan(body, (p, res), (xs, ys))
        return p, losses

    return jax.jit(shard_map(
        make := train, mesh=mesh,
        in_specs=(P(), P(None, "dp"), P(None, "dp")),
        out_specs=(P(), P()),
    ))

xs = jnp.asarray(X.reshape(STEPS, 8 * B_LOC, D))
ys = jnp.asarray(Y.reshape(STEPS, 8 * B_LOC))

_, losses_ref = make_train(False)(p0, xs, ys)
_, losses_cmp = make_train(True)(p0, xs, ys)
l0, lr_, lc = float(losses_ref[0]), float(losses_ref[-1]), float(losses_cmp[-1])
assert lr_ < l0 / 5, (l0, lr_)
assert lc < l0 / 5, (l0, lc)            # compressed training converges too
assert lc < lr_ * 3 + 1e-3, (lr_, lc)   # and lands near the uncompressed loss
print("COMPRESS_TRAIN_OK", l0, lr_, lc)
"""


def test_compressed_gradient_training_converges():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _COMPRESS_TRAIN],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "COMPRESS_TRAIN_OK" in out.stdout
