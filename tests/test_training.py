"""Training substrate: optimizer math, schedules, accumulation, and an
actual loss-goes-down integration test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import TokenStream
from repro.models import init_lm
from repro.optim import (
    AdamWConfig, adamw_update, clip_by_global_norm, cosine_schedule,
    global_norm, init_opt_state,
)
from repro.parallel.sharding import Rules
from repro.training import Hyper, make_train_step

RULES = Rules()


def test_adamw_matches_reference():
    """One fused update == the textbook numpy AdamW."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    st = init_opt_state(p)
    lr = 1e-2
    new_p, new_st = adamw_update(g, st, p, lr, cfg)

    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.999)
    want = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_st["m"]["w"]), m, rtol=1e-6)
    assert int(new_st["count"]) == 1


def test_clip_by_global_norm():
    t = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, g = clip_by_global_norm(t, 1.0)
    assert float(g) == pytest.approx(np.sqrt(9 * 3 + 16 * 4), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    same, _ = clip_by_global_norm(t, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=110, min_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(110)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(60)) == pytest.approx(0.55, abs=0.02)


def test_loss_decreases_dense():
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, RULES, Hyper(lr=3e-3, warmup=2, total_steps=40)))
    data = TokenStream(cfg.vocab_size, 4, 16, seed=1)
    # overfit a single repeated batch: loss must drop substantially
    batch = jax.tree.map(jnp.asarray, next(iter(data)))
    losses = []
    for s in range(30):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[:3] + losses[-3:]


def test_loss_decreases_moe_sort_dispatch():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, RULES, Hyper(lr=3e-3, warmup=2, total_steps=40)))
    batch = jax.tree.map(jnp.asarray, next(iter(TokenStream(cfg.vocab_size, 4, 16, seed=2))))
    losses = []
    for s in range(30):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("minicpm3-4b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    batch = jax.tree.map(jnp.asarray, next(iter(TokenStream(cfg.vocab_size, 8, 8, seed=3))))

    outs = {}
    for accum in (1, 4):
        p = jax.tree.map(lambda x: x, params)
        opt = init_opt_state(p)
        step_fn = jax.jit(make_train_step(cfg, RULES, Hyper(lr=1e-3, accum=accum)))
        p, opt, m = step_fn(p, opt, batch, jnp.int32(0))
        outs[accum] = (p, float(m["loss"]))
    # same data, same update (microbatched loss is the mean over equal slices)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        outs[1][0], outs[4][0])
    assert max(jax.tree.leaves(diff)) < 5e-3
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-3)


def test_bf16_moment_state_dtype():
    cfg = get_smoke_config("llama3-405b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, moment_dtype=jnp.bfloat16)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(opt["m"]))


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-1.2b"])
def test_loss_decreases_ssm_family(arch):
    """Regression: the SSD intra-chunk decay must mask BEFORE exp, or the
    backward pass NaNs on the overflowed upper triangle (caught by the
    train CLI; see models/ssm.py)."""
    cfg = get_smoke_config(arch)
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, RULES, Hyper(lr=3e-3, warmup=2, total_steps=40)))
    batch = jax.tree.map(jnp.asarray, next(iter(TokenStream(cfg.vocab_size, 4, 16, seed=5))))
    losses = []
    for s in range(25):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all(), losses[:5]
    assert losses[-1] < losses[0] - 1.0
