"""benchmarks/gate.py: the perf regression gate over BENCH_kernels.json.

Synthetic trajectories pin the failure modes (regression beyond threshold,
best-prior baseline selection, allowlist pass-through, provenance
compatibility); the real committed trajectory must pass the gate with the
committed allowlist — the exact invocation CI runs.
"""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common
from benchmarks.gate import check_latest, load_allowlist, main

_REPO = os.path.join(os.path.dirname(__file__), "..")
_ALLOW = {"default_threshold": 3.5,
          "allow": [{"pattern": "distributed/*", "reason": "noisy"}]}


def _entry(records, failures=(), prov=None):
    return {"timestamp": "t", "modules": ["m"], "failures": list(failures),
            "records": [{"name": n, "us_per_call": us, "derived": "",
                         **({"provenance": prov} if prov else {})}
                        for n, us in records]}


def test_gate_fails_on_synthetic_regression():
    hist = [_entry([("kernels/x", 100.0)]), _entry([("kernels/x", 1000.0)])]
    report = check_latest(hist, _ALLOW)
    assert [r["name"] for r in report["regressions"]] == ["kernels/x"]
    assert report["regressions"][0]["ratio"] == 10.0
    assert report["regressions"][0]["baseline_us"] == 100.0


def test_gate_passes_within_threshold():
    hist = [_entry([("kernels/x", 100.0)]), _entry([("kernels/x", 120.0)])]
    report = check_latest(hist, _ALLOW)
    assert not report["regressions"] and report["checked"] == 1


def test_gate_baselines_against_best_prior():
    """The baseline is the best prior value, not the most recent: a slow
    run must not ratchet the bar down for the next one."""
    hist = [_entry([("kernels/x", 100.0)]), _entry([("kernels/x", 500.0)]),
            _entry([("kernels/x", 400.0)])]
    report = check_latest(hist, _ALLOW)
    assert report["regressions"][0]["baseline_us"] == 100.0
    assert report["regressions"][0]["ratio"] == 4.0


def test_gate_allowlist_reports_but_passes():
    hist = [_entry([("distributed/x", 100.0)]),
            _entry([("distributed/x", 10000.0)])]
    report = check_latest(hist, _ALLOW)
    assert not report["regressions"]
    assert report["allowed"][0]["reason"] == "noisy"


def test_gate_provenance_mismatch_seeds_new_baseline():
    """A stamped baseline from a different backend never gates this run —
    the record counts as new instead of comparing apples to oranges."""
    tpu = {"backend": "tpu", "device_kind": "v5e", "pallas": "compiled"}
    cpu = {"backend": "cpu", "device_kind": "cpu", "pallas": "interpret"}
    hist = [_entry([("kernels/x", 1.0)], prov=tpu),
            _entry([("kernels/x", 1000.0)], prov=cpu)]
    report = check_latest(hist, _ALLOW)
    assert not report["regressions"] and report["new"] == ["kernels/x"]


def test_gate_unstamped_legacy_baseline_still_gates():
    cpu = {"backend": "cpu", "device_kind": "cpu", "pallas": "interpret"}
    hist = [_entry([("kernels/x", 100.0)]),  # pre-stamp history
            _entry([("kernels/x", 1000.0)], prov=cpu)]
    report = check_latest(hist, _ALLOW)
    assert [r["name"] for r in report["regressions"]] == ["kernels/x"]


def test_gate_module_failures_fail_the_gate():
    hist = [_entry([("kernels/x", 100.0)], failures=["bench_kernels"])]
    assert check_latest(hist, _ALLOW)["failures"] == ["bench_kernels"]


def test_gate_empty_trajectory_raises():
    with pytest.raises(ValueError):
        check_latest([], _ALLOW)


def test_gate_cli_synthetic_regression(tmp_path):
    traj = tmp_path / "traj.json"
    traj.write_text(json.dumps([_entry([("kernels/x", 100.0)]),
                                _entry([("kernels/x", 1000.0)])]))
    allow = tmp_path / "allow.json"
    allow.write_text(json.dumps(_ALLOW))
    assert main(["--trajectory", str(traj), "--allowlist", str(allow)]) == 1
    traj.write_text(json.dumps([_entry([("kernels/x", 100.0)]),
                                _entry([("kernels/x", 110.0)])]))
    assert main(["--trajectory", str(traj), "--allowlist", str(allow)]) == 0
    assert main(["--trajectory", str(tmp_path / "missing.json"),
                 "--allowlist", str(allow)]) == 2


def test_gate_passes_on_real_trajectory():
    """The committed trajectory + committed allowlist must be green — the
    exact check CI's bench-gate job runs on every PR."""
    assert main(["--trajectory", os.path.join(_REPO, "BENCH_kernels.json")]) == 0


def test_committed_allowlist_is_valid():
    allow = load_allowlist()
    assert allow["default_threshold"] > 1
    assert any(e["pattern"] == "distributed/*" for e in allow["allow"])


def test_emit_stamps_provenance(capsys):
    """Every new trajectory record carries the execution-provenance stamp
    the gate keys compatibility on."""
    import jax
    common.emit("gate_test/provenance_probe", 1.0)
    rec = common.RECORDS.pop()
    capsys.readouterr()
    prov = rec["provenance"]
    assert prov["backend"] == jax.default_backend()
    assert prov["jax"] == jax.__version__
    assert prov["pallas"] in ("interpret", "compiled")
    assert prov["mode"].endswith(prov["backend"])
    assert "device_kind" in prov


def test_bench_rng_is_deterministic():
    a = common.rng("site", 1).integers(0, 1 << 30, 8)
    b = common.rng("site", 1).integers(0, 1 << 30, 8)
    c = common.rng("site", 2).integers(0, 1 << 30, 8)
    assert (a == b).all() and not (a == c).all()
