"""Dtype coverage for the unified ``kernels.ops`` front-end.

Pins the sentinel / dtype contract documented in ``ops.py``: signed ints use
the *positive* max as the padding sentinel, unsigned values at UINT32_MAX
collide with the sentinel yet still sort correctly, floats handle ±inf, and
the float NaN contract is ``jnp.sort``-equivalent: NaNs sink to the tail
under the canonical total order of ``kernels/lex.py`` while the bit-level
multiset is conserved exactly.

Widths stay inside the single-tile OETS tier — dtype handling is identical
across engines (same padding helpers, same comparator), and the cross-engine
sweeps live in test_differential / test_blocksort.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import sort, sort_kv
from repro.kernels.ops import _sentinel

I32_MIN, I32_MAX = np.iinfo(np.int32).min, np.iinfo(np.int32).max
U32_MAX = np.iinfo(np.uint32).max


def test_sentinel_signed_dtypes():
    """Regression: the signed sentinel is the positive dtype max — an
    unsigned-style all-ones pattern would be -1 and sort padding to the
    *front*, corrupting every padded row."""
    s32 = np.asarray(_sentinel(jnp.int32))
    assert s32 == I32_MAX and s32 > 0
    s16 = np.asarray(_sentinel(jnp.int16))
    assert s16 == np.iinfo(np.int16).max and s16 > 0
    assert np.asarray(_sentinel(jnp.uint32)) == U32_MAX
    # float sentinel: the all-ones-bits NaN — strictly above every value
    # (including every other NaN) under the canonical order bits, so
    # padding can never strand inside a row holding real NaNs
    sf = np.asarray(_sentinel(jnp.float32))
    assert sf.view(np.uint32) == np.uint32(0xFFFFFFFF)


def test_sort_int32_negative_values():
    rng = np.random.default_rng(0)
    x = rng.integers(-10_000, 10_000, (3, 100)).astype(np.int32)
    x[0, :5] = [I32_MIN, -1, 0, 1, I32_MAX]
    out = sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))


def test_sort_uint32_values_at_sentinel():
    """Real UINT32_MAX elements collide with the padding sentinel; the slice
    back to the real width must still return every one of them."""
    rng = np.random.default_rng(1)
    x = rng.integers(0, 100, (3, 100)).astype(np.uint32)
    x[:, ::7] = U32_MAX
    out = sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))


def test_sort_kv_uint32_sentinel_keys_keep_payloads():
    k = np.full((100,), U32_MAX, np.uint32)
    k[:50] = np.arange(50, dtype=np.uint32)
    v = np.arange(100, dtype=np.uint32)
    ok, ov = sort_kv(jnp.asarray(k), jnp.asarray(v))
    assert sorted(zip(k.tolist(), v.tolist())) == \
        list(zip(np.asarray(ok).tolist(), np.asarray(ov).tolist()))


def test_sort_float32_infinities():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(3, 100)).astype(np.float32)
    x[:, ::9] = np.inf
    x[:, 1::9] = -np.inf
    out = sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))


def test_sort_float32_nan_total_order():
    """Pinned NaN contract (see ops.py): ``jnp.sort``-equivalent. Engines
    compare the canonical order bits of ``kernels/lex.py`` (every NaN above
    ``+inf``) but swap the raw values, so NaNs sink to the tail — payload
    bits and ``-0.0`` signs intact — and the bit-level multiset is
    conserved exactly."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(64,)).astype(np.float32)
    x[10] = np.nan
    x[20] = np.uint32(0x7F800001).view(np.float32)   # signalling NaN
    x[30] = np.uint32(0xFFC00000).view(np.float32)   # negative quiet NaN
    x[40] = np.float32(-0.0)
    out = np.asarray(sort(jnp.asarray(x)))
    # bit-level multiset conserved: payloads and zero signs survive
    assert (sorted(out.view(np.uint32).tolist())
            == sorted(x.view(np.uint32).tolist()))
    # NaNs at the tail, non-NaN prefix sorted — jnp.sort agreement
    assert np.isnan(out[-3:]).all() and not np.isnan(out[:-3]).any()
    assert np.all(np.diff(out[:-3]) >= 0)
    np.testing.assert_array_equal(np.isnan(out), np.isnan(np.asarray(
        jnp.sort(jnp.asarray(x)))))


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_sort_all_sentinel_rows(dtype):
    """A row made entirely of sentinel values round-trips unchanged."""
    fill = np.inf if dtype == np.float32 else np.iinfo(dtype).max
    x = np.full((2, 64), fill, dtype)
    out = sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), x)
