"""End-to-end behaviour tests for the paper's system."""

import os
import subprocess
import sys

import numpy as np

from repro.configs import get_smoke_config
from repro.core import bucketed_sort_words
from repro.data import synthetic_words
from repro.launch.train import train_loop
from repro.training import Hyper


def test_paper_pipeline_end_to_end():
    """The paper's complete system: clean -> bucket -> parallel sort ->
    concatenate, on a corpus with the paper's length statistics."""
    words = synthetic_words(5_000, seed=0)
    for algo in ("oets", "bitonic", "xla"):
        out = bucketed_sort_words(words, algorithm=algo)
        assert out == sorted(words, key=lambda w: (len(w), w)), algo


def test_train_with_failure_recovery(tmp_path):
    """Full driver: train, checkpoint, die at step 12, recover, finish."""
    cfg = get_smoke_config("glm4-9b")
    params, losses, events = train_loop(
        cfg, steps=20, batch=4, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=5, fail_at=(12,),
        hyper=Hyper(lr=1e-3, warmup=2, total_steps=20), verbose=False,
    )
    assert len(events) == 1            # one recovery happened
    assert len(losses) >= 20           # re-run steps counted too
    assert losses[-1] < losses[0]      # and training still converged


def test_train_moe_arch_runs():
    cfg = get_smoke_config("granite-moe-1b-a400m")
    _, losses, _ = train_loop(cfg, steps=8, batch=2, seq=16,
                              ckpt_dir=None, verbose=False)
    assert np.isfinite(losses).all()


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "quickstart complete" in out.stdout


def test_cold_restart_before_first_checkpoint(tmp_path):
    """Failure BEFORE any snapshot exists => cold restart from step 0
    (fresh initial state), not a crash."""
    from repro.training import Hyper
    cfg = get_smoke_config("glm4-9b")
    _, losses, events = train_loop(
        cfg, steps=12, batch=2, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=50, fail_at=(4,),
        hyper=Hyper(lr=1e-3, warmup=2, total_steps=12), verbose=False,
    )
    assert len(events) == 1 and events[0].step == 0
    assert len(losses) == 4 + 12  # 4 pre-failure + full 12 after restart
    assert np.isfinite(losses).all()
