"""Edge cases for ``kernels.ops.partition_rows`` (the paper's distribute
step): degenerate splitter sets, boundary widths, and the padded-row /
padded-col histogram correction pinned from both sides — against the jnp
oracle AND the invariants (non-negative counts, counts sum to cols)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import partition_rows, partition_rows_ref


def _check_against_oracle(x, spl):
    bid, cnt = partition_rows(x, spl)
    rbid, rcnt = partition_rows_ref(x, spl)
    np.testing.assert_array_equal(np.asarray(bid), np.asarray(rbid))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))
    cnt = np.asarray(cnt)
    assert (cnt >= 0).all()
    assert (cnt.sum(axis=1) == x.shape[1]).all()
    return np.asarray(bid), cnt


def test_zero_splitters_single_bucket():
    """No splitters -> one bucket holding every element (and the padded-col
    correction must target that only bucket without going negative)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-100, 100, (3, 130)).astype(np.int32))
    spl = jnp.zeros((0,), jnp.int32)
    bid, cnt = _check_against_oracle(x, spl)
    assert (bid == 0).all()
    assert (cnt[:, 0] == 130).all()


def test_all_equal_keys():
    """Every key identical: all elements land in one bucket, boundary rule
    pinned — bucket id counts splitters <= key, so key == splitter goes to
    the *right* bucket."""
    x = jnp.full((2, 96), 50, jnp.int32)
    spl = jnp.asarray(np.array([10, 50, 90], np.int32))
    bid, cnt = _check_against_oracle(x, spl)
    assert (bid == 2).all()          # splitters 10 and 50 are <= 50
    assert (cnt[:, 2] == 96).all()


def test_cols_exactly_at_lane_boundary():
    """cols == 128: no padded columns, so the top-bucket correction must be
    a no-op (pinning the correction from the zero side)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 1000, (4, 128)).astype(np.int32))
    spl = jnp.asarray(np.array([250, 500, 750], np.int32))
    _check_against_oracle(x, spl)


def test_padded_cols_top_bucket_correction():
    """cols padded 130 -> 256: the 126 sentinel columns land in the top
    bucket and must be subtracted there — and only on real rows. Keys are
    drawn *above* every splitter so the top bucket is also the busiest
    (maximal sensitivity to an over-subtraction)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(900, 1000, (5, 130)).astype(np.int32))
    spl = jnp.asarray(np.array([100, 200], np.int32))
    bid, cnt = _check_against_oracle(x, spl)
    assert (cnt[:, 2] == 130).all()   # every real element, no sentinel residue


def test_padded_rows_sliced_off():
    """rows padded 5 -> 8: returned shapes carry only real rows, and real
    rows' histograms are unaffected by the zero-filled padding rows (which
    land in bucket 0 inside the kernel, not the corrected top bucket)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 100, (5, 130)).astype(np.int32))
    spl = jnp.asarray(np.array([25, 50, 75], np.int32))
    bid, cnt = _check_against_oracle(x, spl)
    assert bid.shape == (5, 130) and cnt.shape == (5, 4)


def test_single_row_single_col():
    x = jnp.asarray(np.array([[42]], np.int32))
    spl = jnp.asarray(np.array([42], np.int32))
    bid, cnt = _check_against_oracle(x, spl)
    assert bid[0, 0] == 1             # 42 <= 42: right bucket
    assert cnt[0].tolist() == [0, 1]


@pytest.mark.parametrize("n_spl", [1, 127])
def test_splitter_count_extremes(n_spl):
    """1 splitter and the 127-splitter lane-tile bound."""
    rng = np.random.default_rng(n_spl)
    x = jnp.asarray(rng.integers(0, 10_000, (3, 128)).astype(np.int32))
    spl = jnp.asarray(np.sort(rng.choice(10_000, n_spl, replace=False))
                      .astype(np.int32))
    _check_against_oracle(x, spl)
