"""Seeded chaos soak: randomized fault schedules against the out-of-core
distributed sort. Plan generation is a pure function of the seed (pinned
here), and the soak contract — every schedule either completes bit-identical
to the no-fault oracle or dies with a typed error whose store resumes
bit-identically — is driven over 25 seeds on the 8-fake-device mesh in a
subprocess (``test_distributed_sort.py``'s pattern).

The soak is the single most expensive test in the suite (25 schedules x 2
invocations over interpret-mode Pallas chunks); sizes stay at ~200 words /
chunks of 32 so it holds within the CI chaos-soak budget.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime import ChaosPlan, make_plan


def _run_multidev(script, timeout=1800):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# plan generation: deterministic, bounded, recoverable by construction
# ---------------------------------------------------------------------------

def test_make_plan_deterministic():
    for seed in (0, 7, 123):
        a, b = make_plan(seed), make_plan(seed)
        assert a == b
        assert isinstance(a, ChaosPlan)
    assert make_plan(1) != make_plan(2)


def test_plans_stay_within_retry_budget():
    """Per stage, transient + timeout faults must stay under max_retries:
    a plan with no kill/device/damage faults has to complete invocation 1."""
    for seed in range(200):
        p = make_plan(seed)
        per_stage = {}
        for stage, _occ in p.fail_at + p.timeout_at:
            per_stage[stage] = per_stage.get(stage, 0) + 1
        assert all(n <= p.max_retries for n in per_stage.values()), \
            f"seed {seed}: unrecoverable schedule {per_stage}"


def test_plan_population_covers_required_fault_classes():
    """Across seeds 0..24 (the CI soak population) the generator must
    exercise every fault class the acceptance bar names: kills inside
    ingest, exchange, and combine; store damage of each kind; both
    validation modes."""
    plans = [make_plan(s) for s in range(25)]
    kill_stages = {st for p in plans for st, _ in p.kill_at}
    assert kill_stages == {"ingest_chunk", "run_exchange",
                          "streaming_combine"}
    kinds = {k for p in plans for k, _store in p.damages}
    assert {"tmp", "truncate", "short_rows", "bitflip"} <= kinds
    assert {p.validate for p in plans} == {"cheap", "full"}
    # bitflips only ride 'full' plans (cheap cannot promise to catch a
    # sortedness-preserving flip) and only target the recomputable shards
    for p in plans:
        for kind, store in p.damages:
            if kind == "bitflip":
                assert p.validate == "full" and store == "shards"


def test_timeouts_ride_dedicated_budget():
    """Timeout faults are retryable: every generated (stage, occ) pair must
    be reachable (occ below the per-stage occurrence ceiling)."""
    from repro.runtime.chaos import _STAGE_OCCS
    for seed in range(100):
        p = make_plan(seed)
        for stage, occ in p.fail_at + p.timeout_at + p.kill_at:
            assert 0 <= occ < _STAGE_OCCS[stage], (seed, stage, occ)


# ---------------------------------------------------------------------------
# the soak
# ---------------------------------------------------------------------------

def test_chaos_soak_25_seeds_mesh(tmp_path):
    """The acceptance bar: 25 seeded schedules on 8 fake devices, each
    bit-identical to the oracle directly or through a typed-error resume —
    and the population must actually have killed jobs inside run_exchange
    and streaming_combine and caught torn-shard damage."""
    out = _run_multidev(f"""
import numpy as np, jax
from repro.core.packing import pack_words
from repro.runtime import chaos_soak

assert len(jax.devices()) == 8
rng = np.random.default_rng(0)
alpha = list("abcdefgh")
words = ["".join(rng.choice(alpha, l)) for l in rng.integers(0, 9, 200)]
keys = np.asarray(pack_words(words))

reports = chaos_soak(keys, seeds=range(25), workdir={str(tmp_path)!r},
                     devices=jax.devices(), num_devices=8)
bad = [r for r in reports if not r.ok]
for r in bad:
    print("BAD seed", r.seed, r.first_error, r.detail)
assert not bad, f"{{len(bad)}} of 25 schedules broke the soak contract"

fired = [(st, kind) for r in reports for (st, _o, kind) in r.fired]
kill_stages = {{st for st, kind in fired if kind == "kill"}}
assert "run_exchange" in kill_stages, "no seed killed the exchange"
assert "streaming_combine" in kill_stages, "no seed killed the combine"
assert any(kind == "timeout" for _st, kind in fired)
damaged_kinds = {{k for r in reports for (k, _path) in r.damaged}}
assert "truncate" in damaged_kinds, "no seed tore a landed file"
resumes = sum(1 for r in reports if r.resumed)
print("SOAK_OK", len(reports), "resumed", resumes,
      "fired", len(fired), "damaged", sum(len(r.damaged) for r in reports))
""")
    assert "SOAK_OK 25" in out


def test_single_seed_soak_single_device(tmp_path):
    """Fast in-process smoke of the same harness (one seed with a kill in
    its schedule, single repeated device) so soak regressions surface
    outside the long mesh job too."""
    import jax

    from repro.core.packing import pack_words
    from repro.runtime import chaos_soak

    rng = np.random.default_rng(1)
    alpha = list("abcdefgh")
    words = ["".join(rng.choice(alpha, l))
             for l in rng.integers(0, 9, 100)]
    keys = np.asarray(pack_words(words))
    seed = next(s for s in range(50) if make_plan(s).kill_at)
    reports = chaos_soak(keys, seeds=[seed], workdir=str(tmp_path),
                         devices=[jax.devices()[0]] * 4)
    assert len(reports) == 1 and reports[0].ok
    assert reports[0].resumed
