"""Failure-handling units: ``ElasticSupervisor`` edge cases,
``StragglerMonitor`` re-baselining after a durable regime shift, and the
sort pipeline's stage-level fault machinery (``StageFailureInjector`` /
``SortSupervisor``) — all host-only, no device work.
"""

import pytest

from repro.runtime import (CapacityOverflow, DeviceFailure,
                           ElasticSupervisor, RetryPolicy, SortSupervisor,
                           StageFailure, StageFailureInjector,
                           StragglerMonitor)


class _FakeCkpt:
    def wait(self):
        pass


def _remesh_factory(snapshots):
    """remesh(devices) -> latest (step, state) snapshot, or None."""
    def remesh(devices):
        return snapshots[-1] if snapshots else None
    return remesh


# ---------------------------------------------------------------------------
# ElasticSupervisor edge cases
# ---------------------------------------------------------------------------

def test_elastic_shrink_below_min_devices_raises():
    """Losing more devices than min_devices allows must fail loudly (the old
    clamp silently pretended min_devices still existed)."""
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=4, min_devices=3)

    def run_segment(state, step, devices):
        raise DeviceFailure("two nodes gone", failed_devices=2)

    with pytest.raises(RuntimeError, match="insufficient surviving devices"):
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    try:
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    except RuntimeError as e:
        assert isinstance(e.__cause__, DeviceFailure)  # original chained
    # devices never mutated to a fictional survivor count
    assert sup.devices == 4
    assert sup.events == []


def test_elastic_max_recoveries_exhaustion_chains_original():
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=16,
                            max_recoveries=3)
    calls = []

    def run_segment(state, step, devices):
        calls.append(devices)
        raise DeviceFailure(f"flaky at {devices}", failed_devices=1)

    with pytest.raises(RuntimeError, match="exceeded max recoveries") as ei:
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    assert isinstance(ei.value.__cause__, DeviceFailure)
    # 1 initial attempt + 3 recoveries, shrinking one device each time
    assert calls == [16, 15, 14, 13]
    assert len(sup.events) == 3


def test_elastic_recovery_event_bookkeeping():
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=8)
    attempts = []

    def run_segment(state, step, devices):
        attempts.append((step, devices))
        if len(attempts) == 1:
            raise DeviceFailure("one gone", failed_devices=1)
        if len(attempts) == 2:
            raise DeviceFailure("two gone", failed_devices=2)
        return state, step

    final = sup.run(run_segment, _remesh_factory([(5, "S")]), "S0", 0)
    assert final == ("S", 5)
    assert [(e.devices_before, e.devices_after) for e in sup.events] == \
        [(8, 7), (7, 5)]
    assert all(e.step == 5 for e in sup.events)  # resumed-from step recorded
    assert attempts == [(0, 8), (5, 7), (5, 5)]


def test_elastic_restartable_keeps_world_size():
    """Single-host / respawning-scheduler mode: the 'lost' device is the
    restarted process, so recovery restores from checkpoint at the SAME
    world size instead of shrinking (1 - 1 = 0 would otherwise raise)."""
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=1,
                            restartable=True)
    attempts = []

    def run_segment(state, step, devices):
        attempts.append((step, devices))
        if len(attempts) == 1:
            raise DeviceFailure("process died", failed_devices=1)
        return state, step

    out = sup.run(run_segment, _remesh_factory([(7, "S")]), "S0", 0)
    assert out == ("S", 7)
    assert attempts == [(0, 1), (7, 1)]  # same world size after recovery
    assert [(e.devices_before, e.devices_after) for e in sup.events] == \
        [(1, 1)]


# ---------------------------------------------------------------------------
# StragglerMonitor re-baselining (frozen-baseline pathology)
# ---------------------------------------------------------------------------

def test_straggler_rebaseline_after_durable_regime_shift():
    """Flagged steps never feed the EWMA, so without re-baselining a durable
    slowdown (migration to slower hardware) is flagged *forever*. After
    ``rebaseline_after`` consecutive flags the monitor must adopt the new
    regime and stop flagging it."""
    mon = StragglerMonitor(threshold=3.0, warmup=5, rebaseline_after=4)
    for s in range(20):
        assert mon.record(s, 0.1 + 0.001 * (s % 3)) is False
    # durable shift: every step is now ~10x slower
    flags = [mon.record(20 + i, 1.0 + 0.001 * (i % 3)) for i in range(12)]
    assert flags[:4] == [True, True, True, True]   # streak builds...
    assert mon.rebaselines == [23]                 # ...then re-baseline
    assert not any(flags[4:])                      # new regime is the norm
    assert mon.mean == pytest.approx(1.0, rel=0.05)
    # a genuine outlier against the NEW baseline still flags
    assert mon.record(40, 30.0) is True


def test_straggler_one_off_does_not_rebaseline():
    mon = StragglerMonitor(threshold=3.0, warmup=5, rebaseline_after=3)
    for s in range(15):
        mon.record(s, 0.1)
    assert mon.record(15, 5.0) is True    # one-off straggler
    assert mon.record(16, 0.1) is False   # healthy step resets the streak
    assert mon.record(17, 5.0) is True
    assert mon.record(18, 0.1) is False
    assert mon.rebaselines == []
    assert mon.mean == pytest.approx(0.1, rel=0.05)  # baseline unpolluted


# ---------------------------------------------------------------------------
# StageFailureInjector
# ---------------------------------------------------------------------------

def test_injector_fires_once_per_scheduled_occurrence():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 2}},
                               device_fail_at={"exchange": {1}},
                               failed_devices=3)
    with pytest.raises(StageFailure) as ei:
        inj.check("ingest_chunk")          # occurrence 0: scheduled
    assert ei.value.stage == "ingest_chunk" and ei.value.occurrence == 0
    inj.check("ingest_chunk")              # occurrence 1: clean
    with pytest.raises(StageFailure):
        inj.check("ingest_chunk")          # occurrence 2: scheduled
    inj.check("ingest_chunk")              # occurrence 3: clean

    inj.check("exchange")                  # occurrence 0: clean
    with pytest.raises(DeviceFailure) as ei:
        inj.check("exchange")              # occurrence 1: device loss
    assert ei.value.failed_devices == 3
    inj.check("exchange")                  # fired faults never repeat

    assert inj.fired == [("ingest_chunk", 0, "transient"),
                         ("ingest_chunk", 2, "transient"),
                         ("exchange", 1, "device")]
    assert inj.occurrences == {"ingest_chunk": 4, "exchange": 3}


# ---------------------------------------------------------------------------
# SortSupervisor
# ---------------------------------------------------------------------------

def test_run_stage_retries_transient_then_succeeds():
    inj = StageFailureInjector(fail_at={"merge_round": {0, 1}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=3), injector=inj)
    calls = []
    out = sup.run_stage("merge_round", lambda: calls.append(1) or "ok")
    assert out == "ok" and calls == [1]
    assert [(e.stage, e.action) for e in sup.events] == \
        [("merge_round", "retry"), ("merge_round", "retry")]


def test_run_stage_exhausts_retries():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 1, 2, 3, 4}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj)
    with pytest.raises(StageFailure):
        sup.run_stage("ingest_chunk", lambda: "never")
    assert len([e for e in sup.events if e.action == "retry"]) == 2


def test_run_stage_exponential_backoff_schedule():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 1, 2}})
    delays = []
    sup = SortSupervisor(
        policy=RetryPolicy(max_retries=3, backoff_base=0.5),
        injector=inj, sleep=delays.append)
    assert sup.run_stage("ingest_chunk", lambda: 42) == 42
    assert delays == [0.5, 1.0, 2.0]


def test_run_with_capacity_doubles_to_required():
    sup = SortSupervisor()
    attempts = []

    def fn(cap):
        attempts.append(cap)
        if cap < 40:
            raise CapacityOverflow("too small", cap, required=40)
        return cap

    assert sup.run_with_capacity("ingest_chunk", fn, 4) == 40
    # jumps straight to the reported requirement, not 4->8->16->32->64
    assert attempts == [4, 40]
    assert [e.action for e in sup.events] == ["capacity_double"]


def test_run_with_capacity_gives_up_after_max_doublings():
    sup = SortSupervisor()

    def fn(cap):
        raise CapacityOverflow("bottomless", cap)

    with pytest.raises(CapacityOverflow, match="still overflowing"):
        sup.run_with_capacity("ingest_chunk", fn, 1, max_doublings=3)


def test_run_distributed_shrinks_on_device_failure():
    inj = StageFailureInjector(device_fail_at={"exchange": {0}},
                               failed_devices=2)
    sup = SortSupervisor(injector=inj)
    meshes = []
    out = sup.run_distributed(lambda d: meshes.append(d) or f"mesh{d}",
                              8, lambda mesh: (mesh, "sorted"))
    assert out == ("mesh6", "sorted")
    assert meshes == [6]  # never built the 8-device mesh: probe fired first
    assert [(e.stage, e.action, e.detail) for e in sup.events] == \
        [("exchange", "remesh", "8 -> 6 devices")]


def test_run_distributed_below_min_devices():
    inj = StageFailureInjector(device_fail_at={"exchange": {0}},
                               failed_devices=7)
    sup = SortSupervisor(injector=inj)
    with pytest.raises(RuntimeError,
                       match="insufficient surviving devices") as ei:
        sup.run_distributed(lambda d: d, 8, lambda mesh: mesh,
                            min_devices=4)
    assert isinstance(ei.value.__cause__, DeviceFailure)


def test_run_distributed_max_recoveries():
    inj = StageFailureInjector(device_fail_at={"exchange": {0, 1, 2}})
    sup = SortSupervisor(injector=inj)
    with pytest.raises(RuntimeError, match="exceeded max recoveries") as ei:
        sup.run_distributed(lambda d: d, 8, lambda mesh: mesh,
                            max_recoveries=2)
    assert isinstance(ei.value.__cause__, DeviceFailure)


# ---------------------------------------------------------------------------
# RetryPolicy seeded jitter
# ---------------------------------------------------------------------------

def test_retry_jitter_schedule_pinned():
    """Full jitter draws from a seeded splitmix64 stream: a pure function
    of (seed, stream, attempt), pinned here so the schedule can never
    drift silently — chaos runs replay bit-identically."""
    from repro.runtime import RetryPolicy

    p = RetryPolicy(max_retries=3, backoff_base=0.5, jitter=1.0, seed=42)
    assert [p.delay(a, stream=0) for a in (1, 2, 3)] == pytest.approx(
        [0.13591061335532129, 0.7866412375473091, 1.8628382167494537])
    # a different stream (another destination retrying the same stage)
    # decollides: same policy, disjoint delays
    assert [p.delay(a, stream=1) for a in (1, 2, 3)] == pytest.approx(
        [0.20080581975595135, 0.027594869490522256, 0.20276720752981037])
    # replay determinism
    assert p.delay(2, stream=1) == p.delay(2, stream=1)


def test_retry_jitter_bounds_and_legacy_exactness():
    from repro.runtime import RetryPolicy

    full = RetryPolicy(backoff_base=1.0, jitter=1.0, seed=3)
    for stream in range(50):
        d = full.delay(1, stream=stream)
        assert 0.0 < d <= 1.0          # full jitter: (0, expo]
    half = RetryPolicy(backoff_base=1.0, jitter=0.5, seed=3)
    for stream in range(50):
        assert 0.5 <= half.delay(1, stream=stream) <= 1.0
    # jitter=0 keeps the legacy exact schedule regardless of stream
    legacy = RetryPolicy(backoff_base=0.5)
    assert [legacy.delay(a, stream=9) for a in (1, 2, 3)] == [0.5, 1.0, 2.0]


def test_supervisor_streams_decollide_destinations():
    """Two invocations of the same stage (two combine destinations) must
    draw different jittered schedules, and a replayed supervisor draws the
    same ones."""
    from repro.runtime import RetryPolicy, SortSupervisor, StageFailureInjector

    def delays_of():
        inj = StageFailureInjector(fail_at={"streaming_combine": {0, 2}})
        delays = []
        sup = SortSupervisor(
            policy=RetryPolicy(max_retries=3, backoff_base=0.5,
                               jitter=1.0, seed=11),
            injector=inj, sleep=delays.append)
        sup.run_stage("streaming_combine", lambda: 1)
        sup.run_stage("streaming_combine", lambda: 2)
        return delays

    a = delays_of()
    assert len(a) == 2 and a[0] != a[1]     # per-destination decollision
    assert a == delays_of()                 # replay determinism


# ---------------------------------------------------------------------------
# StageTimeout: injected + real deadlines
# ---------------------------------------------------------------------------

def test_injected_timeout_is_retried_like_transient():
    from repro.runtime import (RetryPolicy, SortSupervisor,
                               StageFailureInjector, StageTimeout)

    inj = StageFailureInjector(timeout_at={"streaming_combine": {0}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj)
    assert sup.run_stage("streaming_combine", lambda: "ok") == "ok"
    assert inj.fired == [("streaming_combine", 0, "timeout")]
    assert [e.action for e in sup.events] == ["timeout_retry"]
    # exhaustion propagates the typed timeout
    inj2 = StageFailureInjector(timeout_at={"run_exchange": {0, 1, 2}})
    sup2 = SortSupervisor(policy=RetryPolicy(max_retries=1), injector=inj2)
    with pytest.raises(StageTimeout):
        sup2.run_stage("run_exchange", lambda: "never")


def test_deadline_converts_hang_to_timeout_and_retry_succeeds():
    """A stage outliving its wall-clock deadline becomes a retryable
    StageTimeout; the retry (the hang was injected fire-once slowness)
    completes. The timed-out launch is abandoned, never joined."""
    import time as _time

    from repro.runtime import (RetryPolicy, SortSupervisor,
                               StageFailureInjector)

    inj = StageFailureInjector(slow_at={"streaming_combine": {0: 0.5}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj,
                         deadlines={"streaming_combine": 0.1})
    t0 = _time.monotonic()
    assert sup.run_stage("streaming_combine", lambda: "done") == "done"
    # the retry must not block on the 0.5s abandoned sleeper
    assert _time.monotonic() - t0 < 0.45
    assert inj.fired == [("streaming_combine", 0, "slow")]
    assert [e.action for e in sup.events] == ["timeout_retry"]
    assert "deadline" in sup.events[0].detail


def test_deadline_exhaustion_raises_stage_timeout():
    from repro.runtime import (RetryPolicy, SortSupervisor,
                               StageFailureInjector, StageTimeout)

    inj = StageFailureInjector(
        slow_at={"run_exchange": {0: 0.3, 1: 0.3}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=1), injector=inj,
                         deadlines={"run_exchange": 0.05})
    with pytest.raises(StageTimeout) as ei:
        sup.run_stage("run_exchange", lambda: "never")
    assert ei.value.stage == "run_exchange"
    assert ei.value.deadline == pytest.approx(0.05)


def test_stages_without_deadline_run_unwrapped():
    from repro.runtime import SortSupervisor

    sup = SortSupervisor(deadlines={"other_stage": 0.01})
    import threading
    main = threading.get_ident()
    seen = []
    sup.run_stage("ingest_chunk", lambda: seen.append(threading.get_ident()))
    assert seen == [main]   # no worker thread without a deadline


# ---------------------------------------------------------------------------
# ProcessKilled: never retried
# ---------------------------------------------------------------------------

def test_kill_propagates_without_retry():
    from repro.runtime import (ProcessKilled, RetryPolicy, SortSupervisor,
                               StageFailureInjector)

    inj = StageFailureInjector(kill_at={"streaming_combine": {1}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=5), injector=inj)
    assert sup.run_stage("streaming_combine", lambda: 0) == 0
    calls = []
    with pytest.raises(ProcessKilled) as ei:
        sup.run_stage("streaming_combine", lambda: calls.append(1))
    assert ei.value.stage == "streaming_combine" and ei.value.occurrence == 1
    assert calls == []                 # died at the boundary, fn never ran
    assert sup.events == []            # no retry was attempted
    assert inj.fired == [("streaming_combine", 1, "kill")]


# ---------------------------------------------------------------------------
# speculation (StragglerMonitor.cutoff + run_speculative)
# ---------------------------------------------------------------------------

def _warm_monitor(mean=0.01, warmup=3):
    mon = StragglerMonitor(warmup=warmup, min_ratio=2.0)
    for s in range(warmup):
        mon.record(s, mean)
    return mon


def test_monitor_cutoff_warmup_then_relative_floor():
    mon = StragglerMonitor(warmup=3, min_ratio=1.5)
    assert mon.cutoff() is None
    for s in range(3):
        mon.record(s, 0.2)
    assert mon.cutoff() == pytest.approx(0.3, rel=0.05)


def test_run_speculative_fast_primary_no_backup():
    from repro.runtime import SortSupervisor, SpeculationPolicy

    mon = _warm_monitor()
    sup = SortSupervisor(
        speculation=SpeculationPolicy(monitor=mon, min_wait=0.2))
    assert sup.run_speculative("streaming_combine", lambda: "fast") == "fast"
    assert sup.events == []            # no speculation happened
    assert mon.count == 4              # completion fed the baseline


def test_run_speculative_backup_wins_and_loser_confirmed():
    """Primary straggling (injected slow) past the cutoff: backup launches,
    wins, and the loser's digest-equal output confirms the discard."""
    from repro.runtime import (SortSupervisor, SpeculationPolicy,
                               StageFailureInjector)

    mon = _warm_monitor(mean=0.01)
    inj = StageFailureInjector(slow_at={"streaming_combine": {0: 0.6}})
    sup = SortSupervisor(
        injector=inj,
        speculation=SpeculationPolicy(monitor=mon, min_wait=0.05))
    out = sup.run_speculative("streaming_combine", lambda: 41 + 1,
                              digest_of=lambda v: v)
    assert out == 42
    actions = [e.action for e in sup.events]
    assert actions == ["speculate", "speculation_confirmed"]
    assert "backup won" in sup.events[-1].detail


def test_run_speculative_digest_mismatch_raises():
    from repro.runtime import (SortSupervisor, SpeculationMismatch,
                               SpeculationPolicy, StageFailureInjector)

    mon = _warm_monitor(mean=0.01)
    inj = StageFailureInjector(slow_at={"streaming_combine": {0: 0.6}})
    sup = SortSupervisor(
        injector=inj,
        speculation=SpeculationPolicy(monitor=mon, min_wait=0.05))
    results = iter([1, 2])            # impure stage: replicas disagree
    with pytest.raises(SpeculationMismatch):
        sup.run_speculative("streaming_combine",
                            lambda: next(results),
                            digest_of=lambda v: v)


def test_run_speculative_loser_failure_is_recorded_not_fatal():
    """The slow loser raising after the winner completed must not fail the
    stage — the winner already proved it computable — but is recorded."""
    import time as _time

    from repro.runtime import SortSupervisor, SpeculationPolicy

    mon = _warm_monitor(mean=0.01)
    sup = SortSupervisor(
        speculation=SpeculationPolicy(monitor=mon, min_wait=0.05))
    calls = []

    def fn():
        calls.append(1)
        if len(calls) == 1:           # primary: slow, then dies
            _time.sleep(0.4)
            raise RuntimeError("late failure")
        return "ok"

    assert sup.run_speculative("streaming_combine", fn,
                               digest_of=lambda v: v) == "ok"
    actions = [e.action for e in sup.events]
    assert actions == ["speculate", "speculation_loser_failed"]


def test_run_speculative_transient_failure_uses_retry_budget():
    from repro.runtime import (RetryPolicy, SortSupervisor,
                               SpeculationPolicy, StageFailureInjector)

    mon = _warm_monitor(mean=0.05)
    inj = StageFailureInjector(fail_at={"streaming_combine": {0}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj,
                         speculation=SpeculationPolicy(monitor=mon))
    assert sup.run_speculative("streaming_combine", lambda: "ok") == "ok"
    assert [e.action for e in sup.events] == ["retry"]


def test_run_speculative_without_policy_is_run_stage():
    from repro.runtime import SortSupervisor, StageFailureInjector

    inj = StageFailureInjector(fail_at={"streaming_combine": {0}})
    sup = SortSupervisor(injector=inj)
    assert sup.run_speculative("streaming_combine", lambda: 7) == 7
    assert [e.action for e in sup.events] == ["retry"]
