"""Failure-handling units: ``ElasticSupervisor`` edge cases,
``StragglerMonitor`` re-baselining after a durable regime shift, and the
sort pipeline's stage-level fault machinery (``StageFailureInjector`` /
``SortSupervisor``) — all host-only, no device work.
"""

import pytest

from repro.runtime import (CapacityOverflow, DeviceFailure,
                           ElasticSupervisor, RetryPolicy, SortSupervisor,
                           StageFailure, StageFailureInjector,
                           StragglerMonitor)


class _FakeCkpt:
    def wait(self):
        pass


def _remesh_factory(snapshots):
    """remesh(devices) -> latest (step, state) snapshot, or None."""
    def remesh(devices):
        return snapshots[-1] if snapshots else None
    return remesh


# ---------------------------------------------------------------------------
# ElasticSupervisor edge cases
# ---------------------------------------------------------------------------

def test_elastic_shrink_below_min_devices_raises():
    """Losing more devices than min_devices allows must fail loudly (the old
    clamp silently pretended min_devices still existed)."""
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=4, min_devices=3)

    def run_segment(state, step, devices):
        raise DeviceFailure("two nodes gone", failed_devices=2)

    with pytest.raises(RuntimeError, match="insufficient surviving devices"):
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    try:
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    except RuntimeError as e:
        assert isinstance(e.__cause__, DeviceFailure)  # original chained
    # devices never mutated to a fictional survivor count
    assert sup.devices == 4
    assert sup.events == []


def test_elastic_max_recoveries_exhaustion_chains_original():
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=16,
                            max_recoveries=3)
    calls = []

    def run_segment(state, step, devices):
        calls.append(devices)
        raise DeviceFailure(f"flaky at {devices}", failed_devices=1)

    with pytest.raises(RuntimeError, match="exceeded max recoveries") as ei:
        sup.run(run_segment, _remesh_factory([(0, {})]), {}, 0)
    assert isinstance(ei.value.__cause__, DeviceFailure)
    # 1 initial attempt + 3 recoveries, shrinking one device each time
    assert calls == [16, 15, 14, 13]
    assert len(sup.events) == 3


def test_elastic_recovery_event_bookkeeping():
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=8)
    attempts = []

    def run_segment(state, step, devices):
        attempts.append((step, devices))
        if len(attempts) == 1:
            raise DeviceFailure("one gone", failed_devices=1)
        if len(attempts) == 2:
            raise DeviceFailure("two gone", failed_devices=2)
        return state, step

    final = sup.run(run_segment, _remesh_factory([(5, "S")]), "S0", 0)
    assert final == ("S", 5)
    assert [(e.devices_before, e.devices_after) for e in sup.events] == \
        [(8, 7), (7, 5)]
    assert all(e.step == 5 for e in sup.events)  # resumed-from step recorded
    assert attempts == [(0, 8), (5, 7), (5, 5)]


def test_elastic_restartable_keeps_world_size():
    """Single-host / respawning-scheduler mode: the 'lost' device is the
    restarted process, so recovery restores from checkpoint at the SAME
    world size instead of shrinking (1 - 1 = 0 would otherwise raise)."""
    sup = ElasticSupervisor(_FakeCkpt(), initial_devices=1,
                            restartable=True)
    attempts = []

    def run_segment(state, step, devices):
        attempts.append((step, devices))
        if len(attempts) == 1:
            raise DeviceFailure("process died", failed_devices=1)
        return state, step

    out = sup.run(run_segment, _remesh_factory([(7, "S")]), "S0", 0)
    assert out == ("S", 7)
    assert attempts == [(0, 1), (7, 1)]  # same world size after recovery
    assert [(e.devices_before, e.devices_after) for e in sup.events] == \
        [(1, 1)]


# ---------------------------------------------------------------------------
# StragglerMonitor re-baselining (frozen-baseline pathology)
# ---------------------------------------------------------------------------

def test_straggler_rebaseline_after_durable_regime_shift():
    """Flagged steps never feed the EWMA, so without re-baselining a durable
    slowdown (migration to slower hardware) is flagged *forever*. After
    ``rebaseline_after`` consecutive flags the monitor must adopt the new
    regime and stop flagging it."""
    mon = StragglerMonitor(threshold=3.0, warmup=5, rebaseline_after=4)
    for s in range(20):
        assert mon.record(s, 0.1 + 0.001 * (s % 3)) is False
    # durable shift: every step is now ~10x slower
    flags = [mon.record(20 + i, 1.0 + 0.001 * (i % 3)) for i in range(12)]
    assert flags[:4] == [True, True, True, True]   # streak builds...
    assert mon.rebaselines == [23]                 # ...then re-baseline
    assert not any(flags[4:])                      # new regime is the norm
    assert mon.mean == pytest.approx(1.0, rel=0.05)
    # a genuine outlier against the NEW baseline still flags
    assert mon.record(40, 30.0) is True


def test_straggler_one_off_does_not_rebaseline():
    mon = StragglerMonitor(threshold=3.0, warmup=5, rebaseline_after=3)
    for s in range(15):
        mon.record(s, 0.1)
    assert mon.record(15, 5.0) is True    # one-off straggler
    assert mon.record(16, 0.1) is False   # healthy step resets the streak
    assert mon.record(17, 5.0) is True
    assert mon.record(18, 0.1) is False
    assert mon.rebaselines == []
    assert mon.mean == pytest.approx(0.1, rel=0.05)  # baseline unpolluted


# ---------------------------------------------------------------------------
# StageFailureInjector
# ---------------------------------------------------------------------------

def test_injector_fires_once_per_scheduled_occurrence():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 2}},
                               device_fail_at={"exchange": {1}},
                               failed_devices=3)
    with pytest.raises(StageFailure) as ei:
        inj.check("ingest_chunk")          # occurrence 0: scheduled
    assert ei.value.stage == "ingest_chunk" and ei.value.occurrence == 0
    inj.check("ingest_chunk")              # occurrence 1: clean
    with pytest.raises(StageFailure):
        inj.check("ingest_chunk")          # occurrence 2: scheduled
    inj.check("ingest_chunk")              # occurrence 3: clean

    inj.check("exchange")                  # occurrence 0: clean
    with pytest.raises(DeviceFailure) as ei:
        inj.check("exchange")              # occurrence 1: device loss
    assert ei.value.failed_devices == 3
    inj.check("exchange")                  # fired faults never repeat

    assert inj.fired == [("ingest_chunk", 0, "transient"),
                         ("ingest_chunk", 2, "transient"),
                         ("exchange", 1, "device")]
    assert inj.occurrences == {"ingest_chunk": 4, "exchange": 3}


# ---------------------------------------------------------------------------
# SortSupervisor
# ---------------------------------------------------------------------------

def test_run_stage_retries_transient_then_succeeds():
    inj = StageFailureInjector(fail_at={"merge_round": {0, 1}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=3), injector=inj)
    calls = []
    out = sup.run_stage("merge_round", lambda: calls.append(1) or "ok")
    assert out == "ok" and calls == [1]
    assert [(e.stage, e.action) for e in sup.events] == \
        [("merge_round", "retry"), ("merge_round", "retry")]


def test_run_stage_exhausts_retries():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 1, 2, 3, 4}})
    sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj)
    with pytest.raises(StageFailure):
        sup.run_stage("ingest_chunk", lambda: "never")
    assert len([e for e in sup.events if e.action == "retry"]) == 2


def test_run_stage_exponential_backoff_schedule():
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 1, 2}})
    delays = []
    sup = SortSupervisor(
        policy=RetryPolicy(max_retries=3, backoff_base=0.5),
        injector=inj, sleep=delays.append)
    assert sup.run_stage("ingest_chunk", lambda: 42) == 42
    assert delays == [0.5, 1.0, 2.0]


def test_run_with_capacity_doubles_to_required():
    sup = SortSupervisor()
    attempts = []

    def fn(cap):
        attempts.append(cap)
        if cap < 40:
            raise CapacityOverflow("too small", cap, required=40)
        return cap

    assert sup.run_with_capacity("ingest_chunk", fn, 4) == 40
    # jumps straight to the reported requirement, not 4->8->16->32->64
    assert attempts == [4, 40]
    assert [e.action for e in sup.events] == ["capacity_double"]


def test_run_with_capacity_gives_up_after_max_doublings():
    sup = SortSupervisor()

    def fn(cap):
        raise CapacityOverflow("bottomless", cap)

    with pytest.raises(CapacityOverflow, match="still overflowing"):
        sup.run_with_capacity("ingest_chunk", fn, 1, max_doublings=3)


def test_run_distributed_shrinks_on_device_failure():
    inj = StageFailureInjector(device_fail_at={"exchange": {0}},
                               failed_devices=2)
    sup = SortSupervisor(injector=inj)
    meshes = []
    out = sup.run_distributed(lambda d: meshes.append(d) or f"mesh{d}",
                              8, lambda mesh: (mesh, "sorted"))
    assert out == ("mesh6", "sorted")
    assert meshes == [6]  # never built the 8-device mesh: probe fired first
    assert [(e.stage, e.action, e.detail) for e in sup.events] == \
        [("exchange", "remesh", "8 -> 6 devices")]


def test_run_distributed_below_min_devices():
    inj = StageFailureInjector(device_fail_at={"exchange": {0}},
                               failed_devices=7)
    sup = SortSupervisor(injector=inj)
    with pytest.raises(RuntimeError,
                       match="insufficient surviving devices") as ei:
        sup.run_distributed(lambda d: d, 8, lambda mesh: mesh,
                            min_devices=4)
    assert isinstance(ei.value.__cause__, DeviceFailure)


def test_run_distributed_max_recoveries():
    inj = StageFailureInjector(device_fail_at={"exchange": {0, 1, 2}})
    sup = SortSupervisor(injector=inj)
    with pytest.raises(RuntimeError, match="exceeded max recoveries") as ei:
        sup.run_distributed(lambda d: d, 8, lambda mesh: mesh,
                            max_recoveries=2)
    assert isinstance(ei.value.__cause__, DeviceFailure)
