"""pipeline/validate.py digest edges.

The multiset digest is the load-bearing half of the corruption gate; these
pin its boundary behavior: the empty-run digest, single-element (capacity-1)
runs where the sortedness compare never fires, the additive mod-2^64
wraparound the merge reconciliation leans on, and — by inverting the
splitmix64 finalizer — a crafted pair of rows whose summed digest equals
the empty digest, proving the count check is load-bearing and not
redundant next to the digest compare.
"""

import types

import numpy as np
import pytest

from repro.pipeline.manifest import RunManifest
from repro.pipeline.validate import (ValidationError, _mix, check_chunked,
                                     check_lanes_sorted, check_multiset,
                                     check_run, keys_digest, multiset_digest,
                                     order_bits_view)

_M64 = (1 << 64) - 1
_FNV_PRIME = 0x100000001B3
_FNV_OFFSET = 0xCBF29CE484222325


def _manifest(keys, lengths, nb=2):
    keys = np.asarray(keys, np.uint32)
    lengths = np.asarray(lengths, np.int32)
    return RunManifest(
        chunk_id=0, count=int(lengths.shape[0]), lanes=keys.shape[1],
        length_histogram=tuple(np.bincount(lengths, minlength=nb).tolist()),
        min_key=None, max_key=None, digest=keys_digest(keys))


def test_empty_run_digest_is_zero():
    assert multiset_digest([]) == 0
    assert multiset_digest([np.zeros(0, np.uint32)]) == 0
    assert keys_digest(np.zeros((0, 3), np.uint32)) == 0
    # an empty run reconciles against its manifest in full mode
    keys = np.zeros((0, 2), np.uint32)
    lengths = np.zeros(0, np.int32)
    check_run(types.SimpleNamespace(keys=keys, lengths=lengths),
              _manifest(keys, lengths), mode="full")


def test_capacity_one_runs_reconcile_and_catch_corruption():
    """Single-element (capacity-1) runs: the adjacent sortedness compare
    never fires (n < 2), so the digest is the only content check left —
    it must still catch a flipped element end to end."""
    r1 = types.SimpleNamespace(keys=np.array([[5, 0]], np.uint32),
                               lengths=np.array([1], np.int32))
    r2 = types.SimpleNamespace(keys=np.array([[3, 7]], np.uint32),
                               lengths=np.array([1], np.int32))
    mans = [_manifest(r.keys, r.lengths) for r in (r1, r2)]
    merged = types.SimpleNamespace(keys=np.array([[3, 7], [5, 0]], np.uint32),
                                   lengths=np.array([1, 1], np.int32))
    check_chunked([r1, r2], mans, merged, mode="full")
    corrupted = types.SimpleNamespace(
        keys=np.array([[3, 7], [5, 1]], np.uint32),  # one flipped bit-ish
        lengths=merged.lengths)
    with pytest.raises(ValidationError, match="digest"):
        check_chunked([r1, r2], mans, corrupted, mode="full")


def test_digest_is_additive_mod_2_64():
    rng = np.random.default_rng(7)
    a = [rng.integers(0, _M64, 500, dtype=np.uint64)]
    b = [rng.integers(0, _M64, 300, dtype=np.uint64)]
    both = [np.concatenate([a[0], b[0]])]
    assert multiset_digest(both) == \
        (multiset_digest(a) + multiset_digest(b)) % (1 << 64)


# --- crafted collision: same digest, different count -------------------------

def _inv_xshr(y: int, s: int) -> int:
    x = y
    for _ in range(0, 64, s):
        x = y ^ (x >> s)
    return x


def _mix_inv(h: int) -> int:
    """Inverse of validate._mix (the splitmix64 finalizer is a bijection)."""
    h = _inv_xshr(h, 31)
    h = (h * pow(0x94D049BB133111EB, -1, 1 << 64)) & _M64
    h = _inv_xshr(h, 27)
    h = (h * pow(0xBF58476D1CE4E5B9, -1, 1 << 64)) & _M64
    h = _inv_xshr(h, 30)
    return h


def test_mix_inverse_round_trips():
    rng = np.random.default_rng(11)
    vals = rng.integers(0, _M64, 64, dtype=np.uint64)
    mixed = _mix(vals)
    back = np.array([_mix_inv(int(m)) for m in mixed], np.uint64)
    np.testing.assert_array_equal(back, vals)


def test_crafted_pair_collides_with_empty_digest():
    """Two rows whose per-row digests sum to exactly 2^64: the pair's
    digest equals the empty multiset's (0), with the wraparound hitting the
    modulus on the nose. The digest alone therefore cannot distinguish
    {a, b} from {} — check_multiset must catch it via the element *count*,
    which is why the count check precedes the digest compare."""
    chain0 = (_FNV_OFFSET * _FNV_PRIME) & _M64  # one-lane FNV chain prefix
    v_a = 0xDEADBEEFCAFEF00D
    d_a = multiset_digest([np.array([v_a], np.uint64)])
    h_b = _mix_inv(((1 << 64) - d_a) & _M64)
    v_b = h_b ^ chain0
    pair = [np.array([v_a, v_b], np.uint64)]
    assert multiset_digest(pair) == multiset_digest([]) == 0
    assert (d_a + multiset_digest([np.array([v_b], np.uint64)])) == (1 << 64)
    with pytest.raises(ValidationError, match="count changed"):
        check_multiset([np.zeros(0, np.uint64)], pair)


def test_float_digest_negative_zero_round_trip():
    """An engine may legally return +0.0 where -0.0 went in (the canonical
    order equates them): the digest must reconcile that swap instead of
    flagging corruption, because it hashes the order-bits view — while a
    *value* change of the same magnitude still trips it."""
    a = np.array([-0.0, 1.5, -2.25, 0.0, -0.0], np.float32)
    swapped = a.copy()
    swapped[[0, 4]] = np.float32(0.0)  # -0.0 -> +0.0, bitwise different
    assert a.view(np.uint32).tolist() != swapped.view(np.uint32).tolist()
    assert multiset_digest([a]) == multiset_digest([swapped])
    check_multiset([a], [swapped])  # must not raise
    altered = a.copy()
    altered[1] = np.float32(1.5000001)
    assert multiset_digest([a]) != multiset_digest([altered])


def test_check_lanes_sorted_rejects_nan_out_of_tail():
    """A raw float compare decides nothing against NaN, so a NaN stranded
    mid-run would sail through a naive check — the order-bits view makes it
    a hard failure, and a NaN-tailed run passes."""
    check_lanes_sorted([np.array([-np.inf, -0.0, 0.0, 2.5, np.inf, np.nan,
                                  np.nan], np.float32)])
    with pytest.raises(ValidationError, match="not sorted"):
        check_lanes_sorted([np.array([1.0, np.nan, 2.0], np.float32)])


def test_order_bits_view_matches_jax_transform():
    """Differential pin: the numpy mirror and ``kernels.lex.to_order_bits``
    are the same function, bit for bit, over an adversarial float32 set
    (±0.0, ±inf, every NaN payload class, the sentinel pattern). Denormals
    are excluded by design: XLA flushes them to zero in compares (the jax
    transform follows its backend; the numpy mirror follows IEEE), the one
    documented divergence between the two runtimes."""
    import jax.numpy as jnp

    from repro.kernels.lex import to_order_bits

    vals = np.array([0x00000000, 0x80000000,   # +/- 0.0
                     0x3F800000, 0xBF800000,   # +/- 1.0
                     0x7F7FFFFF, 0xFF7FFFFF,   # +/- max finite
                     0x7F800000, 0xFF800000,   # +/- inf
                     0x7FC00000, 0xFFC00000,   # quiet NaNs
                     0x7F800001, 0xFF800001,   # signalling NaNs
                     0xFFFFFFFF],              # the padding sentinel
                    np.uint32).view(np.float32)
    rng = np.random.default_rng(11)
    vals = np.concatenate([vals, rng.normal(size=64).astype(np.float32)])
    np.testing.assert_array_equal(
        order_bits_view(vals),
        np.asarray(to_order_bits(jnp.asarray(vals))))
