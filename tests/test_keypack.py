"""Packed rank-key subsystem (kernels/keypack.py): the packed order must
equal the lane-wise ``lex_gt_lanes`` order, exactly, across every bias rule
and both packing tiers (exact 1-2 lane budgets and the >2-lane prefix
fallback). The lane-wise ``lex_rank_count``/``lex_merge_take`` stay the
differential oracle; sizes stay <= 128 per the interpret-mode compile-width
constraint (the sort engines compile per shape)."""

import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import keypack as kp
from repro.kernels import sort_lex
from repro.kernels.lex import lex_merge_take, lex_rank_count

U32_MAX = np.uint32(0xFFFFFFFF)


def _seed(*parts):
    return zlib.crc32("-".join(map(str, parts)).encode())


def _draw_lane(rng, n, dtype, flavor):
    """flavor: 'random' | 'negatives' (int32 spanning the sign bit) |
    'sentinel' (collides with 0xFFFFFFFF / iinfo.max) | 'dups' (tiny
    alphabet, many ties)."""
    if flavor == "negatives":
        return rng.integers(-(2**31), 2**31, n).astype(np.int32)
    if flavor == "sentinel":
        x = rng.integers(0, 2**32, n).astype(np.uint32)
        x[rng.random(n) < 0.4] = U32_MAX
        return x
    if flavor == "dups":
        return rng.integers(0, 4, n).astype(dtype)
    if dtype == np.int32:
        return rng.integers(-(2**31), 2**31, n).astype(np.int32)
    return rng.integers(0, 2**32, n).astype(np.uint32)


def _sorted_lanes(lanes):
    order = np.lexsort(tuple(np.asarray(a) for a in reversed(lanes)))
    return [jnp.asarray(np.asarray(a)[order]) for a in lanes]


# ---------------------------------------------------------------------------
# bias rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.int8, np.int16,
                                   np.uint16])
def test_bias_preserves_integer_order(dtype):
    rng = np.random.default_rng(_seed("bias", dtype.__name__))
    info = np.iinfo(dtype)
    a = rng.integers(info.min, int(info.max) + 1, 200).astype(dtype)
    b = rng.integers(info.min, int(info.max) + 1, 200).astype(dtype)
    for edge in (info.min, info.max, 0):
        a[rng.integers(0, 200)] = edge
    ba = np.asarray(kp.bias_to_u32(jnp.asarray(a)))
    bb = np.asarray(kp.bias_to_u32(jnp.asarray(b)))
    np.testing.assert_array_equal(ba > bb, a > b)
    np.testing.assert_array_equal(ba == bb, a == b)


def test_bias_float32_total_order_and_zero_equality():
    """The oracle is *jax's* compare (what ``lex_gt_lanes`` compiles to) —
    XLA flushes denormals to zero in comparisons, and the bias must agree
    with that, not with numpy."""
    rng = np.random.default_rng(_seed("bias", "f32"))
    a = jnp.asarray(np.concatenate(
        [rng.normal(size=60), [0.0, -0.0, np.inf, -np.inf,
                               1e-38, -1e-38]]).astype(np.float32))
    b = jnp.asarray(np.concatenate(
        [rng.normal(size=60), [-0.0, 0.0, -np.inf, np.inf,
                               -1e-38, 1e-38]]).astype(np.float32))
    ba = np.asarray(kp.bias_to_u32(a))
    bb = np.asarray(kp.bias_to_u32(b))
    np.testing.assert_array_equal(ba > bb, np.asarray(a > b))
    # -0.0 is normalised before biasing, so packed equality matches ==
    np.testing.assert_array_equal(ba == bb, np.asarray(a == b))


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def test_pack_exact_roundtrip_tight_widths():
    """Three bounded lanes collapse into a single uint32 rank key and come
    back bit-identical."""
    rng = np.random.default_rng(_seed("roundtrip"))
    lanes = [jnp.asarray(rng.integers(0, 13, 100).astype(np.int32)),
             jnp.asarray(rng.integers(0, 256, 100).astype(np.uint32)),
             jnp.asarray(rng.integers(-128, 128, 100).astype(np.int8))]
    mv = (12, 255, None)
    pk = kp.pack_rank_keys(lanes, mv)
    assert pk.plan.exact and pk.plan.n_packed == 1 and pk.plan.covered == 3
    back = kp.unpack_rank_keys(pk.lanes, [a.dtype for a in lanes], mv)
    for a, r in zip(lanes, back):
        assert r.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_pack_two_lane_budget_roundtrip():
    rng = np.random.default_rng(_seed("u64"))
    lanes = [jnp.asarray(rng.integers(-(2**31), 2**31, 90).astype(np.int32)),
             jnp.asarray(rng.integers(0, 2**32, 90).astype(np.uint32))]
    pk = kp.pack_rank_keys(lanes)
    assert pk.plan.exact and pk.plan.n_packed == 2
    back = kp.unpack_rank_keys(pk.lanes, [a.dtype for a in lanes])
    for a, r in zip(lanes, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_pack_overflow_is_inexact_and_unpack_refuses():
    lanes = [jnp.zeros((4,), jnp.uint32)] * 3
    pk = kp.pack_rank_keys(lanes)
    assert not pk.plan.exact and pk.plan.covered == 2
    with pytest.raises(ValueError, match="inexact"):
        kp.unpack_rank_keys(pk.lanes, [jnp.uint32] * 3)


def test_bad_inputs():
    with pytest.raises(ValueError):
        kp.pack_rank_keys([])
    with pytest.raises(ValueError):
        kp.plan_pack([jnp.uint32], max_values=(1, 2))
    with pytest.raises(TypeError):
        kp.plan_pack([jnp.float64])
    with pytest.raises(ValueError):
        kp.lex_searchsorted([jnp.zeros(3)], [jnp.zeros(3)], side="middle")


def test_bounded_float_lane_refused():
    """max_values on a float lane would pack by fraction truncation
    (1.9 and 1.2 both -> 1) — it must raise, and the merge front-end must
    fall back to a correct lane-wise rank instead of emitting unsorted
    output."""
    with pytest.raises(TypeError, match="integer"):
        kp.plan_pack([jnp.float32], max_values=(7,))
    with pytest.raises(TypeError, match="integer"):
        kp.bias_to_u32(jnp.asarray([1.9], jnp.float32), max_value=7)
    from repro.kernels import merge_sorted_lex
    a = (jnp.asarray([1.9], jnp.float32),)
    b = (jnp.asarray([1.2], jnp.float32),)
    (out,) = merge_sorted_lex(a, b, engine="packed", max_values=(7,))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.array([1.2, 1.9], np.float32))


def test_sort_lex_packed_engine_validates_shapes():
    """Shape validation must run before the packed routing: mismatched
    lanes raise instead of silently broadcasting through the pack."""
    from repro.kernels import sort_lex as ops_sort_lex
    a = jnp.asarray([3, 1, 2], jnp.uint8)
    b = jnp.asarray([0], jnp.uint8)
    with pytest.raises(ValueError, match="identical shapes"):
        ops_sort_lex([a, b])


# ---------------------------------------------------------------------------
# packed order == lane-wise order (the subsystem's whole contract)
# ---------------------------------------------------------------------------

FLAVORS = ["random", "negatives", "sentinel", "dups"]


@pytest.mark.parametrize("flavor", FLAVORS)
@pytest.mark.parametrize("n_lanes", [1, 2, 3, 4])
def test_packed_searchsorted_matches_broadcast_oracle(n_lanes, flavor):
    """Ranks from the packed binary search equal ``lex_rank_count``'s
    broadcast on both sides (strict/left and non-strict/right) — covering
    signed negatives, 0xFFFFFFFF sentinel collisions, the >2-lane prefix
    fallback, and dup-heavy ties."""
    rng = np.random.default_rng(_seed("ss", n_lanes, flavor))
    A = _sorted_lanes([_draw_lane(rng, 96, np.uint32, flavor)
                       for _ in range(n_lanes)])
    V = [jnp.asarray(_draw_lane(rng, 57, np.uint32, flavor))
         for _ in range(n_lanes)]
    for side, strict in [("left", True), ("right", False)]:
        got = kp.packed_searchsorted(A, V, side=side)
        want = lex_rank_count(A, V, strict=strict)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("flavor", FLAVORS)
@pytest.mark.parametrize("n_lanes", [1, 2, 3])
@pytest.mark.parametrize("na,nb", [(80, 47), (1, 64), (33, 33)])
def test_merge_take_packed_bit_identical(n_lanes, flavor, na, nb):
    rng = np.random.default_rng(_seed("mt", n_lanes, flavor, na, nb))
    A = _sorted_lanes([_draw_lane(rng, na, np.uint32, flavor)
                       for _ in range(n_lanes)])
    B = _sorted_lanes([_draw_lane(rng, nb, np.uint32, flavor)
                       for _ in range(n_lanes)])
    got = kp.merge_take_packed(A, B)
    want = lex_merge_take(A, B)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_cmp_from_packed_consistent_with_fresh_packing():
    """Rank keys packed ahead of time (the fused-program path) rank
    identically to a fresh ``packed_cmp_lanes``."""
    rng = np.random.default_rng(_seed("cfp"))
    lens = np.sort(rng.integers(0, 9, 70)).astype(np.int32)
    keys = rng.integers(0, 2**32, (70, 2)).astype(np.uint32)
    lanes = [jnp.asarray(lens)] + [jnp.asarray(keys[:, l]) for l in range(2)]
    lanes = _sorted_lanes(lanes)
    keys2d = jnp.stack(lanes[1:], axis=1)
    pk = kp.pack_shortlex(lanes[0], keys2d)
    mv = kp.shortlex_max_values(2)
    via_precomputed = kp.cmp_from_packed(list(pk.lanes), lanes, mv)
    fresh = kp.packed_cmp_lanes(lanes, mv)
    assert len(via_precomputed) == len(fresh)
    for a, b in zip(via_precomputed, fresh):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sort_lex_packed_engine_matches_lanes():
    """The ops.sort_lex routing knob: an exact small-range tuple sorts
    bit-identically through the packed rank-key engine."""
    rng = np.random.default_rng(_seed("sort-packed"))
    lanes = [jnp.asarray(rng.integers(0, 13, (3, 40)).astype(np.int32)),
             jnp.asarray(rng.integers(0, 200, (3, 40)).astype(np.uint32)),
             jnp.asarray(rng.integers(0, 100, (3, 40)).astype(np.uint32))]
    mv = (12, 255, 127)
    from repro.kernels import choose_lex_engine
    assert choose_lex_engine([a.dtype for a in lanes], mv) == "packed"
    got = sort_lex(lanes, engine="packed", max_values=mv)
    want = sort_lex(lanes, engine="lanes")
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_sort_lex_packed_engine_overflow_falls_back():
    """>2-lane full-width tuples exceed the 2xu32 budget: the packed engine
    must fall back to the lane-wise path (never sort on a lossy key) and
    stay bit-identical."""
    rng = np.random.default_rng(_seed("sort-fallback"))
    lanes = [jnp.asarray(rng.integers(0, 2**32, (2, 33)).astype(np.uint32))
             for _ in range(3)]
    from repro.kernels import choose_lex_engine
    assert choose_lex_engine([a.dtype for a in lanes],
                             engine="packed") == "lanes"
    got = sort_lex(lanes, engine="packed")
    want = sort_lex(lanes, engine="lanes")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_sort_lex_float_lane_routing():
    """Float lanes route through the packed engine now (the order-bit
    transform is total, so packed keys rank floats exactly; the sort
    gathers the original lanes through the permutation to conserve NaN
    payload bits). Routing rules pinned: packed only ever runs where it
    *shrinks* the compare list, and an explicit request is honored."""
    from repro.kernels import choose_lex_engine
    # 2 full-width lanes pack to 2 lanes — no shrink, auto stays lanes
    assert choose_lex_engine([jnp.float32, jnp.uint32]) == "lanes"
    # ... but an explicit packed request on a float tuple is honored
    assert choose_lex_engine([jnp.float32], engine="packed") == "packed"
    # 64 bits across 3 lanes: packed shrinks the list, auto takes it
    assert choose_lex_engine([jnp.float32, jnp.int16, jnp.int16]) == "packed"


def test_sort_lex_packed_float_conserves_nan_bits():
    """The packed float path must return the *original* lanes (gathered
    through the packed permutation), never an unpack — distinct NaN
    payloads and -0.0 signs survive bit-for-bit while the order is the
    canonical total order (NaNs above +inf, sentinel pattern maximal)."""
    pats = np.array([0x7FC00001, 0xFFC00000, 0x7F800001, 0xFFFFFFFF],
                    np.uint32).view(np.float32)
    x = np.concatenate([np.array([1.5, -2.0, np.inf, -np.inf, -0.0, 0.0],
                                 np.float32), pats])
    rng = np.random.default_rng(_seed("packed-float-nan"))
    x = x[rng.permutation(x.size)]
    (out,) = sort_lex((jnp.asarray(x),), engine="packed")
    out = np.asarray(out)
    assert (sorted(out.view(np.uint32).tolist())
            == sorted(x.view(np.uint32).tolist()))
    want = sorted(range(x.size),
                  key=lambda i: int(np.asarray(kp.bias_to_u32(
                      jnp.asarray(x[i:i + 1])))[0]))
    np.testing.assert_array_equal(out.view(np.uint32),
                                  x[want].view(np.uint32))


def test_bias_nan_canonical_order():
    """The NaN slots of the canonical transform: every NaN above +inf, the
    all-ones (padding sentinel) pattern strictly above the rest, all other
    payloads collapsed to one slot, and -0.0 == +0.0."""
    vals = np.array([0x7F800000,    # +inf
                     0x7FC00000,    # quiet NaN
                     0x7F800001,    # signalling NaN
                     0xFFC00000,    # negative quiet NaN
                     0xFFFFFFFF],   # all-ones: the float padding sentinel
                    np.uint32).view(np.float32)
    b = np.asarray(kp.bias_to_u32(jnp.asarray(vals)))
    assert (b[1:] > b[0]).all(), "every NaN must sit above +inf"
    assert b[1] == b[2] == b[3], "non-sentinel NaN payloads share one slot"
    assert b[4] == np.uint32(0xFFFFFFFF) and (b[4] > b[1:4]).all(), \
        "the sentinel pattern owns the strict maximum"
    zb = np.asarray(kp.bias_to_u32(jnp.asarray(
        np.array([-0.0, 0.0], np.float32))))
    assert zb[0] == zb[1], "-0.0 and +0.0 must share order bits"


# ---------------------------------------------------------------------------
# hypothesis sweep (slow tier)
# ---------------------------------------------------------------------------

# equal inner lengths are enforced inside the test (truncate to the min):
# the hypothesis-compat shim cannot express .filter at module scope
lane_lists = st.lists(
    st.lists(st.integers(min_value=-(2**31), max_value=2**31 - 1),
             min_size=1, max_size=64),
    min_size=1, max_size=3)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(lane_lists, lane_lists)
def test_packed_rank_property(a_ls, b_ls):
    """Random int32 tuples (any arity 1-3, dup-heavy by construction):
    packed ranks equal the broadcast oracle and the packed merge is
    bit-identical to the lane-wise one."""
    arity = min(len(a_ls), len(b_ls))
    na = min(len(l) for l in a_ls[:arity])
    nb = min(len(l) for l in b_ls[:arity])
    A = _sorted_lanes([jnp.asarray(np.asarray(l[:na], np.int32))
                       for l in a_ls[:arity]])
    B = _sorted_lanes([jnp.asarray(np.asarray(l[:nb], np.int32))
                       for l in b_ls[:arity]])
    for side, strict in [("left", True), ("right", False)]:
        got = kp.packed_searchsorted(A, B, side=side)
        want = lex_rank_count(A, B, strict=strict)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got = kp.merge_take_packed(A, B)
    want = lex_merge_take(A, B)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
