"""Import hypothesis if available; otherwise provide stand-ins that collect
the property tests as *skipped* while letting the rest of the module's tests
run (a module-level importorskip would silently drop those too)."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Accepts any strategy construction; the result is never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
