"""Launch-layer machinery testable without 512 devices: input specs,
HLO collective parsing, roofline arithmetic, accum/param accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.launch.dryrun import (
    TRAIN_ACCUM, parse_collectives, roofline_terms, _shape_bytes,
)
from repro.launch.specs import count_params
from repro.launch import hw


def test_shape_bytes_parser():
    assert _shape_bytes("bf16[128,1024]{1,0}") == 128 * 1024 * 2
    assert _shape_bytes("f32[16]{0}") == 64
    assert _shape_bytes("(bf16[8,8]{1,0}, f32[4]{0})") == 128 + 16
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_counts_ops():
    hlo = """
  %ag = bf16[64,512]{1,0} all-gather(bf16[4,512]{1,0} %x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%sum
  %cp = f32[32]{0} collective-permute(f32[32]{0} %z), source_target_pairs={{0,1}}
  %a2a = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(bf16[8,8]{1,0} %a, bf16[8,8]{1,0} %b)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 64 * 512 * 2
    assert out["all-reduce"]["bytes"] == 4096
    assert out["collective-permute"]["count"] == 1
    assert out["all-to-all"]["bytes"] == 2 * 64 * 2


def test_roofline_terms_math():
    coll = {"all-reduce": {"count": 1, "bytes": hw.ICI_BW}}  # 1s at 2x mult
    t = roofline_terms(hw.PEAK_FLOPS_BF16, hw.HBM_BW, coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["bottleneck"] == "collective_s"


def test_count_params_llama405():
    total, active = count_params(get_config("llama3-405b"))
    assert 3.9e11 < total < 4.2e11       # ~405B
    assert total == active               # dense


def test_count_params_moe_active_fraction():
    total, active = count_params(get_config("granite-moe-1b-a400m"))
    assert 1.2e9 < total < 1.5e9         # ~1.3B total
    assert 3.5e8 < active < 5.5e8        # ~400M active
    t2, a2 = count_params(get_config("deepseek-v2-236b"))
    assert 2.0e11 < t2 < 2.6e11          # ~236B total
    assert 1.5e10 < a2 < 3.0e10          # ~21B active


def test_cell_coverage_is_32():
    cells = sum(len(cells_for(get_config(a))) for a in ARCH_IDS)
    assert cells == 32                   # 10x3 + 2 long_500k (ssm/hybrid)


def test_accum_configured_for_big_models():
    assert TRAIN_ACCUM["llama3-405b"] >= 16
    assert TRAIN_ACCUM["nemotron-4-340b"] >= 16


def test_mesh_factories():
    from repro.launch.mesh import make_elastic_mesh, make_test_mesh
    m = make_test_mesh()
    assert set(m.axis_names) == {"data", "model"}
    e = make_elastic_mesh(1, model_parallel=4)
    assert e.size == 1
