"""End-to-end elastic recovery across a REAL mesh shrink (8 host devices in
a subprocess): train sharded on a (4,2) mesh, checkpoint, lose half the
devices, rebuild a (2,2) mesh from the survivors, reshard-restore from the
snapshot, and keep training. This is the control flow a 1000-node deployment
runs on node failure; only the failure detector differs."""

import os
import subprocess
import sys

_ELASTIC_SCRIPT = r"""
import tempfile
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.parallel.compat import AxisType, mesh_from_devices, set_mesh
from repro.configs import get_smoke_config
from repro.data import TokenStream
from repro.models.model import init_lm
from repro.models.param import tree_specs
from repro.optim import init_opt_state
from repro.parallel.sharding import Rules
from repro.training import Hyper, make_train_step

rules = Rules()
cfg = get_smoke_config("glm4-9b")
hyper = Hyper(lr=1e-3, warmup=2, total_steps=40)
step_fn_raw = make_train_step(cfg, rules, hyper)


def shardings_for(tree, axes, mesh):
    specs = tree_specs(axes, rules, mesh, tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def place(tree, axes, mesh):
    return jax.tree.map(jax.device_put, tree, shardings_for(tree, axes, mesh))


def mk_mesh(devs, shape):
    return mesh_from_devices(np.array(devs).reshape(shape), ("data", "model"),
                             axis_types=(AxisType.Auto, AxisType.Auto))


devs = jax.devices()
mesh_a = mk_mesh(devs[:8], (4, 2))
mesh_b = mk_mesh(devs[:4], (2, 2))   # the survivors after "losing" 4 devices

params, axes = init_lm(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
params = place(params, axes, mesh_a)
from repro.optim import opt_state_axes
o_axes = opt_state_axes(axes)
opt = place(opt, o_axes, mesh_a)

data = TokenStream(cfg.vocab_size, 8, 16, seed=0)
losses = []
ckpt_dir = tempfile.mkdtemp()
mgr = CheckpointManager(ckpt_dir, keep=2, async_save=False)

step_fn = jax.jit(step_fn_raw)
with set_mesh(mesh_a):
    for step in range(6):
        batch = jax.tree.map(jnp.asarray, next(data))
        params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
        losses.append(float(m["loss"]))
    mgr.save(6, {"params": params, "opt": opt})

# ---- simulated failure: half the pod is gone; rebuild on mesh_b ----
target = {"params": jax.tree.map(lambda x: x, params),
          "opt": jax.tree.map(lambda x: x, opt)}
shards_b = {"params": shardings_for(params, axes, mesh_b),
            "opt": shardings_for(opt, o_axes, mesh_b)}
step0, state = mgr.restore_latest(target, shards_b)
assert step0 == 6
params_b, opt_b = state["params"], state["opt"]
# every restored leaf lives on the shrunken mesh
for leaf in jax.tree.leaves(params_b):
    assert set(leaf.sharding.device_set) <= set(devs[:4])

step_fn_b = jax.jit(step_fn_raw)
with set_mesh(mesh_b):
    for step in range(step0, step0 + 6):
        batch = jax.tree.map(jnp.asarray, next(data))
        params_b, opt_b, m = step_fn_b(params_b, opt_b, batch, jnp.int32(step))
        losses.append(float(m["loss"]))

assert all(np.isfinite(losses)), losses
# Training continued after the shrink: random-token LM loss hovers at the
# unigram entropy (~log vocab), so descent is noise at this step count —
# assert continuity instead (a broken reshard-restore shows up as a jump).
pre, post = losses[:6], losses[6:]
assert abs(float(np.mean(post)) - float(np.mean(pre))) < 0.5, losses
print("ELASTIC_OK", [round(l, 3) for l in losses])
"""


def test_elastic_mesh_shrink_end_to_end():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ELASTIC_OK" in out.stdout
