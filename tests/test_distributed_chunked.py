"""Out-of-core distributed chunked sort (``distributed_chunked_sort_lex``):
chunk-per-device ingest -> one exact-count run exchange -> one-launch
streaming k-way combine per destination. The mesh-scale cases ride the
8-fake-device subprocess pattern of ``test_distributed_sort.py`` /
``test_sortfault.py``; every output is held bit-identical to the
single-process pipeline and the NumPy shortlex oracle.

Sizes stay small (~500 words, per-device chunks of 64): every chunk
compiles an interpret-mode Pallas program on this CPU container.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import distributed_chunked_sort_lex
from repro.core.packing import pack_words, unpack_words
from repro.pipeline import chunked_sort_packed


def _run_multidev(script, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import distributed_chunked_sort_lex
from repro.core.packing import pack_words, unpack_words

assert len(jax.devices()) == 8
rng = np.random.default_rng(0)
alpha = list("abcdefgh")
words = ["".join(rng.choice(alpha, l)) for l in rng.integers(0, 9, 509)]
keys = np.asarray(pack_words(words))

def assert_runs_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
    np.testing.assert_array_equal(np.asarray(a.lengths),
                                  np.asarray(b.lengths))
"""


def test_distributed_chunked_bit_identical_to_oracle():
    """509 words over 8 devices: every per-device chunk holds at most 64
    rows, so the input is larger than any single chunk capacity — and the
    global result must equal both the single-process chunked pipeline and
    the NumPy shortlex oracle bit-for-bit, with ``validate='full'`` green."""
    out = _run_multidev(_COMMON + """
from repro.pipeline import chunked_sort_packed

run = distributed_chunked_sort_lex(keys, validate="full")
assert int(run.keys.shape[0]) == 509
oracle = chunked_sort_packed(jnp.asarray(keys), chunk_size=64)
assert_runs_equal(run, oracle)
shortlex = sorted(words, key=lambda w: (len(w.encode()), w.encode()))
assert unpack_words(np.asarray(run.keys)) == shortlex
print("DIST_CHUNKED_OK")
""")
    assert "DIST_CHUNKED_OK" in out


def test_exchange_and_combine_failures_recover_bit_identical():
    """Injected ``StageFailure`` mid run-exchange and mid streaming-combine:
    both stages are pure functions of their input runs, so supervised retry
    must recover output bit-identical to the no-failure run."""
    out = _run_multidev(_COMMON + """
from repro.runtime import RetryPolicy, SortSupervisor, StageFailureInjector

oracle = distributed_chunked_sort_lex(keys)
inj = StageFailureInjector(fail_at={"run_exchange": {0},
                                    "streaming_combine": {0, 2}})
sup = SortSupervisor(policy=RetryPolicy(max_retries=3), injector=inj)
run = distributed_chunked_sort_lex(keys, supervisor=sup, validate="full")
assert_runs_equal(run, oracle)
assert ("run_exchange", 0, "transient") in inj.fired
assert ("streaming_combine", 0, "transient") in inj.fired
assert [e.action for e in sup.events] == ["retry"] * 3
print("FAULTS_OK")
""")
    assert "FAULTS_OK" in out


def test_overflow_policies_raise_retry_clip():
    """Destination-capacity overflow paths: 'raise' reports the required
    size, 'retry' doubles capacity (and sample density) until lossless even
    under unsplittable total skew, 'clip' keeps each destination's capacity
    smallest elements and stays sorted."""
    out = _run_multidev(_COMMON + """
from repro.runtime import CapacityOverflow

try:
    distributed_chunked_sort_lex(keys, capacity=30, on_overflow="raise")
    raise SystemExit("expected CapacityOverflow")
except CapacityOverflow as e:
    assert e.capacity == 30 and e.required > 30

# unsplittable skew: one word repeated — every splitter equal, one
# destination takes everything; retry must still terminate (capacity
# doubling is bounded by n) and come back lossless
dup = np.asarray(pack_words(["abc"] * 400))
oracle = distributed_chunked_sort_lex(dup)
run = distributed_chunked_sort_lex(dup, capacity=80, on_overflow="retry",
                                   validate="full")
assert_runs_equal(run, oracle)

clip = distributed_chunked_sort_lex(dup, capacity=30, on_overflow="clip",
                                    validate="cheap")
assert int(clip.keys.shape[0]) == 30
assert np.all(np.diff(np.asarray(clip.lengths)) >= 0)
print("OVERFLOW_OK")
""")
    assert "OVERFLOW_OK" in out


def test_store_resume_skips_completed_runs():
    """PR 6's manifests survive the distributed path: a job killed mid
    ingest resumes from its persisted per-device runs (only the missing
    chunks launch), and a fully-persisted store resumes with zero
    launches — output bit-identical throughout."""
    out = _run_multidev(_COMMON + """
import tempfile
from unittest import mock
import repro.pipeline.ingest as ingest_mod
from repro.pipeline import RunStore
from repro.runtime import (RetryPolicy, SortSupervisor, StageFailure,
                           StageFailureInjector)

oracle = distributed_chunked_sort_lex(keys)
td = tempfile.mkdtemp()
store = RunStore(td)
inj = StageFailureInjector(fail_at={"ingest_chunk": {2, 3, 4}})
sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj)
try:
    distributed_chunked_sort_lex(keys, store=store, supervisor=sup)
    raise SystemExit("expected StageFailure")
except StageFailure:
    pass
assert store.completed() == [0, 1]

launches = []
real = ingest_mod.sorted_run
with mock.patch.object(ingest_mod, "sorted_run",
                       lambda k, **kw: launches.append(1) or real(k, **kw)):
    run = distributed_chunked_sort_lex(keys, store=store, validate="full")
assert_runs_equal(run, oracle)
assert len(launches) == 6  # chunks 0-1 loaded, 2-7 launched
assert store.completed() == list(range(8))

with mock.patch.object(ingest_mod, "sorted_run",
                       lambda k, **kw: launches.append(1) or real(k, **kw)):
    run2 = distributed_chunked_sort_lex(keys, store=store, validate="full")
assert_runs_equal(run2, oracle)
assert len(launches) == 6  # pure load, zero new launches
print("RESUME_OK")
""")
    assert "RESUME_OK" in out


# ---------------------------------------------------------------------------
# in-process degenerate cases (one local device)
# ---------------------------------------------------------------------------

def _words(n, seed, max_len=8):
    rng = np.random.default_rng(seed)
    alpha = list("abcdefgh")
    return ["".join(rng.choice(alpha, l))
            for l in rng.integers(0, max_len + 1, n)]


def test_single_device_degenerate_equals_pipeline():
    words = _words(150, 1)
    keys = np.asarray(pack_words(words))
    run = distributed_chunked_sort_lex(keys, devices=[jax.devices()[0]],
                                       validate="full")
    oracle = chunked_sort_packed(jnp.asarray(keys), chunk_size=150)
    np.testing.assert_array_equal(np.asarray(run.keys),
                                  np.asarray(oracle.keys))
    np.testing.assert_array_equal(np.asarray(run.lengths),
                                  np.asarray(oracle.lengths))


def test_empty_input_and_bad_args():
    empty = np.zeros((0, 2), np.uint32)
    run = distributed_chunked_sort_lex(empty)
    assert run.keys.shape[0] == 0 and run.lengths.shape[0] == 0
    keys = np.asarray(pack_words(_words(20, 2)))
    with pytest.raises(ValueError, match="validate"):
        distributed_chunked_sort_lex(keys, validate="bogus")
    with pytest.raises(ValueError, match="on_overflow"):
        distributed_chunked_sort_lex(keys, on_overflow="bogus")


def test_kill_between_exchange_and_combine_resumes_shard_granular():
    """A job killed mid streaming-combine (after the exchange, two
    destinations landed) must resume with ZERO ingest launches — every
    per-device run reloads from the run store and the exchange replays as a
    pure function of them — and re-merge only the destinations whose shards
    never landed. A second resume over the fully landed stores merges
    nothing at all. Output bit-identical throughout."""
    out = _run_multidev(_COMMON + """
import tempfile
from unittest import mock
import repro.pipeline.ingest as ingest_mod
import repro.pipeline.merge as merge_mod
from repro.pipeline import RunStore, ShardStore
from repro.runtime import (ProcessKilled, RetryPolicy, SortSupervisor,
                           StageFailureInjector)

oracle = distributed_chunked_sort_lex(keys)
run_store = RunStore(tempfile.mkdtemp())
shard_store = ShardStore(tempfile.mkdtemp())

inj = StageFailureInjector(kill_at={"streaming_combine": {2}})
sup = SortSupervisor(policy=RetryPolicy(max_retries=2), injector=inj)
try:
    distributed_chunked_sort_lex(keys, store=run_store,
                                 shard_store=shard_store, supervisor=sup)
    raise SystemExit("expected ProcessKilled")
except ProcessKilled as e:
    assert e.stage == "streaming_combine"
assert run_store.completed() == list(range(8))   # ingest fully landed
assert shard_store.completed() == [0, 1]         # killed during dest 2

launches, real_ingest = [], ingest_mod.sorted_run
real_merge = merge_mod.merge_runs
with mock.patch.object(ingest_mod, "sorted_run",
                       lambda k, **kw: launches.append(1)
                       or real_ingest(k, **kw)), \
     mock.patch.object(merge_mod, "merge_runs",
                       side_effect=real_merge) as merges:
    res = distributed_chunked_sort_lex(keys, store=run_store,
                                       shard_store=shard_store,
                                       validate="full")
assert len(launches) == 0       # exchange replayed from reloaded runs
assert merges.call_count == 6   # only destinations 2-7 re-merged
assert shard_store.completed() == list(range(8))
assert_runs_equal(res.to_run(validate="full"), oracle)

with mock.patch.object(merge_mod, "merge_runs",
                       side_effect=real_merge) as merges2:
    res2 = distributed_chunked_sort_lex(keys, store=run_store,
                                        shard_store=shard_store,
                                        validate="full")
assert merges2.call_count == 0  # double resume: pure shard reload
assert_runs_equal(res2.to_run(), oracle)
print("KILL_RESUME_OK")
""")
    assert "KILL_RESUME_OK" in out


def test_mesh_shard_spill_bit_identical():
    """8-device spill mode (``gather=False``): the sharded result's
    materialisation equals the gathered oracle bit-for-bit, with one shard
    per destination and the full metadata gate green."""
    out = _run_multidev(_COMMON + """
import tempfile
from repro.pipeline import ShardedRun, ShardStore

oracle = distributed_chunked_sort_lex(keys, validate="full")
sharded = distributed_chunked_sort_lex(
    keys, shard_store=ShardStore(tempfile.mkdtemp()), validate="full")
assert isinstance(sharded, ShardedRun)
assert len(sharded.manifests) == 8
assert sharded.count == 509
assert_runs_equal(sharded.to_run(validate="full"), oracle)
print("SPILL_MESH_OK")
""")
    assert "SPILL_MESH_OK" in out


def test_mesh_speculative_combine_bit_identical():
    """Speculative re-execution on the mesh: a straggling combine
    destination (injected fire-once slowness) gets a backup replica; the
    digest-confirmed winner keeps the output bit-identical."""
    out = _run_multidev(_COMMON + """
from repro.runtime import (SortSupervisor, SpeculationPolicy,
                           StageFailureInjector, StragglerMonitor)

oracle = distributed_chunked_sort_lex(keys)
mon = StragglerMonitor(warmup=3, min_ratio=3.0)
inj = StageFailureInjector(slow_at={"streaming_combine": {5: 2.0}})
sup = SortSupervisor(
    injector=inj,
    speculation=SpeculationPolicy(monitor=mon, min_wait=0.05))
run = distributed_chunked_sort_lex(keys, supervisor=sup, validate="full")
assert_runs_equal(run, oracle)
assert ("streaming_combine", 5, "slow") in inj.fired
actions = [e.action for e in sup.events]
assert "speculate" in actions, actions
assert "speculation_confirmed" in actions, actions
print("SPECULATE_OK")
""")
    assert "SPECULATE_OK" in out
