"""Sharding rules, compression math, and (in a subprocess with 8 host
devices) the distributed sort / ring collectives / pipeline."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.parallel.compression import dequantize_int8, ef_compress, ef_init, quantize_int8
from repro.parallel.sharding import DEFAULT_RULES, Rules


def test_shape_spec_drops_nondivisible():
    r = Rules()
    sizes = {"data": 16, "model": 16, "pod": 2}
    # 8 kv heads cannot shard over 16-way model -> replicated
    spec = r.shape_spec(("embed", "kv_heads", None), (1024, 8, 64), sizes)
    assert tuple(spec) == ("data", None, None)
    # divisible case keeps the axis
    spec = r.shape_spec(("embed", "heads", None), (1024, 32, 64), sizes)
    assert tuple(spec) == ("data", "model", None)


def test_shape_spec_tuple_prefix():
    r = Rules()
    sizes = {"data": 16, "model": 16, "pod": 2}
    # batch 8: divisible by pod(2) but not pod*data(32) -> keep prefix ('pod',)
    spec = r.shape_spec(("batch", "seq"), (8, 128), sizes)
    assert spec[0] == ("pod",) or spec[0] == "pod"
    # batch 64: full ('pod','data')
    spec = r.shape_spec(("batch", "seq"), (64, 128), sizes)
    assert tuple(spec[0]) == ("pod", "data")


def test_rules_override():
    r = Rules().override(cache_seq="model")
    assert r.table["cache_seq"] == "model"
    assert DEFAULT_RULES["cache_seq"] is None  # original untouched


def test_mesh_spec_filters_missing_axes():
    r = Rules()
    spec = r.mesh_spec(("batch", "seq", "act_heads"), ("data",))
    # PartitionSpec normalizes the 1-tuple ('data',) to 'data'
    assert tuple(spec) == ("data", None, None)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1, max_size=64))
def test_quantize_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert (err <= float(s) / 2 + 1e-6).all()


def test_error_feedback_unbiased_over_steps():
    """With EF, the *cumulative* compressed signal tracks the true signal."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    res = ef_init(g)
    sent_total = np.zeros(32, np.float32)
    for _ in range(50):
        comp, res = ef_compress(g, res)
        sent_total += np.asarray(dequantize_int8(*comp["w"]))
    np.testing.assert_allclose(sent_total / 50, np.asarray(g["w"]), atol=1e-3)


_MULTIDEV_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import PartitionSpec as P
from repro.core.distributed import distributed_sort, odd_even_block_sort
from repro.parallel.compat import AxisType, make_mesh, shard_map
from repro.parallel.ring import ring_all_reduce
from repro.parallel.pipeline import pipeline_forward
from repro.parallel.compression import compressed_psum

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)

# distributed odd-even block sort == global sort, all merge strategies
# (engine pinned: 'auto' routes P=8 to sample, covered in
# tests/test_distributed_sort.py)
x = jnp.asarray(rng.integers(0, 10**6, 8 * 128), dtype=jnp.int32)
for merge in ("resort", "bitonic", "take"):
    out = distributed_sort(x, mesh, axis="d", engine="odd_even", merge=merge)
    assert (out == jnp.sort(x)).all(), merge

# duplicate-heavy input, both the pinned engine and the auto cost model
xd = jnp.asarray(rng.integers(0, 5, 8 * 64), dtype=jnp.int32)
assert (distributed_sort(xd, mesh, axis="d", engine="odd_even",
                         merge="bitonic") == jnp.sort(xd)).all()
assert (distributed_sort(xd, mesh, axis="d") == jnp.sort(xd)).all()

# ring all-reduce == psum
y = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
f = jax.jit(shard_map(lambda v: ring_all_reduce(v, "d"),
                          mesh=mesh, in_specs=P("d"), out_specs=P("d")))
assert np.allclose(np.asarray(f(y)), np.tile(np.asarray(y).sum(0), (8, 1)), atol=1e-4)

# pipeline: 8 stages of (x @ W_i) == sequential composition
ws = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32) * 0.5)
mbs = jnp.asarray(rng.normal(size=(5, 3, 4)).astype(np.float32))
def stage(w, x):
    return jnp.tanh(x @ w)
pf = jax.jit(shard_map(
    lambda w, xs: pipeline_forward(lambda wi, x: stage(wi[0], x), w, xs, "d")[None],
    mesh=mesh, in_specs=(P("d"), P()), out_specs=P("d")))
outs = pf(ws, mbs)[-1]  # outputs land on the last stage
ref = mbs
for i in range(8):
    ref = jnp.tanh(ref @ ws[i])
assert np.allclose(np.asarray(outs), np.asarray(ref), atol=1e-5), "pipeline"

# compressed psum close to true mean
def body(v, r):
    return compressed_psum(v, "d", r)
h = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("d"), P("d")), out_specs=(P("d"), P("d"))))
m, _ = h(y, jnp.zeros_like(y))
true = np.tile(np.asarray(y).mean(0), (8, 1))
assert np.abs(np.asarray(m) - true).max() < 0.05
print("MULTIDEV_OK")
"""


def test_multidevice_suite():
    """Distributed sort / ring / pipeline / compression on 8 host devices
    (subprocess so the 8-device XLA flag cannot leak into other tests)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIDEV_OK" in out.stdout


_SAMPLESORT_SCRIPT = r"""
import functools
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.distributed import sample_sort
from repro.parallel.compat import AxisType, make_mesh, shard_map

mesh = make_mesh((8,), ("d",), axis_types=(AxisType.Auto,))
rng = np.random.default_rng(0)
def body(blk):
    vals, count = sample_sort(blk, axis_name="d")
    return vals, count[None]
for n_per, seed in ((64, 0), (128, 1), (32, 2)):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 10**6, 8 * n_per), dtype=jnp.int32)
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                               out_specs=(P("d"), P("d"))))
    vals, counts = fn(x)
    vals_np = np.asarray(vals).reshape(8, -1)
    counts_np = np.asarray(counts).reshape(8)
    got = np.concatenate([vals_np[i, :counts_np[i]] for i in range(8)])
    want = np.sort(np.asarray(x))
    assert got.shape == want.shape, (got.shape, want.shape)
    assert (got == want).all()
print("SAMPLESORT_OK")
"""


def test_sample_sort_multidevice():
    """Splitter-based distributed sort == global sort (8 host devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _SAMPLESORT_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SAMPLESORT_OK" in out.stdout
