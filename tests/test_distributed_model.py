"""Distributed model execution == single-device execution (8 host devices):
the full train step and the decode step run under a real (data, model) mesh
with the production sharding rules and must match the unsharded results."""

import os
import subprocess
import sys

_DIST_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.parallel.compat import AxisType, make_mesh, set_mesh
from repro.models.model import decode_step, forward, init_cache, init_lm
from repro.models.param import tree_specs
from repro.parallel.sharding import Rules

rules = Rules()
cfg = get_smoke_config("glm4-9b")
params, axes = init_lm(cfg, jax.random.PRNGKey(0))
B, S = 8, 16
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

# single-device references
ref_logits, _, _ = forward(cfg, params, {"tokens": tokens}, rules)
cache0, _ = init_cache(cfg, B, S)
ref_dec, _ = decode_step(cfg, params, cache0, tokens[:, :1], jnp.int32(0), rules)

# (4, 2) mesh with production rules
mesh = make_mesh((4, 2), ("data", "model"),
                 axis_types=(AxisType.Auto, AxisType.Auto))
p_specs = tree_specs(axes, rules, mesh, params)
p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                       is_leaf=lambda x: isinstance(x, P))
params_d = jax.tree.map(jax.device_put, params, p_shard)

with set_mesh(mesh):
    fwd = jax.jit(lambda p, t: forward(cfg, p, {"tokens": t}, rules)[0])
    got = fwd(params_d, tokens)
err = float(jnp.max(jnp.abs(got - ref_logits)))
assert err < 2e-3, ("forward", err)

cache1, c_axes = init_cache(cfg, B, S)
c_specs = tree_specs(c_axes, rules, mesh, cache1)
c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                       is_leaf=lambda x: isinstance(x, P))
cache_d = jax.tree.map(jax.device_put, cache1, c_shard)
with set_mesh(mesh):
    dec = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i, rules))
    got_dec, new_cache = dec(params_d, cache_d, tokens[:, :1], jnp.int32(0))
err_d = float(jnp.max(jnp.abs(got_dec - ref_dec)))
assert err_d < 2e-3, ("decode", err_d)
print("DISTMODEL_OK", err, err_d)
"""


def test_distributed_model_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DISTMODEL_OK" in out.stdout
