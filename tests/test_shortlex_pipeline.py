"""End-to-end shortlex pipeline: ``bucketed_sort_words`` through the fused
Pallas segmented path (the paper's distribute -> parallel in-bucket sort ->
concatenate, fully on-device).

Acceptance pin for the lex engine: buckets whose words pack to MORE than one
uint32 lane (> 4 chars) must run through the Pallas lexicographic kernels —
``sort_buckets(algorithm='pallas')`` no longer falls back to ``lax.sort``
for multi-lane keys — and the concatenated output must be exact shortlex
(length-major, then byte-wise alphabetic) order.
"""

from unittest import mock

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import bucketed_sort_words, bucketize_words, sort_buckets
from repro.kernels import segmented_sort


def _shortlex(words):
    return sorted(words, key=lambda w: (len(w.encode()), w.encode()))


def test_multilane_words_sort_shortlex_via_pallas():
    """>4-char words (2-3 uint32 lanes) through algorithm='pallas'."""
    words = ["bananas", "apple", "cherry", "banana", "apples", "dates",
             "cherries", "avocado", "fig", "figs", "grapefruit", "apple"]
    b = bucketize_words(words)
    assert b.keys.shape[-1] > 1  # really multi-lane
    got = bucketed_sort_words(words, algorithm="pallas")
    assert got == _shortlex(words)


def test_pallas_path_never_calls_lax_sort():
    """The 'pallas' bucket path must stay on the Pallas lex engine: patching
    out jax.lax.sort proves no XLA-sort fallback runs for multi-lane keys."""
    words = ["serpent", "sorbet", "sierra", "samba", "sonata", "sunset"]
    b = bucketize_words(words)
    assert b.keys.shape[-1] > 1
    with mock.patch("jax.lax.sort",
                    side_effect=AssertionError("lax.sort fallback used")):
        sorted_keys = sort_buckets(jnp.asarray(b.keys), "pallas",
                                   counts=jnp.asarray(b.counts))
    ref = np.asarray(sort_buckets(jnp.asarray(b.keys), "oets"))
    np.testing.assert_array_equal(np.asarray(sorted_keys), ref)


def test_lane_boundary_lengths():
    """Lengths straddling the 4/8/16-char lane boundaries, duplicates, and
    the empty string, in one pipeline pass."""
    words = ["", "abcd", "abcde", "abcdefgh", "abcdefghi", "abcd", "",
             "abcdefghijklmnop", "abcdefghijklmnopq", "zzzz", "aaaa",
             "abcdefg", "abcdefgz", "a"]
    got = bucketed_sort_words(words, algorithm="pallas")
    assert got == _shortlex(words)


def test_segmented_sort_matches_per_bucket_oracle():
    """segmented_sort == per-bucket tuple sort, with count masking: slots at
    index >= count must come back as pure sentinel rows."""
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 6, (5, 40, 3), dtype=np.int64).astype(np.uint32)
    counts = np.array([40, 0, 7, 23, 40], np.int32)
    out = np.asarray(segmented_sort(jnp.asarray(keys), jnp.asarray(counts)))
    for b, c in enumerate(counts):
        want = sorted(tuple(t) for t in keys[b, :c])
        assert [tuple(t) for t in out[b, :c]] == want
        assert (out[b, c:] == np.iinfo(np.uint32).max).all()


def test_empty_and_single_word():
    assert bucketed_sort_words([], algorithm="pallas") == []
    assert bucketed_sort_words(["only"], algorithm="pallas") == ["only"]


words_strategy = st.lists(
    st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
            min_size=0, max_size=18),
    min_size=0, max_size=40)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(words_strategy)
def test_shortlex_roundtrip_property(ws):
    """Round-trip random word lists (empty strings, duplicates, lengths
    straddling the 4/8/16-char lane boundaries) against the python oracle
    sorted(words, key=lambda w: (len(w), w))."""
    ws = [w.encode()[:18].decode(errors="ignore").replace("\x00", "")
          for w in ws]
    got = bucketed_sort_words(ws, algorithm="pallas")
    assert got == _shortlex(ws)
