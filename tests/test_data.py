"""Data pipeline: synthetic corpus, length bucketing, loader."""

import numpy as np

from repro.data import (
    LengthBucketedBatcher, ShardedLoader, TokenStream,
    clean_text, plan_buckets, synthetic_words, words_from_text,
)


def test_synthetic_words_deterministic_and_lengthy():
    w1 = synthetic_words(500, seed=7)
    w2 = synthetic_words(500, seed=7)
    assert w1 == w2
    lens = [len(w) for w in w1]
    assert min(lens) >= 1 and max(lens) <= 15
    assert len(set(lens)) > 5  # real spread of bucket sizes


def test_clean_text_phase():
    assert words_from_text("To be, or not to be?!") == ["to", "be", "or", "not", "to", "be"]
    assert "," not in clean_text("a,b")


def test_plan_buckets_covers_all():
    lens = list(np.random.default_rng(0).integers(1, 100, 1000))
    bounds = plan_buckets(lens, 8)
    assert bounds[-1] >= max(lens)
    assert bounds == sorted(bounds)


def test_plan_buckets_empty_input_plans_nothing():
    """Regression: [] used to IndexError on the quantile index; an empty
    wave plans no buckets."""
    assert plan_buckets([]) == []
    assert plan_buckets([], n_buckets=4) == []


def test_plan_buckets_single_length():
    bounds = plan_buckets([7, 7, 7], 4)
    assert bounds == [7]


def test_batcher_emits_dense_padded_batches():
    b = LengthBucketedBatcher(bounds=[4, 8, 16], batch_size=2)
    out = []
    out += b.add(0, [1, 2, 3])
    out += b.add(1, [5, 6])            # fills bucket 0 -> emits
    out += b.add(2, list(range(10)))
    assert len(out) == 1
    batch = out[0]
    assert batch["tokens"].shape == (2, 4)
    assert batch["lengths"].tolist() == [3, 2]
    rest = b.flush()
    assert len(rest) == 1 and rest[0]["tokens"].shape == (1, 16)


def test_token_stream_shards_disjoint():
    a = next(iter(TokenStream(100, 2, 8, seed=1, shard_index=0, num_shards=2)))
    b = next(iter(TokenStream(100, 2, 8, seed=1, shard_index=1, num_shards=2)))
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
    assert (a["labels"][:, -1] == -1).all()


def test_sharded_loader_prefetches_all():
    items = [{"i": np.array([k])} for k in range(10)]
    loader = ShardedLoader(iter(items), prefetch=3)
    got = [int(b["i"][0]) for b in loader]
    assert got == list(range(10))
