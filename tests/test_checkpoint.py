"""Checkpointing + elastic recovery."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.runtime import DeviceFailure, ElasticSupervisor, FailureInjector, StragglerMonitor


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (5,)).astype(np.int32))},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, jax.tree.map(lambda x: x, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left(tmp_path):
    save(str(tmp_path), 1, _tree())
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree(5)
    mgr.save(11, t)
    step, out = mgr.restore_latest(jax.tree.map(lambda x: x, t))
    assert step == 11
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))


def test_restore_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "nested": {"b": jnp.zeros((5,), jnp.int32)}}
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, bad)


def test_elastic_supervisor_recovers(tmp_path):
    """Simulated node failure mid-training: supervisor restores the last
    snapshot and continues with fewer devices."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    injector = FailureInjector(fail_at_steps=[7], failed_devices=2)
    state0 = {"x": jnp.zeros(()), "step_seen": jnp.zeros((), jnp.int32)}
    trace = []

    def run_segment(state, start, devices):
        s = state
        for step in range(start, 12):
            injector.check(step)
            s = {"x": s["x"] + 1.0, "step_seen": jnp.int32(step)}
            trace.append((step, devices))
            if (step + 1) % 3 == 0:
                mgr.save(step + 1, s)
        return s

    def remesh(devices):
        step, s = mgr.restore_latest(jax.tree.map(lambda x: x, state0))
        return (step, s) if step is not None else None

    sup = ElasticSupervisor(mgr, initial_devices=8)
    final = sup.run(run_segment, remesh, state0, 0)
    assert len(sup.events) == 1
    assert sup.events[0].devices_before == 8 and sup.events[0].devices_after == 6
    # recovery resumed from step 6 (last snapshot), not from 0
    resumed = [t for t in trace if t[1] == 6]
    assert resumed[0][0] == 6
    assert float(final["x"]) == 12.0  # 7 steps + (12-6) re-run minus overlap -> total applied


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=3.0, warmup=5)
    flagged = []
    mon.on_straggler = lambda step, d, z: flagged.append(step)
    for s in range(20):
        mon.record(s, 0.1 + 0.001 * (s % 3))
    assert mon.record(20, 5.0) is True
    assert flagged == [20]
    assert mon.record(21, 0.1) is False


# ---------------------------------------------------------------------------
# torn-write hardening (CorruptSnapshotError + tmp sweeping)
# ---------------------------------------------------------------------------

def test_truncated_npy_raises_typed_error_naming_path(tmp_path):
    """A landed .npy torn by external damage (disk fault, tampering) must
    raise CorruptSnapshotError carrying the path — not a bare numpy
    exception the resume logic can't distinguish from a bug."""
    from repro.checkpoint import CorruptSnapshotError

    t = _tree()
    save(str(tmp_path), 3, t)
    victim = os.path.join(str(tmp_path), "step_3", "a.npy")
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) // 2)
    with pytest.raises(CorruptSnapshotError) as ei:
        restore(str(tmp_path), 3, jax.tree.map(lambda x: x, t))
    assert victim in str(ei.value)
    assert ei.value.path == victim


def test_zero_length_npy_raises_typed_error(tmp_path):
    from repro.checkpoint import CorruptSnapshotError

    t = _tree()
    save(str(tmp_path), 1, t)
    victim = os.path.join(str(tmp_path), "step_1", "a.npy")
    with open(victim, "wb"):
        pass
    with pytest.raises(CorruptSnapshotError, match="zero-length"):
        restore(str(tmp_path), 1, jax.tree.map(lambda x: x, t))


def test_short_rows_vs_manifest_raises_typed_error(tmp_path):
    """A *loadable* npy holding fewer rows than the snapshot manifest
    records (rewritten by a confused writer) is torn data, not a caller
    shape mistake: CorruptSnapshotError, not ValueError."""
    from repro.checkpoint import CorruptSnapshotError

    t = _tree()
    save(str(tmp_path), 2, t)
    victim = os.path.join(str(tmp_path), "step_2", "a.npy")
    np.save(victim, np.asarray(t["a"])[:1])
    with pytest.raises(CorruptSnapshotError, match="shape"):
        restore(str(tmp_path), 2, jax.tree.map(lambda x: x, t))


def test_torn_manifest_json_raises_typed_error(tmp_path):
    from repro.checkpoint import CorruptSnapshotError, read_manifest

    save(str(tmp_path), 5, _tree())
    man = os.path.join(str(tmp_path), "step_5", "manifest.json")
    with open(man, "w") as f:
        f.write('{"step": 5, "leav')   # torn mid-write
    with pytest.raises(CorruptSnapshotError, match="manifest"):
        read_manifest(str(tmp_path), 5)


def test_sweep_tmp_removes_droppings_and_keeps_landed(tmp_path):
    from repro.checkpoint import list_steps, sweep_tmp

    save(str(tmp_path), 1, _tree())
    for n in (2, 9):
        d = os.path.join(str(tmp_path), f".tmp_{n}")
        os.makedirs(d)
        with open(os.path.join(d, "partial.npy"), "wb") as f:
            f.write(b"\x00" * 8)
    assert sweep_tmp(str(tmp_path)) == [2, 9]
    assert not any(x.startswith(".tmp") for x in os.listdir(tmp_path))
    assert list_steps(str(tmp_path)) == [1]
    assert sweep_tmp(str(tmp_path)) == []          # idempotent
    assert sweep_tmp(str(tmp_path / "missing")) == []


def test_run_store_sweeps_tmp_on_open(tmp_path):
    """A job killed mid-save leaves a .tmp_* dir; opening the store must
    sweep it so a resume only ever discovers fully landed runs."""
    from repro.pipeline import RunStore

    d = os.path.join(str(tmp_path), ".tmp_4")
    os.makedirs(d)
    store = RunStore(str(tmp_path))
    assert not os.path.exists(d)
    assert store.completed() == []
