"""Differential fuzzing: every Pallas sort engine vs the XLA oracle.

Engines under test: 'oets' / 'bitonic' / 'blocksort' (through the unified
``ops.sort``/``sort_kv`` front-end with the algorithm override) and the
variadic ``sort_lex``. Oracles: ``jnp.sort`` for single keys and
``jax.lax.sort`` (variadic, ``num_keys=L``) for lexicographic tuples.

Two tiers:
  * a small deterministic core (tier-1): the comparator-algorithm x
    lane-count lex differential over 2-D rows — the one axis
    ``tests/test_conformance.py`` does not parametrize (its sort_lex
    engines are the lanes/packed *routing* tiers on 1-D inputs). The rest
    of the former deterministic core (sort / sort_kv / 1-D lex edges)
    moved into the conformance matrix, the single tier-1 contract surface;
  * hypothesis sweeps marked ``slow`` — run with ``-m slow`` (CI's fuzz
    job); they degrade to skips when hypothesis is not installed, via the
    ``tests/_hypothesis_compat`` guards.

Shapes are drawn from a fixed palette: jit caches are shape-keyed, so
unconstrained draws would recompile the interpret-mode kernels on every
example and the sweep would never finish.
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import sort, sort_kv, sort_lex

ENGINES = ["oets", "bitonic", "blocksort"]
# blocksort gets a forced 128-lane block so small inputs still span blocks
_BLOCK = {"oets": None, "bitonic": None, "blocksort": 128}

# fixed draw palettes (see module docstring)
COLS = [1, 2, 7, 33, 128, 129, 200, 260]
ROWS = [1, 3, 8]

I32_MAX = np.iinfo(np.int32).max
U32_MAX = np.iinfo(np.uint32).max


def _seed(*parts):
    # stable across processes — hash() is PYTHONHASHSEED-randomized, which
    # would make the deterministic core draw different data every run
    return zlib.crc32("-".join(map(str, parts)).encode())


def _draw(rng, shape, dtype, flavor):
    """flavor: 'random' | 'dups' (tiny alphabet) | 'sentinel' (collides with
    the padding sentinel) | 'mixed' (all of the above)."""
    if dtype == np.float32:
        x = rng.normal(size=shape).astype(dtype)
        if flavor in ("sentinel", "mixed"):
            x[rng.random(shape) < 0.2] = np.inf
            x[rng.random(shape) < 0.1] = -np.inf
        if flavor in ("dups", "mixed"):
            x[rng.random(shape) < 0.3] = 1.5
        return x
    hi = {"dups": 4}.get(flavor, 10_000)
    x = rng.integers(0, hi, shape).astype(dtype)
    if flavor in ("sentinel", "mixed"):
        smax = U32_MAX if dtype == np.uint32 else I32_MAX
        x[rng.random(shape) < 0.2] = smax
    if dtype == np.int32 and flavor in ("random", "mixed"):
        x[rng.random(shape) < 0.2] *= -1
    return x


def _lex_oracle(lanes):
    """jax.lax.sort variadic oracle: all lanes are keys, so the sorted tuple
    sequence is unique and the comparison is exact equality."""
    rows = lanes[0].shape[0]
    outs = [np.empty_like(np.asarray(l)) for l in lanes]
    for r in range(rows):
        sorted_r = jax.lax.sort([l[r] for l in lanes], num_keys=len(lanes))
        for o, s in zip(outs, sorted_r):
            o[r] = np.asarray(s)
    return outs


# --- deterministic core (tier-1): comparator-algo x lanes over 2-D rows ------

@pytest.mark.parametrize("n_lanes", [2, 3])
@pytest.mark.parametrize("algo", ENGINES)
def test_sort_lex_vs_variadic_oracle(algo, n_lanes):
    """Multi-lane lex tuples, tiny lane-0 alphabet so deeper lanes decide.

    Widths stay small (bitonic pads to one 128-lane tile) — wide multi-lane
    networks are covered by the slow fuzz tier; interpret-mode compiles of
    the unrolled network grow superlinearly with width x lanes."""
    cols = {"oets": 40, "bitonic": 100, "blocksort": 300}[algo]
    rng = np.random.default_rng(_seed(algo, n_lanes))
    lanes = [jnp.asarray(_draw(rng, (2, cols), np.uint32,
                               "dups" if l == 0 else "sentinel"))
             for l in range(n_lanes)]
    out = sort_lex(lanes, algorithm=algo, block_size=_BLOCK[algo])
    want = _lex_oracle(lanes)
    for o, w in zip(out, want):
        np.testing.assert_array_equal(np.asarray(o), w)


# --- hypothesis sweeps (slow; skipped when hypothesis is absent) -------------

elements_i32 = st.integers(-(2**31), 2**31 - 1)
elements_dup = st.integers(0, 3)
elements_sentinel = st.sampled_from([0, 1, I32_MAX, I32_MAX - 1, -(2**31)])


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fuzz_engines_key_only(data):
    algo = data.draw(st.sampled_from(ENGINES))
    rows = data.draw(st.sampled_from(ROWS))
    cols = data.draw(st.sampled_from(COLS))
    elems = data.draw(st.sampled_from(
        [elements_i32, elements_dup, elements_sentinel]))
    xs = data.draw(st.lists(elems, min_size=rows * cols, max_size=rows * cols))
    x = jnp.asarray(np.array(xs, np.int64).astype(np.int32).reshape(rows, cols))
    out = sort(x, algorithm=algo, block_size=_BLOCK[algo])
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.sort(x, axis=-1)))


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_fuzz_engines_kv(data):
    algo = data.draw(st.sampled_from(ENGINES))
    cols = data.draw(st.sampled_from(COLS))
    ks = data.draw(st.lists(st.sampled_from([0, 1, 2, I32_MAX]),
                            min_size=cols, max_size=cols))
    k = jnp.asarray(np.array(ks, np.int32))
    v = jnp.asarray(np.arange(cols, dtype=np.int32))
    ok, ov = sort_kv(k, v, algorithm=algo, block_size=_BLOCK[algo])
    wk, wv = _lex_oracle([k[None, :], v[None, :]])
    np.testing.assert_array_equal(np.asarray(ok), wk[0])
    np.testing.assert_array_equal(np.asarray(ov), wv[0])


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.data())
def test_fuzz_sort_lex(data):
    algo = data.draw(st.sampled_from(ENGINES))
    n_lanes = data.draw(st.sampled_from([1, 2, 3, 4]))
    cols = data.draw(st.sampled_from([2, 33, 130]))
    lanes = []
    for _ in range(n_lanes):
        ls = data.draw(st.lists(st.integers(0, 3), min_size=cols, max_size=cols))
        lanes.append(jnp.asarray(np.array(ls, np.int64).astype(np.uint32)))
    out = sort_lex(lanes, algorithm=algo, block_size=_BLOCK[algo])
    want = _lex_oracle([l[None, :] for l in lanes])
    for o, w in zip(out, want):
        np.testing.assert_array_equal(np.asarray(o), w[0])
