import os
import sys

# Tests run on 1 CPU device (the dry-run alone sees 512 placeholder devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hypothesis profiles (no-op when hypothesis is absent; the sweeps then
# degrade to skips via tests/_hypothesis_compat):
#   * ci  — deadline disabled (interpret-mode first calls unroll whole swap
#           networks, so a per-example deadline only measures compile luck)
#           and fixed derandomization so CI failures reproduce locally;
#   * dev — verbose statistics for local sweep triage.
# Select with HYPOTHESIS_PROFILE=dev (default: ci).
try:
    from hypothesis import Verbosity, settings as _hsettings

    _hsettings.register_profile("ci", deadline=None, derandomize=True,
                                print_blob=True)
    _hsettings.register_profile("dev", deadline=None,
                                verbosity=Verbosity.verbose)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    pass
