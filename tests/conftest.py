import os
import sys

# Tests run on 1 CPU device (the dry-run alone sees 512 placeholder devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
