"""benchmarks/run.py must exit nonzero when any module fails.

A bench sweep that prints a traceback but returns 0 lets regressions ship
unnoticed; this pins the exit status end-to-end in a subprocess, using the
BENCH_INJECT_FAILURE knob so no real (slow) benchmark has to run. The
scratch --trajectory keeps the committed BENCH_kernels.json out of reach.
"""

import os
import subprocess
import sys

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(extra_env, tmp_path, only="bench_kernels"):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_REPO, "src"),
               JAX_PLATFORMS="cpu", **extra_env)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", only,
         "--trajectory", str(tmp_path / "traj.json")],
        cwd=_REPO, env=env, capture_output=True, text=True, timeout=300)


def test_injected_module_failure_exits_nonzero(tmp_path):
    proc = _run({"BENCH_INJECT_FAILURE": "bench_kernels"}, tmp_path)
    assert proc.returncode != 0, proc.stdout + proc.stderr
    assert "injected failure in bench_kernels" in proc.stderr
    assert "benchmark failures: ['bench_kernels']" in proc.stderr


def test_no_modules_selected_exits_zero(tmp_path):
    proc = _run({}, tmp_path, only="no_such_module")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert not (tmp_path / "traj.json").exists()  # nothing ran, no entry
