"""Explicit shard_map expert-parallel MoE == GSPMD MoE (8 host devices)."""

import os
import subprocess
import sys

_EP_SCRIPT = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.parallel.compat import AxisType, make_mesh
from repro.models.moe import init_moe, moe
from repro.models.moe_ep import ep_moe
from repro.models.param import Builder, finalize
from repro.parallel.sharding import Rules

rules = Rules()
cfg = get_smoke_config("granite-moe-1b-a400m")
# 8 experts over 8 devices, capacity high enough that nothing drops
cfg = cfg.replace(moe=dataclasses.replace(
    cfg.moe, n_experts=8, top_k=2, capacity_factor=8.0, n_shared=0))

b = Builder(jax.random.PRNGKey(0), dtype=jnp.float32)
params, _ = finalize(init_moe(b, cfg))

T = 64
x = jax.random.normal(jax.random.PRNGKey(1), (1, T, cfg.d_model))

# reference: GSPMD path on one device
y_ref, aux_ref = moe(cfg, params, x, rules)

# explicit EP over 8 devices
mesh = make_mesh((8,), ("ep",), axis_types=(AxisType.Auto,))
y_ep, aux_ep = ep_moe(
    cfg, mesh, "ep",
    x.reshape(T, cfg.d_model),
    params["router"], params["w_in"], params["w_out"],
)

err = float(jnp.max(jnp.abs(y_ep.reshape(1, T, -1) - y_ref)))
assert err < 2e-4, err
print("EP_OK", err)
"""


def test_ep_moe_matches_gspmd():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # 8 host devices; never probe TPU
    out = subprocess.run([sys.executable, "-c", _EP_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "EP_OK" in out.stdout
