"""Multi-block sort engine + unified ``sort()`` front-end validation.

Covers the acceptance bar: 1-D arrays and row-batches whose width spans >= 4
VMEM blocks, bit-identical to jnp.sort for keys and permutation-consistent
for key-value, plus duplicate-key payload preservation across all three
engines (oets / bitonic / blocksort)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocksort import block_sort, block_sort_kv, default_block_size
from repro.kernels import choose_plan, sort, sort_kv

# n = 1000 with block 128 spans 8 blocks; 513 spans 5. Larger sizes run with
# the cost-model block in test_block_sort_default_block (forcing block=128 at
# n=4096 means 32 interpret-mode merge rounds for no extra coverage).
SIZES_1D = [1, 5, 127, 128, 200, 513, 1000]
DTYPES = [np.int32, np.uint32, np.float32]


def _rand(rng, shape, dtype):
    if dtype == np.float32:
        x = rng.normal(size=shape).astype(dtype)
        x[rng.random(shape) < 0.05] = np.inf  # sentinel robustness
        return x
    return rng.integers(0, 10_000, shape).astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", SIZES_1D)
def test_block_sort_1d_matches_jnp(n, dtype):
    rng = np.random.default_rng(hash((n, str(dtype))) % 2**32)
    x = jnp.asarray(_rand(rng, (n,), dtype))
    out = np.asarray(block_sort(x, block_size=128))
    np.testing.assert_array_equal(out, np.asarray(jnp.sort(x)))


def test_block_sort_default_block():
    """Cost-model block at n=4096 (512 -> 8 blocks), no override."""
    rng = np.random.default_rng(4096)
    x = jnp.asarray(rng.integers(0, 10**9, 4096).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(block_sort(x)),
                                  np.asarray(jnp.sort(x)))


@pytest.mark.parametrize("rows,cols", [(1, 600), (5, 600), (12, 1030)])
def test_block_sort_rows_span_many_blocks(rows, cols):
    """cols=600..1030 at block 128 -> 5..9 VMEM blocks per row."""
    rng = np.random.default_rng(rows * 1000 + cols)
    x = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32))
    out = np.asarray(block_sort(x, block_size=128))
    np.testing.assert_array_equal(out, np.asarray(jnp.sort(x, axis=-1)))


def test_block_sort_oets_local_algorithm():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 100, (3, 520)).astype(np.int32))
    out = np.asarray(block_sort(x, block_size=128, local_algorithm="oets"))
    np.testing.assert_array_equal(out, np.asarray(jnp.sort(x, axis=-1)))


def test_block_sort_rejects_bad_block():
    x = jnp.zeros((2, 256), jnp.int32)
    with pytest.raises(ValueError):
        block_sort(x, block_size=100)  # not a power of two
    with pytest.raises(ValueError):
        block_sort(x, block_size=64)   # below one lane tile


def test_default_block_size_cost_model():
    assert default_block_size(1) == 512
    assert default_block_size(4096) == 512
    assert default_block_size(1 << 20) == 1 << 15            # VMEM cap (2 refs)
    assert default_block_size(1 << 20, kv=True) == 1 << 14   # kv: 4 refs
    b = default_block_size(100_000)
    assert b & (b - 1) == 0 and 128 <= b <= (1 << 15)


def test_block_sort_kv_permutation_consistent():
    rng = np.random.default_rng(11)
    k = jnp.asarray(rng.integers(0, 10_000, (4, 700)).astype(np.int32))
    v = jnp.asarray(np.arange(4 * 700, dtype=np.int32).reshape(4, 700))
    ok, ov = block_sort_kv(k, v, block_size=128)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(jnp.sort(k, axis=-1)))
    for r in range(4):
        got = sorted(zip(np.asarray(ok)[r], np.asarray(ov)[r]))
        want = sorted(zip(np.asarray(k)[r], np.asarray(v)[r]))
        assert got == want  # pairs travel together


# --- unified front-end -------------------------------------------------------

def test_choose_plan_tiers():
    assert choose_plan(1) == ("oets", None)
    assert choose_plan(128) == ("oets", None)
    assert choose_plan(129) == ("bitonic", None)
    assert choose_plan(1024) == ("bitonic", None)
    assert choose_plan(1025)[0] == "blocksort"
    assert choose_plan(1 << 20)[0] == "blocksort"
    # overrides pass straight through
    assert choose_plan(64, algorithm="blocksort", block_size=256) == ("blocksort", 256)


@pytest.mark.parametrize("n", [7, 100, 900, 3000])
def test_sort_frontend_1d(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(sort(x)), np.asarray(jnp.sort(x)))


def test_sort_frontend_2d_and_empty():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(0, 99, (6, 1500)).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(sort(x)),
                                  np.asarray(jnp.sort(x, axis=-1)))
    e = jnp.zeros((0,), jnp.int32)
    assert sort(e).shape == (0,)


# --- duplicate-key kv coverage across all three engines ----------------------

# non-pow2, >1 tile (cols > 128), and the n=1 edge, per engine
KV_SIZES = [1, 33, 130, 300, 700]
ENGINES = ["oets", "bitonic", "blocksort"]


@pytest.mark.parametrize("algo", ENGINES)
@pytest.mark.parametrize("n", KV_SIZES)
def test_sort_kv_duplicate_keys(algo, n):
    rng = np.random.default_rng(hash((algo, n)) % 2**32)
    rows = 3
    k = jnp.asarray(rng.integers(0, 5, (rows, n)).astype(np.int32))  # heavy dups
    v = jnp.asarray(rng.integers(0, 10**6, (rows, n)).astype(np.int32))
    block = 128 if algo == "blocksort" else None
    ok, ov = sort_kv(k, v, algorithm=algo, block_size=block)
    ok, ov = np.asarray(ok), np.asarray(ov)
    # keys non-decreasing and exactly the sorted keys
    assert (ok[:, :-1] <= ok[:, 1:]).all()
    np.testing.assert_array_equal(ok, np.asarray(jnp.sort(k, axis=-1)))
    # payload multiset preserved per row, and pairs stay married
    for r in range(rows):
        assert sorted(np.asarray(v)[r].tolist()) == sorted(ov[r].tolist())
        assert sorted(zip(np.asarray(k)[r], np.asarray(v)[r])) == \
            sorted(zip(ok[r], ov[r]))


@pytest.mark.parametrize("algo", ENGINES)
@pytest.mark.parametrize("n", [200, 1300])
def test_sort_kv_real_keys_equal_sentinel(algo, n):
    """Real keys equal to the padding sentinel must not lose their payloads
    to the padding lanes (the kernels' (key, val) lex compare keeps the
    padding pair strictly maximal)."""
    rng = np.random.default_rng(n)
    k = rng.integers(0, 100, n).astype(np.int32)
    k[rng.choice(n, 10, replace=False)] = np.iinfo(np.int32).max
    v = np.arange(n, dtype=np.int32)
    block = 128 if algo == "blocksort" else None
    ok, ov = sort_kv(jnp.asarray(k), jnp.asarray(v), algorithm=algo,
                     block_size=block)
    assert sorted(zip(k.tolist(), v.tolist())) == \
        sorted(zip(np.asarray(ok).tolist(), np.asarray(ov).tolist()))


@pytest.mark.parametrize("algo", ENGINES)
def test_sort_kv_all_equal_keys(algo):
    k = jnp.zeros((2, 150), jnp.int32)
    v = jnp.asarray(np.arange(300, dtype=np.int32).reshape(2, 150))
    block = 128 if algo == "blocksort" else None
    ok, ov = sort_kv(k, v, algorithm=algo, block_size=block)
    assert (np.asarray(ok) == 0).all()
    for r in range(2):
        assert sorted(np.asarray(ov)[r].tolist()) == list(range(r * 150, (r + 1) * 150))


# --- rewired callers ---------------------------------------------------------

def test_sort_buckets_pallas_route():
    """core.bucketing 'pallas' algorithm == the vmap'd OETS reference."""
    from repro.core import bucketize_words, sort_buckets
    ws = ["a", "c", "b", "dd", "aa", "cc", "x", "zz"]
    b = bucketize_words(ws)
    assert b.keys.shape[-1] == 1  # short words pack into one lane
    ref = np.asarray(sort_buckets(jnp.asarray(b.keys), "oets"))
    got = np.asarray(sort_buckets(jnp.asarray(b.keys), "pallas"))
    np.testing.assert_array_equal(got, ref)


def test_scheduler_orders_by_length():
    """serve scheduler batch ordering runs through the kernel sort."""
    from repro.serve.scheduler import BucketedScheduler, Request
    rs = [Request(i, [0] * n) for i, n in enumerate([9, 3, 7, 1, 5, 5])]
    ordered = BucketedScheduler._order_by_length(rs)
    lens = [len(r.prompt) for r in ordered]
    assert lens == sorted(lens)
    assert sorted(r.request_id for r in ordered) == list(range(6))


# --- partition padded-row regression ----------------------------------------

def test_partition_counts_nonnegative_with_padded_rows():
    """Pins the public contract when rows pad to the sublane grid: counts are
    non-negative and sum to cols. (The histogram correction is scoped to real
    rows internally; padded rows are sliced off before returning, so this
    guards the contract rather than the scoping itself.)"""
    from repro.kernels import partition_rows
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(0, 100, (5, 130)).astype(np.int32))  # pads both axes
    spl = jnp.asarray(np.array([25, 50, 75], np.int32))
    _, cnt = partition_rows(x, spl)
    cnt = np.asarray(cnt)
    assert (cnt >= 0).all()
    assert (cnt.sum(axis=1) == 130).all()
