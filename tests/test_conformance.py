"""The execution-mode conformance matrix — the single tier-1 contract
surface for the sort engine.

Every registered (op, engine) of ``repro.testing.CONTRACTS`` runs under
every execution mode the host offers (``repro.testing.modes``), over the
canonical adversarial generator set (``repro.testing.generators``), and
must be bit-identical to its NumPy oracle (total-order for the NaN cells:
bit-level multiset conserved AND sorted under the canonical order bits of
``kernels/lex.py``; capacity-parametric for bucketize). This replaces the
scattered one-off differentials that previously pinned each op in its own
file — the deterministic core of ``test_differential.py`` now lives here.

Unsupported combinations surface as skips with the contract's reason,
never as silent re-runs; the pin tests at the bottom keep the matrix
honest (the packed rank-key routing really is exercised, the NaN padding
hazard stays fixed on every engine, and the matrix never shrinks back
below the point where the NaN cells joined it).
"""

import jax
import numpy as np
import pytest

from repro.kernels.ops import choose_lex_engine
from repro.testing import (CONTRACTS, assert_conforms, available_modes,
                          iter_matrix, run_case)
from repro.testing.contracts import _LEX_MAX_VALUES

MODES = available_modes()
CELLS = iter_matrix(MODES)


def _cell_id(cell):
    op, engine, mode, gen, dtype = cell
    return f"{op}-{engine}-{mode.name}-{gen}-{dtype}"


def test_mode_axis_shape():
    """At least two modes everywhere; names unique; the eager interpreter
    mode of the running backend is always present."""
    assert len(MODES) >= 2
    names = [m.name for m in MODES]
    assert len(set(names)) == len(names)
    assert f"interpret-{jax.default_backend()}" in names
    assert any(m.jit for m in MODES)


def test_matrix_covers_every_engine_under_every_mode():
    """No engine can hide: each registered (op, engine) appears under every
    available mode with at least one adversarial case."""
    seen = {(op, engine, mode.name) for op, engine, mode, _, _ in CELLS}
    for name, contract in CONTRACTS.items():
        for engine in contract.engines:
            for mode in MODES:
                assert (name, engine, mode.name) in seen


def test_cases_are_deterministic_across_builds():
    """CRC-seeded case construction: the same (op, gen, dtype) always draws
    the same data, so failures reproduce across processes and CI shards."""
    for op in ("sort", "merge_sorted", "bucketize"):
        contract = CONTRACTS[op]
        gen = contract.generators[0]
        dtype = contract.dtypes_for(gen)[0]
        a, b = contract.build(gen, dtype), contract.build(gen, dtype)
        for x, y in zip(a.arrays, b.arrays):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("cell", CELLS, ids=[_cell_id(c) for c in CELLS])
def test_conformance(cell):
    op, engine, mode, gen, dtype = cell
    contract = CONTRACTS[op]
    reason = contract.supports(engine, mode, gen)
    if reason:
        pytest.skip(reason)
    case = contract.build(gen, dtype)
    run = run_case(contract, case, engine, mode)
    assert_conforms(contract, case, run.outputs)
    prov = run.provenance
    assert prov["mode"] == mode.name
    assert prov["backend"] == jax.default_backend()
    assert prov["jax"] == jax.__version__
    assert prov["pallas"] in ("interpret", "compiled")
    assert "device_kind" in prov


def test_packed_lex_routing_is_honored():
    """The sort_lex 'packed' cells genuinely run the packed rank-key path:
    the conformance lane bounds (2 + 32 + 16 = 50 bits) fit the 64-bit
    budget with fewer packed lanes, while the same tuple without bounds
    overflows and must fall back to 'lanes' — the silent-fallback rule that
    would otherwise let packed cells quietly re-test the lanes engine."""
    dtypes = [np.dtype(np.uint32)] * 3
    assert choose_lex_engine(dtypes, max_values=_LEX_MAX_VALUES,
                             engine="packed") == "packed"
    assert choose_lex_engine(dtypes, max_values=None,
                             engine="packed") == "lanes"


@pytest.mark.parametrize("engine", ["bitonic", "blocksort"])
def test_nan_padding_hazard(engine):
    """Regression pin for the padded-engine NaN hazard (once a strict
    xfail): a NaN used to compare false both ways against the +inf padding
    sentinel, stranding padding inside the sliced-back region — silent
    data loss. The canonical order bits of ``kernels/lex.py`` place every
    NaN *below* the all-ones sentinel, so padded comparator engines now
    meet the full total-order contract on NaN data."""
    contract = CONTRACTS["sort"]
    case = contract.build("nan", "float32")
    outputs = contract.run(case, engine, MODES[0])
    assert_conforms(contract, case, outputs)


def test_matrix_never_shrinks():
    """The matrix only ever grows: 282 cells before the NaN total-order
    work, 294 after it, 360 once the k-way merge landed (the `merge_runs`
    engine axis — streaming scatter, forced Pallas streaming kernel, and
    the tournament oracle — plus the 'kway' engine on both two-run merge
    ops). Any slide back under the floor means coverage was silently
    dropped."""
    assert len(CELLS) > 354
    assert sum(1 for c in CELLS if c[3] == "nan") >= 30
    assert sum(1 for c in CELLS if c[0] == "merge_runs") >= 30
