"""The run-merge front-end (``ops.merge_sorted``/``merge_sorted_lex``) and
the Pallas merge-path run kernel: every engine must produce output
bit-identical to the lane-wise ``lex_merge_take`` oracle, and the pipeline
tournament's fast paths must not touch the device. Kernel cases use
block_size=128 and small runs (interpret-mode compiles per shape)."""

import zlib
from unittest import mock

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import repro.pipeline.merge as pipeline_merge
from repro.kernels import (choose_merge_engine, merge_runs_lex_pallas,
                           merge_sorted, merge_sorted_lex)
from repro.kernels.lex import lex_merge_take
from repro.pipeline import merge_runs, merge_two
from repro.pipeline.validate import order_bits_view

ENGINES = ["packed", "kernel", "lanes"]


def _seed(*parts):
    return zlib.crc32("-".join(map(str, parts)).encode())


def _sorted_run(rng, n, n_lanes, flavor):
    if flavor == "dups":
        draw = lambda: rng.integers(0, 3, n).astype(np.uint32)
    elif flavor == "sentinel":
        def draw():
            x = rng.integers(0, 2**32, n).astype(np.uint32)
            x[rng.random(n) < 0.3] = np.uint32(0xFFFFFFFF)
            return x
    elif flavor == "negatives":
        draw = lambda: rng.integers(-(2**31), 2**31, n).astype(np.int32)
    else:
        draw = lambda: rng.integers(0, 2**32, n).astype(np.uint32)
    lanes = [draw() for _ in range(n_lanes)]
    order = np.lexsort(tuple(reversed(lanes)))
    return [jnp.asarray(a[order]) for a in lanes]


# ---------------------------------------------------------------------------
# engine differential suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("flavor", ["random", "dups", "sentinel", "negatives"])
@pytest.mark.parametrize("n_lanes", [1, 2, 4])
def test_merge_sorted_lex_bit_identical(engine, flavor, n_lanes):
    rng = np.random.default_rng(_seed("ms", engine, flavor, n_lanes))
    for na, nb in [(130, 89), (128, 128), (5, 100), (1, 1)]:
        A = _sorted_run(rng, na, n_lanes, flavor)
        B = _sorted_run(rng, nb, n_lanes, flavor)
        got = merge_sorted_lex(A, B, engine=engine, block_size=128)
        want = lex_merge_take(A, B)
        for g, w in zip(got, want):
            assert g.dtype == w.dtype
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_sorted_lex_empty_runs(engine):
    a = jnp.asarray(np.sort(np.arange(5).astype(np.int32)))
    empty = jnp.zeros((0,), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(merge_sorted_lex((a,), (empty,), engine=engine)[0]),
        np.asarray(a))
    np.testing.assert_array_equal(
        np.asarray(merge_sorted_lex((empty,), (a,), engine=engine)[0]),
        np.asarray(a))


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_sorted_key_only(engine):
    rng = np.random.default_rng(_seed("key", engine))
    a = np.sort(rng.integers(0, 1000, 140)).astype(np.int32)
    b = np.sort(rng.integers(0, 1000, 71)).astype(np.int32)
    got = merge_sorted(jnp.asarray(a), jnp.asarray(b), engine=engine,
                       block_size=128)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.sort(np.concatenate([a, b])))


def _nan_run(rng, n):
    """A float32 run with NaN payload variety (quiet/signalling, either
    sign, the all-ones sentinel pattern), ±inf and ±0.0, sorted under the
    canonical order bits — np.sort cannot build this (numpy's vectorised
    float sort canonicalises NaN payloads, and the raw order leaves the
    NaN tail unsorted in order-bit space)."""
    x = rng.normal(scale=4.0, size=n).astype(np.float32)
    x[rng.random(n) < 0.2] = np.nan
    x[rng.random(n) < 0.1] = np.float32(-0.0)
    x[rng.random(n) < 0.1] = np.inf
    x[rng.random(n) < 0.05] = -np.inf
    pats = np.array([0x7FC00001, 0xFFC00000, 0x7F800001, 0xFFFFFFFF],
                    np.uint32).view(np.float32)
    mask = rng.random(n) < 0.15
    x[mask] = pats[rng.integers(0, len(pats), int(mask.sum()))]
    return x[np.argsort(order_bits_view(x), kind="stable")]


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_sorted_lex_nan_differential(engine):
    """NaN/±inf/±0.0 differential: every engine agrees bit-for-bit with the
    lane-wise oracle, conserves the input bit multiset (NaN payloads and
    zero signs survive), and emits output sorted under the canonical order
    bits — the jnp.sort-equivalent contract of ops.py."""
    rng = np.random.default_rng(_seed("nan-merge", engine))
    for na, nb in [(96, 80), (5, 96)]:
        ka, kb = _nan_run(rng, na), _nan_run(rng, nb)
        A = [jnp.asarray(ka), jnp.asarray(np.arange(na, dtype=np.int32))]
        B = [jnp.asarray(kb), jnp.asarray(np.arange(nb, dtype=np.int32))]
        got = merge_sorted_lex(A, B, engine=engine, block_size=128)
        want = lex_merge_take(A, B)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(
                np.asarray(g).view(np.uint32), np.asarray(w).view(np.uint32))
        out = np.asarray(got[0])
        assert (sorted(out.view(np.uint32).tolist()) ==
                sorted(np.concatenate([ka, kb]).view(np.uint32).tolist()))
        ob = order_bits_view(out).astype(np.int64)
        assert np.all(np.diff(ob) >= 0), "merge output violates order bits"
        # single-lane front-end rides the same plane
        out1 = np.asarray(merge_sorted(jnp.asarray(ka), jnp.asarray(kb),
                                       engine=engine, block_size=128))
        assert np.all(np.diff(order_bits_view(out1).astype(np.int64)) >= 0)


def test_merge_sorted_validation():
    a = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="arity"):
        merge_sorted_lex((a,), (a, a))
    with pytest.raises(ValueError, match="1-D"):
        merge_sorted_lex((jnp.zeros((2, 2), jnp.int32),),
                         (jnp.zeros((2, 2), jnp.int32),))
    with pytest.raises(ValueError, match="unknown engine"):
        choose_merge_engine(10, engine="bogus")
    with pytest.raises(ValueError, match="power of two"):
        merge_runs_lex_pallas([a], [a], block=100)


def test_runmerge_kernel_total_below_one_block():
    """total < block: a single grid step, tail masked to sentinel and
    sliced off."""
    a = jnp.asarray(np.sort(np.array([3, 9, 9, 40], np.int32)))
    b = jnp.asarray(np.sort(np.array([1, 9, 50], np.int32)))
    (got,) = merge_runs_lex_pallas([a], [b], block=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  [1, 3, 9, 9, 9, 40, 50])


# ---------------------------------------------------------------------------
# pipeline tournament fast paths + packed-key reuse
# ---------------------------------------------------------------------------

def test_merge_runs_empty_list_returns_empty_tuple():
    assert merge_runs([]) == ()


def test_merge_runs_single_run_no_device_work():
    """One run must short-circuit: no merge primitive, no packing, no
    device launch — the run comes back as the identical objects."""
    a = (jnp.asarray([1, 2, 3], jnp.int32), jnp.asarray([4, 5, 6], jnp.uint32))
    with mock.patch.object(pipeline_merge, "merge_sorted_lex",
                           side_effect=AssertionError("merge ran")), \
         mock.patch.object(pipeline_merge, "packed_cmp_lanes",
                           side_effect=AssertionError("packing ran")):
        out = merge_runs([a])
    assert out[0] is a[0] and out[1] is a[1]


def test_merge_two_empty_side_no_device_work():
    """An empty side returns the other run's identical array objects —
    merge_sorted_lex's fast path fires before any rank/scatter work."""
    a = (jnp.asarray([1, 2], jnp.int32),)
    empty = (jnp.zeros((0,), jnp.int32),)
    assert merge_two(a, empty)[0] is a[0]
    assert merge_two(empty, a)[0] is a[0]


def test_merge_runs_cmp_runs_matches_fresh_packing():
    """Tournament fed precomputed rank keys (the fused-program handoff)
    equals the self-packing tournament bit-for-bit."""
    from repro.kernels.keypack import packed_cmp_lanes
    rng = np.random.default_rng(_seed("cmp-runs"))
    runs = [tuple(_sorted_run(rng, n, 3, "dups")) for n in (40, 40, 17)]
    fresh = merge_runs(runs)
    handed = merge_runs(runs, cmp_runs=[packed_cmp_lanes(list(r))
                                        for r in runs])
    for g, w in zip(handed, fresh):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    flat = np.stack([np.concatenate([np.asarray(l) for l in (r[i] for r in runs)])
                     for i in range(3)])
    order = np.lexsort(tuple(reversed(list(flat))))
    for i, g in enumerate(fresh):
        np.testing.assert_array_equal(np.asarray(g), flat[i][order])


# ---------------------------------------------------------------------------
# hypothesis sweep (slow tier)
# ---------------------------------------------------------------------------

run_strategy = st.lists(st.integers(min_value=0, max_value=7),
                        min_size=0, max_size=80)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(run_strategy, run_strategy, st.sampled_from(ENGINES))
def test_merge_sorted_property(a_vals, b_vals, engine):
    a = jnp.asarray(np.sort(np.asarray(a_vals, np.int32)))
    b = jnp.asarray(np.sort(np.asarray(b_vals, np.int32)))
    got = merge_sorted(a, b, engine=engine, block_size=128)
    np.testing.assert_array_equal(
        np.asarray(got), np.sort(np.concatenate([np.asarray(a_vals, np.int32),
                                                 np.asarray(b_vals, np.int32)])))
