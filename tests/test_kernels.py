"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle
(interpret mode on CPU; the kernels target TPU BlockSpec tiling)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import sort_rows, sort_rows_kv, sort_rows_ref, sort_rows_kv_ref

SHAPES = [(1, 1), (3, 17), (8, 128), (5, 200), (9, 257), (16, 64), (2, 512)]
DTYPES = [np.int32, np.uint32, np.float32]


def _rand(rng, shape, dtype):
    if dtype == np.float32:
        x = rng.normal(size=shape).astype(dtype)
        x[rng.random(shape) < 0.05] = np.inf  # sentinel robustness
        return x
    return rng.integers(0, 10_000, shape).astype(dtype)


@pytest.mark.parametrize("algo", ["oets", "bitonic"])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", SHAPES)
def test_sort_rows_matches_ref(algo, dtype, shape):
    rng = np.random.default_rng(hash((algo, str(dtype), shape)) % 2**32)
    x = jnp.asarray(_rand(rng, shape, dtype))
    out = sort_rows(x, algorithm=algo)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(sort_rows_ref(x)))


@pytest.mark.parametrize("algo", ["oets", "bitonic"])
@pytest.mark.parametrize("shape", [(4, 33), (8, 128), (3, 100)])
def test_sort_rows_kv_matches_ref(algo, shape):
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 50, shape).astype(np.int32))
    v = jnp.asarray(rng.integers(0, 10**6, shape).astype(np.int32))
    ok, ov = sort_rows_kv(k, v, algorithm=algo)
    rk, rv = sort_rows_kv_ref(k, v)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(rk))
    # values: same multiset of (k, v) pairs per row (ties may permute)
    for r in range(shape[0]):
        got = sorted(zip(np.asarray(ok)[r], np.asarray(ov)[r]))
        want = sorted(zip(np.asarray(rk)[r], np.asarray(rv)[r]))
        assert got == want


def test_kernel_handles_duplicate_keys():
    k = jnp.asarray(np.zeros((4, 64), np.int32))
    v = jnp.asarray(np.arange(4 * 64, dtype=np.int32).reshape(4, 64))
    ok, ov = sort_rows_kv(k, v, algorithm="oets")
    assert (np.asarray(ok) == 0).all()
    for r in range(4):
        assert sorted(np.asarray(ov)[r].tolist()) == list(range(r * 64, (r + 1) * 64))


def test_kernel_row_independence():
    """Sorting rows together == sorting each row alone (bucket isolation)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 1000, (6, 96)).astype(np.int32))
    full = np.asarray(sort_rows(x, algorithm="bitonic"))
    for r in range(6):
        alone = np.asarray(sort_rows(x[r : r + 1], algorithm="bitonic"))
        np.testing.assert_array_equal(full[r], alone[0])


@pytest.mark.parametrize("shape,n_spl", [((4, 64), 7), ((8, 128), 15),
                                         ((3, 200), 3), ((5, 96), 31)])
def test_partition_rows_matches_ref(shape, n_spl):
    """Splitter-partition kernel (the paper's distribute step) == oracle."""
    from repro.kernels import partition_rows, partition_rows_ref
    rng = np.random.default_rng(hash((shape, n_spl)) % 2**32)
    x = jnp.asarray(rng.integers(0, 10_000, shape).astype(np.int32))
    spl = jnp.asarray(np.sort(rng.choice(10_000, n_spl, replace=False)).astype(np.int32))
    bid, cnt = partition_rows(x, spl)
    rbid, rcnt = partition_rows_ref(x, spl)
    np.testing.assert_array_equal(np.asarray(bid), np.asarray(rbid))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))
    # histogram really partitions every element
    assert (np.asarray(cnt).sum(axis=1) == shape[1]).all()
