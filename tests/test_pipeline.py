"""Pipeline tier: the on-device distribute (ops.distribute / ops.bucketize)
against the host reference bucketizer, the zero-host-loop guard on
``bucketed_sort_words``, and the chunked sorted-run ingest
(``repro.pipeline``) against the shortlex oracle.

Sizes stay small: every case compiles interpret-mode Pallas programs on this
CPU container. Words cap at 11 bytes (3 uint32 lanes, 13 buckets) so the
fused program stays in the oets/bitonic tiers.
"""

from unittest import mock

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import repro.core.bucketing as core_bucketing
from repro.core import bucketize_packed, bucketize_words, sorted_packed
from repro.core.packing import SENTINEL_U32, pack_words, unpack_words
from repro.kernels import bucketize, distribute
from repro.pipeline import (SortedRun, chunked_sort_packed,
                            chunked_sort_words, merge_runs, merge_two)


def _shortlex(words):
    return sorted(words, key=lambda w: (len(w.encode()), w.encode()))


def _word_set(kind, n, rng, max_len=11):
    """Three length distributions the differential sweep covers."""
    alpha = "abcdefgh"
    if kind == "random":
        lens = rng.integers(0, max_len + 1, n)
    elif kind == "dup":  # few distinct words, many repeats
        pool = ["".join(rng.choice(list(alpha), rng.integers(1, max_len + 1)))
                for _ in range(max(2, n // 10))]
        return [pool[i] for i in rng.integers(0, len(pool), n)]
    elif kind == "skew":  # nearly everything one length, a thin tail
        lens = np.where(rng.random(n) < 0.9, 5,
                        rng.integers(0, max_len + 1, n))
    else:
        raise ValueError(kind)
    return ["".join(rng.choice(list(alpha), l)) for l in lens]


# ---------------------------------------------------------------------------
# device distribute / bucketize vs the host reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["random", "dup", "skew"])
def test_device_bucketize_matches_host(kind):
    """300 words > 2 x the 128-word kernel block, so the sequential-grid
    running-count carry (stable ranks across block boundaries) is on the
    differential path, not just the single-block case."""
    rng = np.random.default_rng({"random": 0, "dup": 1, "skew": 2}[kind])
    words = _word_set(kind, 300, rng)
    keys = jnp.asarray(pack_words(words))
    host = bucketize_words(words)
    dev_keys, dev_counts, _ = bucketize(keys)
    dev_counts = np.asarray(dev_counts)
    # dense per-length device buckets vs sparse host buckets: same counts,
    # same contents in arrival order, everything else empty
    host_by_len = dict(zip(host.lengths.tolist(),
                           range(len(host.lengths))))
    for l in range(dev_keys.shape[0]):
        if l in host_by_len:
            hi = host_by_len[l]
            cnt = int(host.counts[hi])
            assert dev_counts[l] == cnt
            np.testing.assert_array_equal(
                np.asarray(dev_keys)[l, :cnt],
                host.keys[hi, :cnt])
        else:
            assert dev_counts[l] == 0
    # all unused device slots hold the sentinel
    slot = np.arange(dev_keys.shape[1])
    mask = slot[None, :] >= dev_counts[:, None]
    assert (np.asarray(dev_keys)[mask] == SENTINEL_U32).all()


def test_distribute_stable_ranks_and_histogram():
    words = ["aa", "bb", "aa", "c", "dd", "c", "aa", ""]
    dest, rank, counts = distribute(jnp.asarray(pack_words(words)))
    assert np.asarray(dest).tolist() == [2, 2, 2, 1, 2, 1, 2, 0]
    # arrival order within each length bucket
    assert np.asarray(rank).tolist() == [0, 1, 2, 0, 3, 1, 4, 0]
    assert np.asarray(counts).tolist()[:3] == [1, 2, 5]


def test_bucketize_explicit_capacity_counts_overflow():
    """Clipped words drop from the tensor but stay in the true counts (the
    exact-count contract); bucketize_packed raises like the host version."""
    keys = jnp.asarray(pack_words(["aa", "bb", "cc", "d"]))
    bk, counts, dropped = bucketize(keys, capacity=2)
    assert int(counts[2]) == 3 and bk.shape[1] == 2
    assert dropped == 1  # the clipped word is *reported*, never silent
    with pytest.raises(ValueError, match="exceeds capacity"):
        bucketize_packed(keys, capacity=2)


def test_bucketize_skew_overflow_policies():
    """A skewed dataset (90% of words one length) against a capacity sized
    for the uniform case: 'clip' must report exactly how many words fell
    past capacity, 'retry' must converge losslessly at the true max, and
    'raise' must carry capacity/required/dropped on the exception."""
    from repro.runtime import CapacityOverflow

    rng = np.random.default_rng(33)
    words = _word_set("skew", 120, rng, max_len=7)
    keys = jnp.asarray(pack_words(words))
    per_len = np.bincount([len(w.encode()) for w in words], minlength=9)
    cap = 16
    want_drop = int(np.maximum(per_len - cap, 0).sum())
    assert want_drop > 0  # the skew really overflows this capacity

    bk, counts, dropped = bucketize(keys, capacity=cap, on_overflow="clip")
    assert dropped == want_drop
    np.testing.assert_array_equal(np.asarray(counts), per_len[: counts.shape[0]])

    bk, counts, dropped = bucketize(keys, capacity=cap, on_overflow="retry")
    assert dropped == 0 and bk.shape[1] == int(per_len.max())

    with pytest.raises(CapacityOverflow) as ei:
        bucketize(keys, capacity=cap, on_overflow="raise")
    assert ei.value.capacity == cap
    assert ei.value.required == int(per_len.max())
    assert ei.value.dropped == want_drop


@pytest.mark.parametrize("kind", ["random", "skew"])
def test_bucketize_capacity_autotune_exact(kind):
    """The two-tier autotune (capacity=None): the optimistic first shot
    must hold every word on near-uniform inputs, and the skewed case —
    one length holding most of the words, far past the optimistic cap —
    must retry at the true max. Either way zero words drop and the tensor
    equals the host reference's buckets."""
    rng = np.random.default_rng({"random": 21, "skew": 22}[kind])
    words = _word_set(kind, 260, rng, max_len=7)
    keys = jnp.asarray(pack_words(words))
    bk, counts, dropped = bucketize(keys)
    assert bk.shape[1] >= int(jnp.max(counts))  # no overflow ever
    assert dropped == 0
    host = bucketize_words(words)
    host_by_len = dict(zip(host.lengths.tolist(), range(len(host.lengths))))
    for l in range(bk.shape[0]):
        if l in host_by_len:
            cnt = int(host.counts[host_by_len[l]])
            assert int(counts[l]) == cnt
            np.testing.assert_array_equal(
                np.asarray(bk)[l, :cnt], host.keys[host_by_len[l], :cnt])
        else:
            assert int(counts[l]) == 0
    if kind == "skew":
        # the dominant length must exceed the optimistic first-shot cap,
        # otherwise this case stopped exercising the retry tier
        from repro.kernels.ops import _optimistic_capacity
        assert int(jnp.max(counts)) > _optimistic_capacity(len(words),
                                                           bk.shape[0])


def test_host_reference_buckets_by_byte_length():
    """Host and device agree on non-ASCII: both bucket by *encoded byte*
    length (the unit the packed lanes sort by), so 'é' (2 bytes) shares a
    bucket with 'ab', not with 'a'."""
    host = bucketize_words(["é", "ab", "a"])
    assert host.lengths.tolist() == [1, 2]
    assert host.counts.tolist() == [1, 2]
    _, _, counts = distribute(jnp.asarray(pack_words(["é", "ab", "a"])))
    assert np.asarray(counts).tolist()[:3] == [0, 1, 2]
    words = ["é", "ab", "a", "日本", "zz"]
    got = core_bucketing.bucketed_sort_words(words, algorithm="pallas")
    assert got == _shortlex(words)


def test_assign_buckets_rejects_unsorted_bounds():
    from repro.pipeline import assign_buckets
    with pytest.raises(ValueError, match="ascending"):
        assign_buckets([5], [16, 4])


def test_bucketize_packed_empty_input():
    b = bucketize_packed(jnp.zeros((0, 1), jnp.uint32))
    assert b.keys.shape[1] == 0 and int(b.counts.sum()) == 0


def test_bucketed_sort_words_never_calls_host_bucketizer():
    """The acceptance pin: after packing, the end-to-end path has no
    host-side per-word Python loop — the host dict-loop bucketizer must be
    dead code on the production path."""
    words = ["serpent", "sorbet", "sierra", "samba", "sonata", "sunset",
             "s", "", "sorbet"]
    with mock.patch.object(core_bucketing, "bucketize_words",
                           side_effect=AssertionError("host bucketizer ran")):
        got = core_bucketing.bucketed_sort_words(words, algorithm="pallas")
    assert got == _shortlex(words)


def test_sorted_packed_shortlex_and_lengths():
    words = ["zz", "a", "zzz", "b", "aaa", ""]
    lens, keys = sorted_packed(jnp.asarray(pack_words(words)))
    assert unpack_words(np.asarray(keys)) == _shortlex(words)
    assert np.asarray(lens).tolist() == sorted(len(w) for w in words)


# ---------------------------------------------------------------------------
# run merge
# ---------------------------------------------------------------------------

def _run_of(words):
    ws = _shortlex(words)
    keys = jnp.asarray(pack_words(ws, width=11))
    lens = jnp.asarray([len(w.encode()) for w in ws], jnp.int32)
    return SortedRun(lengths=lens, keys=keys)


def test_merge_two_unequal_lengths_and_duplicates():
    a = _run_of(["aa", "b", "zz", "aa"])
    b = _run_of(["ab", "c", "c", "yy", "aaa", "q"])
    merged = SortedRun.from_lanes(merge_two(a.lanes(), b.lanes()))
    want = _shortlex(["aa", "b", "zz", "aa", "ab", "c", "c", "yy", "aaa", "q"])
    assert unpack_words(np.asarray(merged.keys)) == want


def test_merge_runs_tournament_odd_count():
    groups = [["dd", "a"], ["bb", "e"], ["cc"], ["aa", "zzz"], ["b"]]
    merged = SortedRun.from_lanes(merge_runs([_run_of(g).lanes()
                                              for g in groups]))
    want = _shortlex([w for g in groups for w in g])
    assert unpack_words(np.asarray(merged.keys)) == want


def test_merge_is_shortlex_not_bytelex():
    """'z' must come before 'aa' — the length lane decides, not the bytes."""
    merged = SortedRun.from_lanes(
        merge_two(_run_of(["z"]).lanes(), _run_of(["aa"]).lanes()))
    assert unpack_words(np.asarray(merged.keys)) == ["z", "aa"]


# ---------------------------------------------------------------------------
# chunked ingest end-to-end
# ---------------------------------------------------------------------------

def test_chunked_sort_multiple_chunks_matches_oracle():
    """> 1 chunk (the acceptance pin): 130 words through 48-word chunks —
    3 runs, 2 merge rounds — exactly equals the shortlex oracle."""
    rng = np.random.default_rng(11)
    words = _word_set("random", 130, rng, max_len=9)
    got = chunked_sort_words(words, chunk_size=48)
    assert got == _shortlex(words)


def test_chunked_equals_single_launch():
    rng = np.random.default_rng(12)
    words = _word_set("dup", 90, rng, max_len=7)
    chunked = chunked_sort_words(words, chunk_size=32)
    single = core_bucketing.bucketed_sort_words(words, algorithm="pallas")
    assert chunked == single == _shortlex(words)


def test_chunked_sort_packed_run_is_exact():
    rng = np.random.default_rng(13)
    words = _word_set("skew", 100, rng, max_len=7)
    keys = jnp.asarray(pack_words(words))
    run = chunked_sort_packed(keys, chunk_size=40)
    assert run.keys.shape == keys.shape
    assert unpack_words(np.asarray(run.keys)) == _shortlex(words)
    byte_lens = [len(w.encode()) for w in _shortlex(words)]
    assert np.asarray(run.lengths).tolist() == byte_lens


def test_chunked_edge_cases():
    assert chunked_sort_words([]) == []
    assert chunked_sort_words(["b", "a"], chunk_size=1) == ["a", "b"]
    with pytest.raises(ValueError):
        chunked_sort_words(["a"], chunk_size=0)


def test_prefetch_map_orders_and_overlaps():
    """The packing double-buffer: results come back in order, one per item,
    and item i+1 runs on the worker thread while the consumer still holds
    item i (i.e. before the generator is advanced again)."""
    import time

    from repro.pipeline.ingest import _prefetch_map
    calls = []

    def fn(x):
        calls.append(x)
        return x * 10

    gen = _prefetch_map(fn, [1, 2, 3])
    first = next(gen)
    # without advancing the generator, the worker must already be packing
    # item 2 — that is the whole point of the prefetch
    deadline = time.monotonic() + 5
    while len(calls) < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert calls == [1, 2]
    assert [first] + list(gen) == [10, 20, 30]
    assert calls == [1, 2, 3]
    assert list(_prefetch_map(fn, [])) == []


def test_packed_ingest_stages_h2d_on_worker_thread():
    """The device half of the ingest double buffer: every chunk's
    host->device staging (``_stage_chunk``) must run through the prefetch
    worker — never the consumer thread — once per chunk, in order, and the
    staged result must still sort exactly. Pins the H2D overlap the same way
    ``test_prefetch_map_orders_and_overlaps`` pins the packing half."""
    import threading

    import repro.pipeline.ingest as ingest_mod
    rng = np.random.default_rng(24)
    words = _word_set("random", 100, rng, max_len=7)
    keys = np.asarray(pack_words(words))
    staged = []
    main = threading.current_thread()
    real = ingest_mod._stage_chunk

    def spy(chunk):
        staged.append((int(chunk.shape[0]),
                       threading.current_thread() is not main))
        return real(chunk)

    with mock.patch.object(ingest_mod, "_stage_chunk", spy):
        run = chunked_sort_packed(keys, chunk_size=40)
    assert [s[0] for s in staged] == [40, 40, 20]  # once per chunk, in order
    assert all(off_main for _, off_main in staged)
    assert unpack_words(np.asarray(run.keys)) == _shortlex(words)


def test_merge_engine_knob_reaches_run_combine():
    """The ``merge_engine`` knob threads from the ingest front-ends to
    ``merge_runs``: every engine yields the identical shortlex result, and
    an unknown engine fails loudly."""
    rng = np.random.default_rng(25)
    words = _word_set("dup", 120, rng, max_len=7)
    outs = {eng: chunked_sort_words(words, chunk_size=48, merge_engine=eng)
            for eng in ("auto", "kway", "tournament")}
    assert outs["auto"] == outs["kway"] == outs["tournament"] \
        == _shortlex(words)
    with pytest.raises(ValueError, match="engine"):
        chunked_sort_words(words, chunk_size=48, merge_engine="bogus")


def test_chunked_words_runs_carry_packed_rank_keys():
    """Every per-chunk run ships the fused program's packed shortlex rank
    keys to the merge tier (no re-pack), and the packed lanes order exactly
    as the shortlex tuples."""
    from repro.pipeline import sorted_run
    rng = np.random.default_rng(23)
    words = _word_set("random", 60, rng, max_len=7)
    run = sorted_run(jnp.asarray(pack_words(words)))
    assert run.packed is not None and len(run.packed) == 2
    cmp = run.cmp_lanes()
    assert len(cmp) <= 1 + run.keys.shape[1]
    # packed lex order must be non-decreasing down the sorted run
    flat = np.stack([np.asarray(c) for c in cmp])
    prev, cur = flat[:, :-1], flat[:, 1:]
    gt = np.zeros(prev.shape[1], bool)
    eq = np.ones(prev.shape[1], bool)
    for i in range(flat.shape[0]):
        gt = gt | (eq & (prev[i] > cur[i]))
        eq = eq & (prev[i] == cur[i])
    assert not gt.any()


words_strategy = st.lists(
    st.text(alphabet=st.characters(min_codepoint=33, max_codepoint=126),
            min_size=0, max_size=11),
    min_size=0, max_size=60)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(words_strategy, st.integers(min_value=1, max_value=25))
def test_chunked_pipeline_property(ws, chunk):
    """Random word lists x random chunk sizes: the chunked pipeline equals
    the shortlex oracle, and the device bucketize histogram equals the host
    length histogram."""
    got = chunked_sort_words(ws, chunk_size=chunk)
    assert got == _shortlex(ws)
    if ws:
        keys = jnp.asarray(pack_words(ws))
        _, _, counts = distribute(keys)
        hist = np.bincount([len(w.encode()) for w in ws],
                           minlength=counts.shape[0])
        np.testing.assert_array_equal(np.asarray(counts), hist)
