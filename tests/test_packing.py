"""Edge-case pins for the fixed-width key packing contract
(``core/packing.py``) — the ingress boundary everything on-device trusts:
big-endian bytes in uint32 lanes, zero tail padding, lane-lex order ==
byte-lex order."""

import numpy as np
import pytest

from repro.core.packing import (SENTINEL_U32, lanes_for_width, pack_words,
                                unpack_words)


def test_width_boundary_words_roundtrip():
    """Words of exactly 4*lanes bytes (no padding byte at all) and one byte
    to either side."""
    for nbytes in (4, 8, 16):
        lanes = lanes_for_width(nbytes)
        assert lanes * 4 == nbytes
        words = ["x" * (nbytes - 1), "y" * nbytes, "z" * (nbytes + 1)]
        keys = pack_words(words)
        assert keys.shape == (3, lanes_for_width(nbytes + 1))
        assert unpack_words(keys) == words


def test_exact_width_fills_every_byte():
    """A 4-byte word in a 1-lane packing uses all 32 bits, big-endian."""
    keys = pack_words(["abcd"], width=4)
    assert keys.shape == (1, 1)
    assert keys[0, 0] == (ord("a") << 24 | ord("b") << 16
                          | ord("c") << 8 | ord("d"))


def test_word_longer_than_width_raises():
    with pytest.raises(ValueError):
        pack_words(["abcde"], width=4)


def test_empty_word_and_empty_list():
    keys = pack_words(["", "a", ""])
    assert keys.shape == (3, 1)
    assert keys[0, 0] == 0 and keys[2, 0] == 0
    assert unpack_words(keys) == ["", "a", ""]
    empty = pack_words([])
    assert empty.shape == (0, 1)
    assert unpack_words(empty) == []


def test_non_ascii_utf8_roundtrip_and_order():
    """Multi-byte UTF-8 packs by encoded byte length and round-trips; byte
    order (not codepoint order) is the sort contract."""
    words = ["héllo", "naïve", "日本", "ascii"]
    keys = pack_words(words)
    assert unpack_words(keys) == words
    # encoded byte widths drive the lane count
    assert keys.shape[1] == lanes_for_width(max(len(w.encode()) for w in words))
    # packed integer order == encoded-byte lexicographic order
    a, b = pack_words(["é", "z"], width=4)[:, 0]
    assert (a > b) == ("é".encode() > "z".encode())


def test_raw_bytes_input_packs_by_byte():
    """bytes input (incl. values >= 0x80) packs verbatim."""
    keys = pack_words([b"\xff\x01", b"\x01\xff"], width=4)
    assert keys[0, 0] == (0xFF << 24 | 0x01 << 16)
    assert keys[1, 0] == (0x01 << 24 | 0xFF << 16)
    assert keys[0, 0] > keys[1, 0]  # byte-lex order preserved


def test_interior_nul_survives_trailing_nul_does_not():
    """Interior NUL bytes round-trip (length = last non-zero byte + 1, the
    same rule the device distribute kernel applies); trailing NULs are
    indistinguishable from padding — pinned as the documented loss."""
    keys = pack_words([b"a\x00b"], width=4)
    assert unpack_words(keys)[0].encode() == b"a\x00b"
    keys = pack_words([b"ab\x00"], width=4)
    assert unpack_words(keys)[0].encode() == b"ab"


def test_prefix_orders_before_extension():
    """Zero padding sorts before every real byte: 'ab' < 'abc'."""
    keys = pack_words(["abc", "ab"])
    assert keys[1, 0] < keys[0, 0]


def test_sentinel_is_maximal():
    keys = pack_words(["\x7f\x7f\x7f\x7f"])  # highest ASCII in every byte
    assert keys[0, 0] < SENTINEL_U32
