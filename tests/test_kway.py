"""The one-launch streaming k-way merge tier (``kernels/kway_kernel.py``)
and its plumbing: the merge-path rank tournament, the fused key-sort 'take'
tier, the Pallas streaming kernel (interpret mode here), and the
``merge_runs`` / ``merge_sorted_lex`` engine knobs — every path held
bit-identical to the NumPy lexsort oracle and to the legacy pairwise
tournament.

Sizes stay small: the kernel cases compile interpret-mode Pallas programs
on this CPU container (block 128, a few hundred elements — still genuinely
multi-block, so the double-buffered segment DMA is on the tested path).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.keypack import packed_cmp_lanes
from repro.kernels.kway_kernel import (kway_ranks, merge_runs_kway_pallas,
                                       merge_runs_kway_take)
from repro.kernels.lex import to_order_bits
from repro.kernels.ops import choose_kway_engine, merge_runs_lex, merge_sorted_lex
from repro.pipeline import merge_runs


def _sorted_run(rng, n, n_lanes=3, hi=2**32):
    lanes = [rng.integers(0, hi, n).astype(np.uint32) for _ in range(n_lanes)]
    order = np.lexsort(tuple(reversed(lanes)))
    return [jnp.asarray(a[order]) for a in lanes]


def _oracle(runs):
    """NumPy lexsort of the concatenation — all lanes compare, so the merged
    lanes are unique per tuple multiset and bit-identical across engines."""
    n_lanes = len(runs[0])
    flat = [np.concatenate([np.asarray(r[i]) for r in runs])
            for i in range(n_lanes)]
    order = np.lexsort(tuple(reversed(flat)))
    return [lane[order] for lane in flat]


def _assert_lanes_equal(got, expect):
    assert len(got) == len(expect)
    for g, e in zip(got, expect):
        g, e = np.asarray(g), np.asarray(e)
        if g.dtype.kind == "f":
            g, e = g.view(np.uint32), e.view(np.uint32)
        np.testing.assert_array_equal(g, e)


# ---------------------------------------------------------------------------
# kway_ranks: the merge-path split
# ---------------------------------------------------------------------------

def test_kway_ranks_breaks_ties_by_run_index():
    """Hand-checkable ties: compare-equal elements must rank lower-run-first
    (then in-run order), the a-before-b protocol along the whole tree."""
    r0 = (jnp.asarray(np.array([0, 5, 5], np.uint32)),)
    r1 = (jnp.asarray(np.array([5, 5, 7], np.uint32)),)
    r2 = (jnp.asarray(np.array([5, 9], np.uint32)),)
    ranks = kway_ranks([r0, r1, r2])
    assert [r.tolist() for r in ranks] == [[0, 1, 2], [3, 4, 6], [5, 7]]


@pytest.mark.parametrize("sizes", [(17,), (9, 13), (32, 0, 21, 5, 40)])
def test_kway_ranks_is_a_permutation(sizes):
    rng = np.random.default_rng(sum(sizes))
    cmp_runs = [tuple(_sorted_run(rng, n, 2, hi=50)) for n in sizes]
    ranks = kway_ranks(cmp_runs)
    assert [r.shape[0] for r in ranks] == list(sizes)
    flat = np.concatenate([np.asarray(r) for r in ranks])
    assert sorted(flat.tolist()) == list(range(sum(sizes)))
    # within a run, ranks must ascend (runs are sorted)
    for r in ranks:
        assert np.all(np.diff(np.asarray(r)) > 0) or r.shape[0] <= 1


# ---------------------------------------------------------------------------
# the jnp 'take' tier: fused key sort + one gather per lane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [(5, 7), (5, 0, 9, 3), (64, 48, 33, 16, 9),
                                   (20,) * 8])
def test_take_matches_oracle(sizes):
    rng = np.random.default_rng(len(sizes))
    runs = [_sorted_run(rng, n) for n in sizes]
    _assert_lanes_equal(merge_runs_kway_take(runs), _oracle(runs))


def test_take_dup_heavy_ties_match_oracle():
    """Tiny alphabet: nearly everything ties on the leading lanes, so the
    run-index tie protocol carries the whole output order."""
    rng = np.random.default_rng(99)
    runs = [_sorted_run(rng, n, 3, hi=3) for n in (40, 40, 40, 40)]
    _assert_lanes_equal(merge_runs_kway_take(runs), _oracle(runs))


def test_take_float32_nan_and_neg_zero():
    """float32 lane with NaNs and -0.0: the take tier's key sort runs on
    canonical order bits, so NaNs land above +inf and -0.0 collapses onto
    +0.0 — exactly the repo comparator, bit-preserving through the gather."""
    rng = np.random.default_rng(7)
    runs = []
    for n in (33, 21, 17):
        v = rng.uniform(-5, 5, n).astype(np.float32)
        v[rng.random(n) < 0.25] = np.nan
        v[rng.random(n) < 0.1] = -0.0
        p = rng.integers(0, 2**31, n).astype(np.int32)
        ob = np.asarray(to_order_bits(jnp.asarray(v)))
        order = np.lexsort((p, ob))
        runs.append([jnp.asarray(v[order]), jnp.asarray(p[order])])
    got = merge_runs_kway_take(runs)
    # oracle in order-bit space (payload rides in the packed compare list)
    va = np.concatenate([np.asarray(r[0]) for r in runs])
    pa = np.concatenate([np.asarray(r[1]) for r in runs])
    order = np.lexsort((pa, np.asarray(to_order_bits(jnp.asarray(va)))))
    _assert_lanes_equal(got, [va[order], pa[order]])


# ---------------------------------------------------------------------------
# the Pallas streaming kernel (interpret mode, multi-block)
# ---------------------------------------------------------------------------

def test_kernel_matches_oracle_multiblock():
    """258 elements at block 128 -> 3 output blocks: the scalar-prefetched
    starts matrix, the 2-slot double-buffered segment DMA, and the loser
    tree all sit on the differential path."""
    rng = np.random.default_rng(42)
    runs = [_sorted_run(rng, n) for n in (130, 77, 50, 1)]
    got = merge_runs_kway_pallas(runs, block=128, interpret=True)
    _assert_lanes_equal(got, _oracle(runs))


def test_kernel_prepacked_cmp_prefix():
    """The ``n_cmp`` contract: rank on pre-packed leading compare lanes
    only (the pipeline hands the fused program's rank keys over); the data
    lanes ride untouched and come back merged bit-identically."""
    rng = np.random.default_rng(8)
    ext_runs = []
    for n in (70, 66, 40):
        lanes = _sorted_run(rng, n, 2, hi=2**16)
        cmp = packed_cmp_lanes(lanes, (2**16 - 1,) * 2)
        assert len(cmp) == 1  # 2x16 bits packs into one uint32 rank key
        ext_runs.append(tuple(cmp) + tuple(lanes))
    got = merge_runs_kway_pallas(ext_runs, n_cmp=1, block=128,
                                 interpret=True)
    expect = _oracle([r[1:] for r in ext_runs])
    _assert_lanes_equal(got[1:], expect)


def test_kernel_rejects_bad_block_and_arity():
    rng = np.random.default_rng(3)
    runs = [_sorted_run(rng, 8), _sorted_run(rng, 8)]
    with pytest.raises(ValueError, match="power of two"):
        merge_runs_kway_pallas(runs, block=96)
    with pytest.raises(ValueError, match="arity"):
        merge_runs_kway_pallas([runs[0], runs[1][:2]])


# ---------------------------------------------------------------------------
# ops / pipeline engine knobs
# ---------------------------------------------------------------------------

def test_pipeline_engines_bit_identical():
    """merge_runs: 'kway' (default route), 'kway_kernel' (forced Pallas
    tier), and 'tournament' (the legacy oracle) agree bit-for-bit."""
    rng = np.random.default_rng(11)
    runs = [_sorted_run(rng, n) for n in (64, 48, 33, 16, 9)]
    expect = _oracle(runs)
    for engine in ("auto", "kway", "kway_kernel", "tournament"):
        got = merge_runs(runs, engine=engine, block_size=128)
        _assert_lanes_equal(got, expect)
    with pytest.raises(ValueError, match="engine"):
        merge_runs(runs, engine="bogus")


def test_merge_sorted_lex_kway_engine():
    """The 2-run special case routes through the k-way front-end and still
    matches the pairwise packed engine bit-for-bit."""
    rng = np.random.default_rng(21)
    a, b = _sorted_run(rng, 60), _sorted_run(rng, 45)
    got = merge_sorted_lex(a, b, engine="kway")
    expect = merge_sorted_lex(a, b, engine="packed")
    _assert_lanes_equal(got, expect)


def test_merge_runs_lex_degenerate_and_empty():
    rng = np.random.default_rng(31)
    empty = tuple(jnp.zeros((0,), jnp.uint32) for _ in range(3))
    one = tuple(_sorted_run(rng, 12))
    with pytest.raises(ValueError, match="arity"):
        merge_runs_lex([])  # the pipeline tier, not ops, owns the [] case
    assert merge_runs([]) == ()
    _assert_lanes_equal(merge_runs_lex([empty, empty]), list(empty))
    _assert_lanes_equal(merge_runs_lex([empty, one, empty]), list(one))
    mixed = merge_runs_lex([one, empty, tuple(_sorted_run(rng, 5))])
    assert mixed[0].shape[0] == 17


def test_choose_kway_engine_contract():
    assert choose_kway_engine(10**6) in ("take", "kernel")
    assert choose_kway_engine(4, engine="kernel") == "kernel"
    assert choose_kway_engine(4, engine="take") == "take"
    with pytest.raises(ValueError):
        choose_kway_engine(4, engine="bogus")
