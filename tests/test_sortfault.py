"""Fault-injected end-to-end sort paths: every injected failure must
recover to output *bit-identical* to the no-failure oracle, resume must
reuse persisted runs instead of re-launching them, and the
``validate='cheap'|'full'`` gate must catch seeded corruption (a flipped
element, a dropped run, a double-counted bucket).

Sizes stay small (chunks of 64, words <= 8 bytes): every chunk compiles an
interpret-mode Pallas program on this CPU container. The mesh-scale paths
(exchange failure remesh, exchange capacity doubling) ride the 8-fake-device
subprocess pattern of ``test_distributed_sort.py``.
"""

import os
import subprocess
import sys
from unittest import mock

import jax.numpy as jnp
import numpy as np
import pytest

import repro.pipeline.ingest as ingest_mod
from repro.core.packing import pack_words
from repro.pipeline import (RunManifest, RunStore, ValidationError,
                            check_chunked, check_run, chunked_sort_packed,
                            chunked_sort_words, keys_digest, multiset_digest)
from repro.pipeline.ingest import SortedRun
from repro.runtime import (RetryPolicy, SortSupervisor, StageFailure,
                           StageFailureInjector)


def _words(n, seed, max_len=8):
    rng = np.random.default_rng(seed)
    alpha = list("abcdefgh")
    return ["".join(rng.choice(alpha, l))
            for l in rng.integers(0, max_len + 1, n)]


def _shortlex(words):
    return sorted(words, key=lambda w: (len(w.encode()), w.encode()))


def _sup(inj=None, retries=3):
    return SortSupervisor(policy=RetryPolicy(max_retries=retries),
                          injector=inj)


# ---------------------------------------------------------------------------
# injected stage failures recover bit-identically
# ---------------------------------------------------------------------------

def test_chunk_launch_failure_recovers_bit_identical():
    words = _words(200, 0)
    oracle = chunked_sort_words(words, chunk_size=64)
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 2}})
    sup = _sup(inj)
    out = chunked_sort_words(words, chunk_size=64, supervisor=sup)
    assert out == oracle == _shortlex(words)
    assert [f[2] for f in inj.fired] == ["transient", "transient"]
    assert [e.action for e in sup.events] == ["retry", "retry"]


def test_merge_round_failure_recovers_bit_identical():
    """The legacy tournament keeps its per-round 'merge_round' stage (the
    default engine's ONE-launch combine runs as 'streaming_combine' below),
    so existing fail_at={'merge_round': ...} injection plans stay
    meaningful — and each re-run round recovers bit-identically."""
    words = _words(300, 1)  # 5 runs -> 3 merge rounds
    oracle = chunked_sort_words(words, chunk_size=64)
    inj = StageFailureInjector(fail_at={"merge_round": {0, 1}})
    sup = _sup(inj)
    out = chunked_sort_words(words, chunk_size=64, supervisor=sup,
                             merge_engine="tournament", validate="full")
    assert out == oracle
    assert ("merge_round", 0, "transient") in inj.fired


def test_streaming_combine_failure_recovers_bit_identical():
    """The default engine's ONE-launch k-way combine runs as the
    'streaming_combine' stage — a pure function of its input runs, so an
    injected failure simply re-executes it and the output stays
    bit-identical (the k-way analogue of the merge_round case above)."""
    words = _words(300, 18)
    oracle = chunked_sort_words(words, chunk_size=64)
    inj = StageFailureInjector(fail_at={"streaming_combine": {0}})
    sup = _sup(inj)
    out = chunked_sort_words(words, chunk_size=64, supervisor=sup,
                             validate="full")
    assert out == oracle == _shortlex(words)
    assert ("streaming_combine", 0, "transient") in inj.fired
    assert [e.action for e in sup.events] == ["retry"]


def test_resume_through_kway_combine(tmp_path):
    """Store resume composes with the k-way combine: a fully persisted
    store resumes with zero launches and the streaming merge reproduces the
    oracle output bit-identically."""
    words = _words(200, 20)
    oracle = chunked_sort_words(words, chunk_size=64)
    store = RunStore(str(tmp_path))
    chunked_sort_words(words, chunk_size=64, store=store)
    launches = []
    real = ingest_mod.sorted_run
    with mock.patch.object(ingest_mod, "sorted_run",
                           lambda k, **kw: launches.append(1) or real(k, **kw)):
        out = chunked_sort_words(words, chunk_size=64, store=store,
                                 validate="full", merge_engine="kway")
    assert out == oracle and launches == []


def test_retries_exhausted_propagates_stage_failure():
    words = _words(100, 2)
    inj = StageFailureInjector(fail_at={"ingest_chunk": {0, 1, 2}})
    sup = _sup(inj, retries=2)
    with pytest.raises(StageFailure):
        chunked_sort_words(words, chunk_size=64, supervisor=sup)


# ---------------------------------------------------------------------------
# resume from persisted runs
# ---------------------------------------------------------------------------

def test_resume_skips_completed_runs(tmp_path):
    """A job killed after N chunks must re-launch only the missing ones;
    the resumed output is bit-identical to a clean run."""
    words = _words(256, 3)  # 4 chunks of 64
    oracle = chunked_sort_words(words, chunk_size=64)

    # first attempt dies on chunk 2 (retries exhausted immediately)
    store = RunStore(str(tmp_path))
    inj = StageFailureInjector(fail_at={"ingest_chunk": {2, 3, 4}})
    with pytest.raises(StageFailure):
        chunked_sort_words(words, chunk_size=64, store=store,
                           supervisor=_sup(inj, retries=2))
    assert store.completed() == [0, 1]  # chunks 0-1 landed atomically

    # resume: only chunks 2-3 may launch
    launches = []
    real = ingest_mod.sorted_run

    def counting(keys, **kw):
        launches.append(int(keys.shape[0]))
        return real(keys, **kw)

    with mock.patch.object(ingest_mod, "sorted_run", counting):
        out = chunked_sort_words(words, chunk_size=64, store=store,
                                 validate="full")
    assert out == oracle
    assert len(launches) == 2  # 0 and 1 loaded from the store
    assert store.completed() == [0, 1, 2, 3]

    # second resume is pure load: zero launches
    with mock.patch.object(ingest_mod, "sorted_run", counting):
        out = chunked_sort_words(words, chunk_size=64, store=store,
                                 validate="full")
    assert out == oracle and len(launches) == 2


def test_stale_store_recomputes(tmp_path):
    """A store written by a *different* dataset must not poison the sort:
    the manifest's content digest cannot match the incoming chunks, so every
    chunk re-ingests (and the store is overwritten with the right runs)."""
    store = RunStore(str(tmp_path))
    chunked_sort_words(_words(128, 4), chunk_size=64, store=store)
    words = _words(128, 5)  # same shape, different content
    out = chunked_sort_words(words, chunk_size=64, store=store,
                             validate="full")
    assert out == _shortlex(words)
    # the store now holds the new dataset's runs: resuming uses them
    launches = []
    real = ingest_mod.sorted_run
    with mock.patch.object(ingest_mod, "sorted_run",
                           lambda k, **kw: launches.append(1) or real(k, **kw)):
        assert chunked_sort_words(words, chunk_size=64, store=store) == out
    assert launches == []


def test_tampered_stored_run_caught_by_validate(tmp_path):
    """Flip one bit inside a persisted run's npy: resume happily loads it
    (the *input* digest still matches the manifest), but the
    ``validate='full'`` gate must refuse the corrupted run — whichever
    invariant (sortedness or content digest) trips first."""
    words = _words(128, 6)
    store = RunStore(str(tmp_path))
    chunked_sort_words(words, chunk_size=64, store=store)
    keys_file = os.path.join(str(tmp_path), "step_1", "keys.npy")
    keys = np.load(keys_file)
    keys[3, 0] ^= np.uint32(1 << 7)  # one bit, one element
    np.save(keys_file, keys)
    with pytest.raises(ValidationError, match="run 1"):
        chunked_sort_words(words, chunk_size=64, store=store,
                           validate="full")


# ---------------------------------------------------------------------------
# the validation gate catches seeded corruption
# ---------------------------------------------------------------------------

def _runs_and_manifests(words, chunk_size=64):
    packed = jnp.asarray(pack_words(words))
    runs = []
    for ci, start in enumerate(range(0, packed.shape[0], chunk_size)):
        chunk = packed[start: start + chunk_size]
        runs.append(ingest_mod.sorted_run(chunk,
                                          capacity=int(chunk.shape[0])))
    manifests = [RunManifest.from_run(r, ci) for ci, r in enumerate(runs)]
    merged = ingest_mod._merged_run(runs)
    return runs, manifests, merged


def test_validate_passes_clean_pipeline():
    runs, manifests, merged = _runs_and_manifests(_words(192, 7))
    check_chunked(runs, manifests, merged, mode="full")


def test_validate_cheap_catches_dropped_run():
    runs, manifests, merged = _runs_and_manifests(_words(192, 8))
    short = ingest_mod._merged_run(runs[:-1])  # one run never merged
    with pytest.raises(ValidationError, match="lost or duplicated"):
        check_chunked(runs, manifests, short, mode="cheap")


def test_validate_cheap_catches_double_counted_bucket():
    runs, manifests, merged = _runs_and_manifests(_words(192, 9))
    dup = SortedRun(  # one element duplicated, as a double-counted slot would
        lengths=jnp.concatenate([merged.lengths[:1], merged.lengths]),
        keys=jnp.concatenate([merged.keys[:1], merged.keys]))
    with pytest.raises(ValidationError, match="lost or duplicated"):
        check_chunked(runs, manifests, dup, mode="cheap")


def test_validate_cheap_catches_unsorted_output():
    runs, manifests, merged = _runs_and_manifests(_words(192, 10))
    lengths = np.asarray(merged.lengths).copy()
    lengths[[0, -1]] = lengths[[-1, 0]]  # swap two rows' length lane
    keys = np.asarray(merged.keys).copy()
    keys[[0, -1]] = keys[[-1, 0]]
    bad = SortedRun(lengths=jnp.asarray(lengths), keys=jnp.asarray(keys))
    with pytest.raises(ValidationError, match="not sorted"):
        check_chunked(runs, manifests, bad, mode="cheap")


def test_validate_full_catches_flipped_element():
    """An in-place value flip that keeps count, histogram, and sortedness
    intact (last element bumped) slides past 'cheap' — the 'full' digest
    must catch it."""
    runs, manifests, merged = _runs_and_manifests(_words(192, 11))
    keys = np.asarray(merged.keys).copy()
    keys[-1, -1] ^= np.uint32(1)  # still sorted, same lengths
    bad = SortedRun(lengths=merged.lengths, keys=jnp.asarray(keys))
    check_chunked(runs, manifests, bad, mode="cheap")  # invisible to cheap
    with pytest.raises(ValidationError, match="digest"):
        check_chunked(runs, manifests, bad, mode="full")


def test_check_run_catches_histogram_mismatch():
    runs, manifests, _ = _runs_and_manifests(_words(100, 12))
    run = runs[0]
    lengths = np.asarray(run.lengths).copy()
    victim = int(np.argmax(lengths))
    lengths[victim] -= 1  # claim one word is a byte shorter
    bad = SortedRun(lengths=jnp.asarray(lengths), keys=run.keys)
    with pytest.raises(ValidationError, match="histogram"):
        check_run(bad, manifests[0], mode="cheap")


def test_multiset_digest_is_additive_and_order_independent():
    rng = np.random.default_rng(13)
    a = rng.integers(0, 2**32, (50, 3), dtype=np.uint32)
    b = rng.integers(0, 2**32, (30, 3), dtype=np.uint32)
    both = np.concatenate([a, b])
    assert keys_digest(both) == (keys_digest(a) + keys_digest(b)) % (1 << 64)
    perm = rng.permutation(both.shape[0])
    assert keys_digest(both[perm]) == keys_digest(both)
    assert keys_digest(a) != keys_digest(b)
    assert multiset_digest([]) == 0


def test_manifest_json_roundtrip():
    runs, manifests, _ = _runs_and_manifests(_words(64, 14))
    m = manifests[0]
    assert RunManifest.from_json(m.to_json()) == m
    assert m.count == 64 and sum(m.length_histogram) == 64
    assert m.min_key is not None and m.min_key <= m.max_key


# ---------------------------------------------------------------------------
# overflow degrade policies on the chunked path
# ---------------------------------------------------------------------------

def test_chunked_sort_overflow_retry_converges():
    """A capacity sized far below the skewed chunk's biggest bucket must
    converge losslessly under on_overflow='retry' — same words out as the
    uncapped oracle, validation gate green."""
    rng = np.random.default_rng(15)
    words = ["".join(rng.choice(list("abcd"), 5)) for _ in range(180)]
    oracle = chunked_sort_words(words, chunk_size=64)
    out = chunked_sort_words(words, chunk_size=64, capacity=8,
                             on_overflow="retry", validate="full")
    assert out == oracle

    with pytest.raises(ValueError, match="exceeds capacity"):
        chunked_sort_words(words, chunk_size=64, capacity=8,
                           on_overflow="raise")


def test_chunked_sort_packed_store_resume(tmp_path):
    """The packed front-end shares the same store/resume machinery."""
    rng = np.random.default_rng(16)
    words = _words(150, 17)
    packed = jnp.asarray(pack_words(words))
    store = RunStore(str(tmp_path))
    run1 = chunked_sort_packed(packed, chunk_size=64, store=store,
                               validate="full")
    launches = []
    real = ingest_mod.sorted_run
    with mock.patch.object(ingest_mod, "sorted_run",
                           lambda k, **kw: launches.append(1) or real(k, **kw)):
        run2 = chunked_sort_packed(packed, chunk_size=64, store=store,
                                   validate="full")
    assert launches == []
    np.testing.assert_array_equal(np.asarray(run1.keys),
                                  np.asarray(run2.keys))
    np.testing.assert_array_equal(np.asarray(run1.lengths),
                                  np.asarray(run2.lengths))


# ---------------------------------------------------------------------------
# mesh-scale faults (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def _run_multidev(script, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_exchange_device_failure_remesh_bit_identical():
    """An injected device loss during the sample-sort exchange re-runs the
    whole sort on a smaller mesh; the output must match the oracle."""
    out = _run_multidev("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import distributed_sort_lex
from repro.runtime import SortSupervisor, StageFailureInjector

rng = np.random.default_rng(0)
a = jnp.asarray(rng.integers(0, 40, 128), jnp.int32)
b = jnp.asarray(rng.integers(0, 1000, 128), jnp.uint32)
inj = StageFailureInjector(device_fail_at={"exchange": {0}},
                           failed_devices=4)
sup = SortSupervisor(injector=inj)

def make_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("data",))

out = sup.run_distributed(
    make_mesh, 8,
    lambda mesh: distributed_sort_lex((a, b), mesh, engine="sample",
                                      validate="full"))
order = np.lexsort((np.asarray(b), np.asarray(a)))
assert np.array_equal(np.asarray(out[0]), np.asarray(a)[order])
assert np.array_equal(np.asarray(out[1]), np.asarray(b)[order])
assert [(e.action, e.detail) for e in sup.events] == \\
    [("remesh", "8 -> 4 devices")]
print("REMESH_OK")
""")
    assert "REMESH_OK" in out


def test_exchange_capacity_retry_and_clip():
    """Skewed keys against a tiny exchange capacity: 'retry' doubles until
    lossless (bit-identical to the oracle), 'clip' returns the survivors
    with the loss reported in the shape."""
    out = _run_multidev("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.distributed import distributed_sort_lex
from repro.runtime import CapacityOverflow

mesh = Mesh(np.array(jax.devices()), ("data",))
rng = np.random.default_rng(1)
a = jnp.zeros(128, jnp.int32)  # total skew: one splitter bucket
b = jnp.asarray(rng.integers(0, 1000, 128), jnp.uint32)

try:
    distributed_sort_lex((a, b), mesh, engine="sample", capacity=2,
                         on_overflow="raise")
    raise SystemExit("expected CapacityOverflow")
except CapacityOverflow as e:
    assert e.capacity == 2

out = distributed_sort_lex((a, b), mesh, engine="sample", capacity=2,
                           on_overflow="retry", validate="full")
assert np.array_equal(np.asarray(out[1]), np.sort(np.asarray(b)))

clipped = distributed_sort_lex((a, b), mesh, engine="sample", capacity=2,
                               on_overflow="clip", validate="cheap")
assert clipped[0].shape[0] < 128
print("OVERFLOW_OK")
""")
    assert "OVERFLOW_OK" in out
