"""Sharded spill combine: ``ShardStore``/``ShardedRun`` storage semantics,
the metadata-only ``check_sharded`` gate, and the shard-granular resume /
self-heal of ``distributed_chunked_sort_lex(shard_store=...)``.

Store + gate tests are host-only (hand-built runs, no device launch). The
end-to-end spill cases run in-process on a single CPU device repeated four
ways — same code path as a real mesh, no subprocess needed — with sizes
small enough for interpret-mode Pallas compiles (~120 words).
"""

import os
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CorruptSnapshotError
from repro.core.distributed import distributed_chunked_sort_lex
from repro.core.packing import pack_words, unpack_words
from repro.pipeline import (RunManifest, ShardedRun, ShardStore,
                            SortedRun, ValidationError, check_sharded)


def _run_of(rows):
    """Hand-build a SortedRun from shortlex-ordered (length, *lanes) rows."""
    lengths = jnp.asarray([r[0] for r in rows], jnp.int32)
    keys = jnp.asarray([list(r[1:]) for r in rows], jnp.uint32) \
        if rows else jnp.zeros((0, 2), jnp.uint32)
    return SortedRun(lengths=lengths, keys=keys)


def _man(run, dest):
    return RunManifest.from_run(run, dest)


_ROWS = [(1, 0x61000000, 0), (2, 0x61620000, 0), (3, 0x61626300, 0),
         (4, 0x61626364, 0), (5, 0x61626364, 0x65000000)]


# ---------------------------------------------------------------------------
# ShardStore
# ---------------------------------------------------------------------------

def test_shard_store_roundtrip_load_and_drop(tmp_path):
    store = ShardStore(str(tmp_path))
    a, b = _run_of(_ROWS[:3]), _run_of(_ROWS[3:])
    store.put(_man(a, 0), a)
    store.put(_man(b, 1), b)
    assert store.completed() == [0, 1]

    sharded = ShardedRun(store=store,
                         manifests=(_man(a, 0), _man(b, 1)))
    assert sharded.count == 5
    got = sharded.load_shard(1, validate="full")
    np.testing.assert_array_equal(np.asarray(got.keys), np.asarray(b.keys))
    whole = sharded.to_run(validate="full")
    np.testing.assert_array_equal(
        np.asarray(whole.lengths),
        np.concatenate([np.asarray(a.lengths), np.asarray(b.lengths)]))

    store.drop(0)
    assert store.completed() == [1]
    store.drop(0)                      # dropping a missing shard is a no-op
    assert store.completed() == [1]


def test_shard_store_sweeps_tmp_droppings_on_open(tmp_path):
    store = ShardStore(str(tmp_path))
    run = _run_of(_ROWS[:2])
    store.put(_man(run, 0), run)
    torn = tmp_path / ".tmp_3"
    torn.mkdir()
    (torn / "keys.npy").write_bytes(b"partial")
    reopened = ShardStore(str(tmp_path))
    assert not torn.exists()
    assert reopened.completed() == [0]


def test_load_shard_full_validate_catches_tampering(tmp_path):
    store = ShardStore(str(tmp_path))
    run = _run_of(_ROWS)
    store.put(_man(run, 0), run)
    victim = os.path.join(str(tmp_path), "step_0", "keys.npy")
    arr = np.load(victim)
    arr[2, 0] ^= 1                    # sortedness-preserving content flip
    np.save(victim, arr)
    sharded = ShardedRun(store=store, manifests=(_man(run, 0),))
    with pytest.raises(ValidationError):
        sharded.load_shard(0, validate="full")
    # and a torn file surfaces as the typed checkpoint error
    with open(victim, "r+b") as f:
        f.truncate(40)
    with pytest.raises(CorruptSnapshotError):
        sharded.load_shard(0)


def test_empty_sharded_run_materialises_empty(tmp_path):
    sharded = ShardedRun(store=ShardStore(str(tmp_path)), manifests=())
    assert sharded.count == 0
    run = sharded.to_run()
    assert int(run.keys.shape[0]) == 0


# ---------------------------------------------------------------------------
# check_sharded: the metadata-only conservation + ordering gate
# ---------------------------------------------------------------------------

def _gate_fixtures():
    runs = [_run_of(_ROWS[:3]), _run_of(_ROWS[3:])]
    # shards partition by shortlex order: [rows 0-1] then [rows 2-4]
    shards = [_run_of(_ROWS[:2]), _run_of(_ROWS[2:])]
    return ([_man(r, i) for i, r in enumerate(runs)],
            [_man(s, i) for i, s in enumerate(shards)])


def test_check_sharded_accepts_conserving_partition():
    run_mans, shard_mans = _gate_fixtures()
    check_sharded(run_mans, shard_mans, mode="cheap")
    check_sharded(run_mans, shard_mans, mode="full")


def test_check_sharded_count_loss():
    run_mans, shard_mans = _gate_fixtures()
    with pytest.raises(ValidationError, match="lost or duplicated"):
        check_sharded(run_mans, shard_mans[:1], mode="cheap")


def test_check_sharded_histogram_swap_same_total():
    run_mans, shard_mans = _gate_fixtures()
    # same total count, one row moved between length buckets
    swapped = _run_of([(1, 0x61000000, 0), (1, 0x62000000, 0)])
    with pytest.raises(ValidationError, match="histogram"):
        check_sharded(run_mans, [_man(swapped, 0), shard_mans[1]],
                      mode="cheap")


def test_check_sharded_boundary_disorder():
    run_mans, shard_mans = _gate_fixtures()
    with pytest.raises(ValidationError, match="boundary"):
        check_sharded(run_mans, list(reversed(shard_mans)), mode="cheap")


def test_check_sharded_digest_mismatch_full_only():
    run_mans, shard_mans = _gate_fixtures()
    # flip one key lane bit, same lengths: histogram + boundaries conserve
    rows = list(_ROWS[2:])
    rows[1] = (rows[1][0], rows[1][1] ^ 1, rows[1][2])
    tampered = [_man(_run_of(_ROWS[:2]), 0), _man(_run_of(rows), 1)]
    check_sharded(run_mans, tampered, mode="cheap")   # cheap can't see it
    with pytest.raises(ValidationError, match="digest"):
        check_sharded(run_mans, tampered, mode="full")


def test_check_sharded_empty_shards_skip_boundary():
    run_mans, shard_mans = _gate_fixtures()
    empty = _man(_run_of([]), 2)
    check_sharded(run_mans, shard_mans + [empty], mode="full")


# ---------------------------------------------------------------------------
# end-to-end spill on a single repeated device
# ---------------------------------------------------------------------------

def _words(n=120, seed=0):
    rng = np.random.default_rng(seed)
    alpha = list("abcdefgh")
    return ["".join(rng.choice(alpha, l)) for l in rng.integers(0, 9, n)]


def test_spill_bit_identical_to_gather(tmp_path):
    words = _words()
    keys = np.asarray(pack_words(words))
    devs = [jax.devices()[0]] * 4
    oracle = distributed_chunked_sort_lex(keys, devices=devs,
                                          validate="full")
    store = ShardStore(str(tmp_path))
    sharded = distributed_chunked_sort_lex(keys, devices=devs,
                                           shard_store=store,
                                           validate="full")
    assert isinstance(sharded, ShardedRun)
    assert len(sharded.manifests) == 4      # one shard per destination
    assert sharded.count == len(words)
    run = sharded.to_run(validate="full")
    np.testing.assert_array_equal(np.asarray(run.keys),
                                  np.asarray(oracle.keys))
    shortlex = sorted(words, key=lambda w: (len(w.encode()), w.encode()))
    assert unpack_words(np.asarray(run.keys)) == shortlex


def test_spill_with_gather_returns_run_and_persists_shards(tmp_path):
    """``gather=True`` alongside a shard store: the caller gets the
    materialised run AND the shards land durably for resume."""
    keys = np.asarray(pack_words(_words(90, seed=3)))
    devs = [jax.devices()[0]] * 4
    store = ShardStore(str(tmp_path))
    run = distributed_chunked_sort_lex(keys, devices=devs,
                                       shard_store=store, gather=True,
                                       validate="full")
    assert int(run.keys.shape[0]) == 90
    assert store.completed() == [0, 1, 2, 3]


def test_gather_false_without_store_rejected():
    with pytest.raises(ValueError, match="shard_store"):
        distributed_chunked_sort_lex(np.zeros((4, 2), np.uint32),
                                     devices=[jax.devices()[0]] * 2,
                                     gather=False)


def test_shard_resume_skips_completed_merges(tmp_path):
    """Second invocation over a fully landed shard store must re-merge
    nothing: every destination resumes from its shard."""
    import repro.pipeline.merge as merge_mod

    keys = np.asarray(pack_words(_words()))
    devs = [jax.devices()[0]] * 4
    store = ShardStore(str(tmp_path))
    first = distributed_chunked_sort_lex(keys, devices=devs,
                                         shard_store=store,
                                         validate="full")
    real = merge_mod.merge_runs
    with mock.patch.object(merge_mod, "merge_runs",
                           side_effect=real) as spy:
        again = distributed_chunked_sort_lex(keys, devices=devs,
                                             shard_store=store,
                                             validate="full")
        assert spy.call_count == 0
    np.testing.assert_array_equal(
        np.asarray(first.to_run().keys), np.asarray(again.to_run().keys))


def test_torn_shard_self_heals_on_resume(tmp_path):
    """A shard truncated after landing (external damage) fails its load on
    resume and is recomputed — the resumed result stays bit-identical and
    the healed shard passes the full gate."""
    import repro.pipeline.merge as merge_mod

    keys = np.asarray(pack_words(_words()))
    devs = [jax.devices()[0]] * 4
    store = ShardStore(str(tmp_path))
    first = distributed_chunked_sort_lex(keys, devices=devs,
                                         shard_store=store,
                                         validate="full")
    victim = os.path.join(str(tmp_path), "step_2", "keys.npy")
    with open(victim, "r+b") as f:
        f.truncate(32)
    real = merge_mod.merge_runs
    with mock.patch.object(merge_mod, "merge_runs",
                           side_effect=real) as spy:
        healed = distributed_chunked_sort_lex(keys, devices=devs,
                                              shard_store=store,
                                              validate="full")
        assert spy.call_count == 1     # only the damaged destination
    np.testing.assert_array_equal(
        np.asarray(first.to_run().keys),
        np.asarray(healed.to_run(validate="full").keys))


def test_stale_shard_store_recomputes(tmp_path):
    """A shard store left over from a different dataset (counts/digests
    that don't match the incoming sub-runs) must be ignored, not merged."""
    devs = [jax.devices()[0]] * 4
    store = ShardStore(str(tmp_path))
    old = np.asarray(pack_words(_words(100, seed=1)))
    distributed_chunked_sort_lex(old, devices=devs, shard_store=store)
    new_words = _words(120, seed=2)
    new = np.asarray(pack_words(new_words))
    sharded = distributed_chunked_sort_lex(new, devices=devs,
                                           shard_store=store,
                                           validate="full")
    shortlex = sorted(new_words,
                      key=lambda w: (len(w.encode()), w.encode()))
    assert unpack_words(np.asarray(sharded.to_run().keys)) == shortlex
