"""Serving admission: padding waste + throughput with the paper's
length-bucketed scheduler vs one global batch."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_lm
from repro.parallel.sharding import Rules
from repro.serve import BucketedScheduler, Engine, Request

from .common import emit, rng as bench_rng


def main():
    cfg = get_smoke_config("glm4-9b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, Rules(), max_seq=96)

    rng = bench_rng("bench_serving", 0)
    reqs = [Request(i, list(rng.integers(1, cfg.vocab_size, int(l))), max_new=4)
            for i, l in enumerate(rng.choice([4, 8, 12, 24, 48], size=32,
                                             p=[0.3, 0.3, 0.2, 0.15, 0.05]))]
    stats = BucketedScheduler.padding_stats(reqs, bounds=[8, 16, 32, 48])
    emit("serving/padding_global", stats["global_waste"] * 100, "percent")
    emit("serving/padding_bucketed", stats["bucketed_waste"] * 100,
         f"reduction={stats['global_waste'] / max(stats['bucketed_waste'], 1e-9):.2f}x")

    sched = BucketedScheduler(engine, batch_size=8, bounds=[8, 16, 32, 48])
    t0 = time.perf_counter()
    results = sched.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    emit("serving/bucketed_throughput", dt * 1e6 / max(toks, 1), f"tokens={toks}")


if __name__ == "__main__":
    main()
