"""Paper Tables 2-3: the data-structure effect.

Approach 1 (paper: vector<string>, 44.373s/6.639s) -> ragged Python-object
in-bucket sorting. Approach 2 (paper: dense char 3-D array) -> packed
fixed-width uint32 lanes, vectorized comparator network, all buckets at once.
The paper's headline result is the 6.68x between them; we report the same
ratio measured on this host at matched element counts.

Comparison counts are identical across approaches (bubble/OETS = n(n-1)/2
per bucket), so the ratio isolates the layout, exactly as in the paper.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import bucketize_words, sort_buckets
from repro.data.synthetic import synthetic_words

from .common import emit


def _ragged_bubble_sort(bucket: list) -> list:
    """Approach 1: honest bubble sort over Python string objects."""
    a = list(bucket)
    n = len(a)
    for i in range(n):
        swapped = False
        for j in range(n - 1 - i):
            if a[j] > a[j + 1]:
                a[j], a[j + 1] = a[j + 1], a[j]
                swapped = True
        if not swapped:
            break
    return a


def run(n_words: int, label: str, cap_per_bucket: int):
    words = synthetic_words(n_words, seed=1)
    # bound bucket size so the O(n^2) ragged path finishes; both approaches
    # sort the *same* buckets.
    by_len: dict[int, list] = {}
    for w in words:
        by_len.setdefault(len(w), [])
        if len(by_len[len(w)]) < cap_per_bucket:
            by_len[len(w)].append(w)
    kept = [w for ws in by_len.values() for w in ws]

    t0 = time.perf_counter()
    ragged = {l: _ragged_bubble_sort(ws) for l, ws in by_len.items()}
    t_ragged = time.perf_counter() - t0

    buckets = bucketize_words(kept)
    keys = jnp.asarray(buckets.keys)
    packed_sort = jax.jit(lambda k: sort_buckets(k, "oets"))
    packed_sort(keys).block_until_ready()  # compile outside timing
    t0 = time.perf_counter()
    packed_sort(keys).block_until_ready()
    t_packed = time.perf_counter() - t0

    emit(f"table2_approach1_ragged/{label}", t_ragged * 1e6, f"n={len(kept)}")
    emit(f"table3_approach2_packed/{label}", t_packed * 1e6,
         f"speedup={t_ragged / t_packed:.2f}x(paper:6.68x)")


def main():
    run(6_000, "ds1-scale", cap_per_bucket=600)
    run(20_000, "ds2-scale", cap_per_bucket=2000)


if __name__ == "__main__":
    main()
