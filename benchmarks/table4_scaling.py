"""Paper Table 4 / Figs 2-4: parallel scaling of the bucketed sort.

The paper sweeps OpenMP threads {1,2,4,6,8,10,16} on an 8-core i7 and finds
speedup peaks at #threads == #cores (2.11x/3.69x), then *degrades*. Two
TPU-era renderings of the same experiment:

 (a) measured on this host: the vectorized comparator network processes W
     buckets per phase in parallel lanes; we sweep the number of buckets
     sorted concurrently (1 -> all) — the lane-level analogue of the
     thread sweep. On 1 CPU core the win comes from vectorization, the exact
     effect the paper's dense-array approach 2 unlocks.
 (b) modeled for the 16x16 pod from the distributed odd-even block sort's
     work/communication terms: per-device work n/P * (local phases) and
     P exchange rounds of n/P elements over 50 GB/s links — efficiency
     decays once communication dominates, reproducing the paper's
     efficiency collapse past the sweet spot (numbers in EXPERIMENTS.md).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import sort_buckets

from .common import emit, rng as bench_rng


def measured_bucket_parallelism(n_buckets: int = 64, cap: int = 192):
    rng = bench_rng("table4_scaling", 0)
    keys = rng.integers(0, 2**31, (n_buckets, cap, 1), dtype=np.uint32)
    keys = jnp.asarray(keys)

    fn_all = jax.jit(lambda k: sort_buckets(k, "oets"))

    base = None
    for group in (1, 2, 4, 8, 16, 32, 64):
        fn_all(keys[:group]).block_until_ready()  # compile this shape first
        t0 = time.perf_counter()
        # sort `group` buckets per call (lane parallelism), loop the rest
        for s in range(0, n_buckets, group):
            fn_all(keys[s : s + group]).block_until_ready()
        dt = time.perf_counter() - t0
        if base is None:
            base = dt
        speedup = base / dt
        eff = speedup / group
        emit(f"table4_measured/buckets_per_call={group}", dt * 1e6,
             f"speedup={speedup:.2f};efficiency={eff:.2f}")


def modeled_device_scaling(n: int = 2**24):
    """Odd-even block sort cost model on v5e numbers (GB/s from launch/hw)."""
    from repro.launch import hw

    # per-element comparator cost from the measured single-bucket sort
    flops_per_cmp = 4.0  # cmp+select on key lanes
    vpu_rate = 0.6e12    # sustainable vector op/s (not MXU)
    for p in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        blk = n // p
        local = blk * np.log2(max(blk, 2)) * flops_per_cmp / vpu_rate  # local sort
        rounds = p if p > 1 else 0  # odd-even transposition rounds at block level
        comm = rounds * (blk * 4) / hw.ICI_BW
        merge = rounds * blk * flops_per_cmp / vpu_rate
        total = local + comm + merge
        t1 = (n * np.log2(n) * flops_per_cmp) / vpu_rate
        speedup = t1 / total
        eff = speedup / p
        emit(f"table4_modeled/devices={p}", total * 1e6,
             f"speedup={speedup:.1f};efficiency={eff:.2f}")


def modeled_samplesort_scaling(n: int = 2**24):
    """Beyond-paper: sample sort replaces P odd-even rounds with ONE
    all_to_all — the scaling wall in the odd-even model disappears."""
    from repro.launch import hw

    flops_per_cmp = 4.0
    vpu_rate = 0.6e12
    for p in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        blk = n // p
        local = blk * np.log2(max(blk, 2)) * flops_per_cmp / vpu_rate
        # one all_to_all moving ~the whole block once + merge of received runs
        comm = (blk * 4) / hw.ICI_BW if p > 1 else 0.0
        resort = (blk * np.log2(max(blk, 2)) * flops_per_cmp / vpu_rate
                  if p > 1 else 0.0)
        total = local + comm + resort
        t1 = (n * np.log2(n) * flops_per_cmp) / vpu_rate
        speedup = t1 / total
        eff = speedup / p
        emit(f"table4_samplesort/devices={p}", total * 1e6,
             f"speedup={speedup:.1f};efficiency={eff:.2f}")


def main():
    measured_bucket_parallelism()
    modeled_device_scaling()
    modeled_samplesort_scaling()


if __name__ == "__main__":
    main()
