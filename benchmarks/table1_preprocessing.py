"""Paper Table 1: pre-processing phases.

Phases on a synthetic corpus matched to the paper's datasets by element
count (the paper's DS1 ~ 190KB of text ~ 30k words; DS2 ~ 1.38MB ~ 230k):
  1. remove special characters,
  2. distribute words into per-length sub-arrays (bucketize),
  3. pack to the dense fixed-width array (the paper's 3-D char array).
"""

from __future__ import annotations

import time

from repro.core.bucketing import bucketize_words
from repro.data.synthetic import synthetic_words, words_from_text

from .common import emit


def run(n_words: int, label: str):
    words = synthetic_words(n_words, seed=0)
    text = " ".join(words) + "?!,." * 100

    t0 = time.perf_counter()
    cleaned = words_from_text(text)
    t_clean = time.perf_counter() - t0

    t0 = time.perf_counter()
    buckets = bucketize_words(cleaned)
    t_bucket = time.perf_counter() - t0

    emit(f"table1/clean/{label}", t_clean * 1e6, f"words={len(cleaned)}")
    emit(f"table1/bucketize_pack/{label}", t_bucket * 1e6,
         f"buckets={len(buckets.lengths)};capacity={buckets.keys.shape[1] if buckets.keys.size else 0}")


def main():
    run(30_000, "ds1~190KB")
    run(230_000, "ds2~1.38MB")


if __name__ == "__main__":
    main()
