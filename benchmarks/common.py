"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax

__all__ = ["timeit", "emit", "RECORDS"]

# Every emit() appends here; benchmarks/run.py drains it into the
# BENCH_kernels.json trajectory file after each module so regressions are
# trackable across PRs.
RECORDS: list[dict] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 5, **kw):
    """Median wall time of fn(*args) in seconds (device-synchronized)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name,us_per_call,derived (also recorded for run.py's JSON)."""
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
