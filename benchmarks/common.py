"""Shared benchmark utilities: timing, record emission with execution
provenance, and the one deterministic seed every module draws from."""

from __future__ import annotations

import time
import zlib

import jax
import numpy as np

__all__ = ["timeit", "emit", "RECORDS", "SEED", "rng", "provenance"]

# Every emit() appends here; benchmarks/run.py drains it into the
# BENCH_kernels.json trajectory file after each module so regressions are
# trackable across PRs (benchmarks/gate.py is the check).
RECORDS: list[dict] = []

# The one deterministic seed behind every benchmark draw: trajectory
# entries are comparable across runs and machines because every module
# draws identical data. Derive per-site streams with rng(...) — never
# default_rng() bare.
SEED = 0


def rng(*parts) -> np.random.Generator:
    """Deterministic per-site generator: ``rng("kernels", "oets", n)``
    always yields the same stream (crc32, not PYTHONHASHSEED-randomized
    hash()), independent across call sites."""
    site = zlib.crc32("-".join(map(str, parts)).encode())
    return np.random.default_rng((SEED, site))


_PROVENANCE: dict | None = None


def provenance() -> dict:
    """The execution-provenance stamp shared by every record this process
    emits (``repro.kernels.ops.execution_provenance``: backend, device
    kind, Pallas lowering, mode label, jax version). ``benchmarks/gate.py``
    only ever compares records whose stamps match — an interpret-cpu number
    is meaningless against a compiled-tpu baseline."""
    global _PROVENANCE
    if _PROVENANCE is None:
        from repro.kernels.ops import execution_provenance
        _PROVENANCE = execution_provenance()
    return _PROVENANCE


def timeit(fn, *args, warmup: int = 1, iters: int = 5, **kw):
    """Median wall time of fn(*args) in seconds (device-synchronized)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name,us_per_call,derived (also recorded, with the process
    provenance stamp, for run.py's trajectory JSON)."""
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived, "provenance": provenance()})
    print(f"{name},{us_per_call:.1f},{derived}")
