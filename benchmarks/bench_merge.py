"""Merge-tier benchmarks: packed rank-key run merges vs the lane-wise
broadcast baseline, and the Pallas merge-path run kernel vs the jnp combine.

Four sweeps, all appended to the BENCH_kernels.json trajectory by
benchmarks/run.py:

  * ``merge/lanes/*`` vs ``merge/packed/*`` — the acceptance axis: the
    broadcast ``lex_merge_take`` (O(|a|·|b|·L) pairwise compare) against the
    packed rank-key path (``kernels/keypack.py`` binary-search ranks + one
    scatter) across lane counts and run lengths. The >= 4-lane, n >= 4096
    rows are where the tentpole's asymptotic win must show.
  * ``merge/packed_exact/*`` — the same tuples with bounded lane ranges so
    they pack exactly into the 2xu32 budget (the searchsorted-native tier).
  * ``merge/kernel/*`` — ``ops.merge_sorted_lex(engine='kernel')``: the
    block-parallel merge-path kernel. Interpret mode on this container, so
    its wall clock is the interpreter's; the tracked signal is the
    packed-vs-lanes ratio trend, with the kernel row recorded for the TPU
    roofline.
  * ``merge/tournament/*`` vs ``merge/kway/*`` — the PR 9 acceptance axis:
    ``pipeline.merge.merge_runs`` with the legacy pairwise tournament
    (ceil(log2 k) full passes) against the one-launch streaming k-way merge
    (one pass for any k) at k in {4, 8, 16} over a fixed total n. The k >= 8
    rows at the largest n are where the single-pass win must show.

``BENCH_MERGE_TINY=1`` (CI smoke) shrinks sizes to compile-bound minimums.
``BENCH_MERGE_SMOKE=1`` runs ONLY the k-way sweep at tiny sizes and asserts
every engine (kway, kway_kernel, tournament) bit-identical to the NumPy
lexsort oracle before emitting — the CI correctness smoke for the
streaming-merge rows.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lex import lex_merge_take
from repro.kernels.ops import merge_sorted_lex
from repro.pipeline.merge import merge_runs

from .common import emit, rng as bench_rng, timeit

_TINY = bool(int(os.environ.get("BENCH_MERGE_TINY", "0")))
_SMOKE = bool(int(os.environ.get("BENCH_MERGE_SMOKE", "0")))

_NS = [256] if _TINY else [1024, 4096]
_LANES = [2, 4] if _TINY else [1, 2, 4, 5]
_KERNEL_BLOCK = 128 if _TINY else 256
_KWAY_KS = [4, 8] if (_TINY or _SMOKE) else [4, 8, 16]
_KWAY_TOTAL = 256 if (_TINY or _SMOKE) else 4096


@functools.partial(jax.jit, static_argnames=("n_arr",))
def _lanes_merge(*arrs, n_arr):
    return tuple(lex_merge_take(list(arrs[:n_arr]), list(arrs[n_arr:])))


def _sorted_run(rng, n, n_lanes, hi):
    lanes = [rng.integers(0, hi, n).astype(np.uint32) for _ in range(n_lanes)]
    order = np.lexsort(tuple(reversed(lanes)))
    return [jnp.asarray(a[order]) for a in lanes]


def packed_vs_lanes():
    rng = bench_rng("bench_merge", 0)
    for n in _NS:
        for n_lanes in _LANES:
            a = _sorted_run(rng, n, n_lanes, 2**32)
            b = _sorted_run(rng, n, n_lanes, 2**32)

            t_lanes = timeit(lambda: _lanes_merge(*a, *b, n_arr=n_lanes),
                             iters=3)
            t_packed = timeit(
                lambda: merge_sorted_lex(a, b, engine="packed"), iters=3)
            emit(f"merge/lanes/n{n}/L{n_lanes}", t_lanes * 1e6,
                 "broadcast lex_merge_take")
            emit(f"merge/packed/n{n}/L{n_lanes}", t_packed * 1e6,
                 f"vs_lanes={t_lanes / t_packed:.2f}x")

            # bounded ranges: the whole tuple fits the 2xu32 budget, so the
            # rank is a native searchsorted over 1-2 packed lanes
            sa = _sorted_run(rng, n, n_lanes, 64)
            sb = _sorted_run(rng, n, n_lanes, 64)
            mv = (63,) * n_lanes
            t_sm_lanes = timeit(lambda: _lanes_merge(*sa, *sb, n_arr=n_lanes),
                                iters=3)
            t_exact = timeit(
                lambda: merge_sorted_lex(sa, sb, engine="packed",
                                         max_values=mv), iters=3)
            emit(f"merge/packed_exact/n{n}/L{n_lanes}", t_exact * 1e6,
                 f"vs_lanes={t_sm_lanes / t_exact:.2f}x")


def kernel_vs_jnp_combine():
    rng = bench_rng("bench_merge", 1)
    for n in _NS:
        for n_lanes in ([2] if _TINY else [1, 4]):
            a = _sorted_run(rng, n, n_lanes, 2**32)
            b = _sorted_run(rng, n, n_lanes, 2**32)
            t_packed = timeit(
                lambda: merge_sorted_lex(a, b, engine="packed"), iters=3)
            t_kernel = timeit(
                lambda: merge_sorted_lex(a, b, engine="kernel",
                                         block_size=_KERNEL_BLOCK), iters=3)
            emit(f"merge/kernel/n{n}/L{n_lanes}", t_kernel * 1e6,
                 f"block={_KERNEL_BLOCK};vs_packed_jnp="
                 f"{t_packed / t_kernel:.2f}x")


def kway_vs_tournament(check: bool = False):
    rng = bench_rng("bench_merge", 2)
    n_lanes = 3
    for k in _KWAY_KS:
        n = _KWAY_TOTAL // k
        runs = [_sorted_run(rng, n, n_lanes, 2**32) for _ in range(k)]
        if check:
            flat = [np.concatenate([np.asarray(r[i]) for r in runs])
                    for i in range(n_lanes)]
            order = np.lexsort(tuple(reversed(flat)))
            expect = [lane[order] for lane in flat]
            for engine in ("kway", "kway_kernel", "tournament"):
                got = merge_runs(runs, engine=engine, block_size=128)
                for g, e in zip(got, expect):
                    np.testing.assert_array_equal(np.asarray(g), e)
        t_tour = timeit(lambda: merge_runs(runs, engine="tournament"),
                        iters=3)
        t_kway = timeit(lambda: merge_runs(runs, engine="kway"), iters=3)
        emit(f"merge/tournament/k{k}/n{k * n}", t_tour * 1e6,
             "pairwise tree, ceil(log2 k) full passes")
        emit(f"merge/kway/k{k}/n{k * n}", t_kway * 1e6,
             f"one-launch streaming;vs_tournament={t_tour / t_kway:.2f}x")


def main():
    if _SMOKE:
        # correctness-first CI smoke: every engine against the NumPy
        # oracle, then the (tiny, compile-bound) timing rows
        kway_vs_tournament(check=True)
        return
    packed_vs_lanes()
    kernel_vs_jnp_combine()
    kway_vs_tournament()


if __name__ == "__main__":
    main()
