"""Ingest-pipeline benchmarks: host vs device distribute, and one-launch vs
chunked sorted-run streaming.

Two sweeps, both appended to the BENCH_kernels.json trajectory by
benchmarks/run.py:

  * ``pipeline/bucketize/*`` — the paper's phases 1-2 as the host dict loop
    (``core.bucketing.bucketize_words``, the seed implementation) vs the
    device path (``kernels.ops.bucketize``: Pallas histogram/rank pass + one
    scatter). Host cost includes packing because the host loop *is* the
    packing-adjacent stage being replaced; device cost is measured from
    packed tensors, which is where the production path starts.
  * ``pipeline/chunked/*`` — ``core.bucketing.sorted_packed`` in one launch
    vs ``pipeline.chunked_sort_packed`` streaming the same input through
    smaller chunks + run merges, the beyond-one-launch path.

On this CPU container Pallas runs interpret-mode, so absolute numbers are
wall-clock of the interpreter; the host/device *ratio* trend and the
chunking overhead factor are the tracked signals. ``BENCH_PIPELINE_TINY=1``
(CI smoke) shrinks sizes to compile-bound minimums so the end-to-end path
is exercised on every push without minutes of XLA compile.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import bucketize_words, sorted_packed
from repro.core.packing import pack_words
from repro.kernels import bucketize
from repro.pipeline import chunked_sort_packed

from .common import emit, rng as bench_rng, timeit

_TINY = bool(int(os.environ.get("BENCH_PIPELINE_TINY", "0")))

# Full sizes are sized for this container's interpret-mode XLA compiles
# (width ~512 is minutes of compile; the compile is paid once per shape and
# the chunked path reuses one executable across chunks).
_BUCKETIZE_NS = [256, 1024] if _TINY else [1024, 4096, 16384]
_CHUNK_CASES = [(256, 128)] if _TINY else [(1024, 256), (2048, 512)]


def _words(n, rng, max_len=11):
    alpha = list("abcdefghijklmnop")
    return ["".join(rng.choice(alpha, l))
            for l in rng.integers(1, max_len + 1, n)]


def host_vs_device_bucketize():
    rng = bench_rng("bench_pipeline", 0)
    for n in _BUCKETIZE_NS:
        words = _words(n, rng)
        keys = jnp.asarray(pack_words(words))

        def host(ws):
            return bucketize_words(ws).keys

        t_host = timeit(host, words, iters=3)
        t_dev = timeit(lambda k: bucketize(k)[0], keys, iters=3)
        emit(f"pipeline/bucketize/host/n{n}", t_host * 1e6, "dict-loop")
        emit(f"pipeline/bucketize/device/n{n}", t_dev * 1e6,
             f"vs_host={t_host / t_dev:.2f}x")


def single_launch_vs_chunked():
    rng = bench_rng("bench_pipeline", 1)
    for n, chunk in _CHUNK_CASES:
        words = _words(n, rng, max_len=7)
        keys = jnp.asarray(pack_words(words))
        nb_runs = -(-n // chunk)

        t_one = timeit(lambda k: sorted_packed(k)[1], keys, iters=1)
        t_chk = timeit(
            lambda k: chunked_sort_packed(k, chunk_size=chunk).keys,
            keys, iters=1)
        emit(f"pipeline/single_launch/n{n}", t_one * 1e6, "one fused program")
        emit(f"pipeline/chunked/n{n}/c{chunk}", t_chk * 1e6,
             f"runs={nb_runs};vs_single={t_one / t_chk:.2f}x")


def shard_spill_overhead():
    """``pipeline/shard_spill/*`` — the distributed sort's in-memory gather
    vs spilling every destination shard to disk (atomic snapshot + manifest
    per destination). The tracked signal is the overhead factor: what
    crash-anywhere durability costs on top of the same merges. Four
    repeated local devices keep it mesh-shaped without a subprocess; each
    timed call gets a FRESH store directory so resume can never shortcut
    the write path."""
    import shutil
    import tempfile

    import jax

    from repro.core.distributed import distributed_chunked_sort_lex
    from repro.pipeline import ShardStore

    rng = bench_rng("bench_pipeline", 2)
    n = 160 if _TINY or os.environ.get("BENCH_CHAOS_SMOKE") else 400
    words = _words(n, rng, max_len=7)
    keys = np.asarray(pack_words(words))
    devs = [jax.devices()[0]] * 4

    t_mem = timeit(
        lambda k: distributed_chunked_sort_lex(k, devices=devs).keys,
        keys, iters=2)

    root = tempfile.mkdtemp(prefix="bench_shard_spill_")
    fresh = iter(range(1000))

    def spill(k):
        d = os.path.join(root, f"call_{next(fresh)}")
        res = distributed_chunked_sort_lex(k, devices=devs,
                                           shard_store=ShardStore(d))
        return res.count

    try:
        t_spill = timeit(spill, keys, iters=2)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    emit(f"pipeline/shard_spill/none/n{n}", t_mem * 1e6,
         "gather, store=None")
    emit(f"pipeline/shard_spill/store/n{n}", t_spill * 1e6,
         f"4 shards;overhead={t_spill / t_mem:.2f}x")


def main():
    # BENCH_CHAOS_SMOKE=1: only the shard-spill overhead rows — the CI
    # bench-gate job's budget for the chaos/durability tier (the other
    # sweeps have their own smoke knobs)
    if os.environ.get("BENCH_CHAOS_SMOKE"):
        shard_spill_overhead()
        return
    host_vs_device_bucketize()
    single_launch_vs_chunked()
    shard_spill_overhead()


if __name__ == "__main__":
    main()
