"""MoE dispatch: the paper's sort-based bucketing vs the one-hot einsum
baseline, at increasing token counts. The sort dispatch is O(T k log Tk + T k d)
while the einsum dispatch is O(T E C) in memory/compute — the crossover is
the systems argument for sort-based routing at scale."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.moe import init_moe, moe
from repro.models.param import Builder, finalize
from repro.parallel.sharding import Rules

from .common import emit, timeit


def main():
    rules = Rules()
    base = get_smoke_config("granite-moe-1b-a400m").replace(d_model=128)
    b = Builder(jax.random.PRNGKey(0), dtype=jnp.float32)
    params, _ = finalize(init_moe(b, base))

    for tokens in (256, 1024, 4096):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, base.d_model))
        for impl in ("sort", "einsum"):
            cfg = base.replace(moe=dataclasses.replace(base.moe, impl=impl))
            fn = jax.jit(lambda p, v, c=cfg: moe(c, p, v, rules)[0])
            t = timeit(fn, params, x)
            emit(f"moe_dispatch/{impl}/T={tokens}", t * 1e6,
                 f"E={base.moe.n_experts};k={base.moe.top_k}")


if __name__ == "__main__":
    main()
