"""Perf regression gate over the ``BENCH_kernels.json`` trajectory.

    PYTHONPATH=src python -m benchmarks.gate [--trajectory PATH]
        [--threshold X] [--allowlist PATH]

The latest trajectory entry is checked against the rest of the history:
for each record name, the baseline is the *best* (minimum ``us_per_call``)
prior value whose provenance stamp is compatible — a stamped baseline must
match the latest record's backend / device kind / Pallas lowering, while
legacy records predating the stamp are accepted so old history still
gates. A record regresses when

    us_per_call > threshold * best_prior_us

Name patterns in the allowlist file (fnmatch, ``gate_allowlist.json`` next
to this module) are reported but never fail the gate; every entry carries
a reason — e.g. ``distributed/*``: host-emulated collective timings whose
run-to-run spread reaches ~8x (ROADMAP documents them as untrustworthy for
absolute numbers). The default threshold also lives in that file so the
noise policy is reviewed in one place.

Records with no compatible baseline are reported as ``new`` and pass — the
first stamped run after a provenance change (new backend, new jax) seeds
fresh baselines instead of comparing apples to oranges.

Exit status: 0 clean, 1 regression (or the latest entry recorded module
failures), 2 trajectory unusable.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_TRAJECTORY = os.path.join(os.path.dirname(_HERE), "BENCH_kernels.json")
_ALLOWLIST = os.path.join(_HERE, "gate_allowlist.json")

__all__ = ["load_allowlist", "check_latest", "main"]


def load_allowlist(path: str = _ALLOWLIST) -> dict:
    """{'default_threshold': float, 'allow': [{'pattern', 'reason'}, ...]}"""
    with open(path) as f:
        allow = json.load(f)
    assert allow.get("default_threshold", 0) > 1, \
        "default_threshold must be > 1 (it multiplies the baseline)"
    for entry in allow.get("allow", []):
        assert entry.get("pattern") and entry.get("reason"), \
            f"allowlist entries need pattern AND reason: {entry}"
    return allow


def _prov_key(record: dict) -> tuple:
    p = record.get("provenance") or {}
    return (p.get("backend"), p.get("device_kind"), p.get("pallas"))


def _compatible(baseline: dict, latest: dict) -> bool:
    # unstamped legacy baselines gate everything; stamped ones only gate
    # like-for-like runs
    if baseline.get("provenance") is None:
        return True
    return _prov_key(baseline) == _prov_key(latest)


def _allowed(name: str, allow: dict):
    for entry in allow.get("allow", []):
        if fnmatch.fnmatch(name, entry["pattern"]):
            return entry
    return None


def check_latest(history: list, allow: dict,
                 threshold: float | None = None) -> dict:
    """Gate history[-1] against history[:-1]. Returns a report dict:
    {'regressions': [...], 'allowed': [...], 'new': [...], 'checked': int,
    'failures': [...]} — the gate fails when 'regressions' or 'failures'
    is non-empty."""
    if not history:
        raise ValueError("empty trajectory: nothing to gate")
    threshold = threshold or allow["default_threshold"]
    latest, prior = history[-1], history[:-1]
    baselines: dict = {}
    for entry in prior:
        for rec in entry.get("records", []):
            baselines.setdefault(rec["name"], []).append(rec)

    report = {"regressions": [], "allowed": [], "new": [],
              "checked": 0, "threshold": threshold,
              "failures": list(latest.get("failures", []))}
    for rec in latest.get("records", []):
        name, us = rec["name"], rec["us_per_call"]
        if us <= 0:
            continue
        compat = [b["us_per_call"] for b in baselines.get(name, [])
                  if _compatible(b, rec) and b["us_per_call"] > 0]
        if not compat:
            report["new"].append(name)
            continue
        report["checked"] += 1
        best = min(compat)
        ratio = us / best
        if ratio <= threshold:
            continue
        finding = {"name": name, "us_per_call": us, "baseline_us": best,
                   "ratio": round(ratio, 2)}
        entry = _allowed(name, allow)
        if entry:
            finding["reason"] = entry["reason"]
            report["allowed"].append(finding)
        else:
            report["regressions"].append(finding)
    return report


def _print_report(report: dict) -> None:
    print(f"gate: {report['checked']} records checked against baselines "
          f"(threshold {report['threshold']}x), "
          f"{len(report['new'])} new (no compatible baseline)")
    for f in report["allowed"]:
        print(f"  ALLOWED    {f['name']}: {f['us_per_call']} vs "
              f"{f['baseline_us']} ({f['ratio']}x) — {f['reason']}")
    for f in report["regressions"]:
        print(f"  REGRESSION {f['name']}: {f['us_per_call']} vs "
              f"{f['baseline_us']} ({f['ratio']}x)")
    if report["failures"]:
        print(f"  FAILURES   latest entry recorded module failures: "
              f"{report['failures']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trajectory", default=_TRAJECTORY)
    ap.add_argument("--allowlist", default=_ALLOWLIST)
    ap.add_argument("--threshold", type=float, default=None,
                    help="override the allowlist's default_threshold")
    args = ap.parse_args(argv)
    try:
        with open(args.trajectory) as f:
            history = json.load(f)
        allow = load_allowlist(args.allowlist)
        report = check_latest(history, allow, args.threshold)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"gate: unusable trajectory/allowlist: {e}", file=sys.stderr)
        return 2
    _print_report(report)
    return 1 if report["regressions"] or report["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
