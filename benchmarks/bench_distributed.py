"""Distributed engine sweep: engines x P in {2, 4, 8} fake devices x merge
strategies, key-only and 4-lane lex, against the single-device jnp.sort
baseline.

The mesh must exist before jax initializes, so the sweep runs in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and
the parent re-emits its rows into the shared BENCH trajectory. The headline
record is the sample-vs-odd-even crossover: odd_even pays P merge rounds and
O(P*B) ICI bytes per device, sample one splitter exchange of O(B) bytes, so
the modeled byte crossover sits at P ~ 3 (``choose_engine``'s boundary) and
the measured ratio climbs toward / past 1 with P. On this CPU container the
fake-device collectives carry millisecond-level rendezvous jitter that
flatters odd_even's ppermute, so the measured key-only ratio trails the
model; the 4-lane lex config (variadic local sorts, the regime the word
pipeline runs) crosses at P >= 4. TPU cost is modelled in the roofline.
"""

from __future__ import annotations

import os
import subprocess
import sys

from .common import emit, rng as bench_rng

_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from benchmarks.common import timeit
from repro.core.distributed import distributed_sort, distributed_sort_lex
from repro.parallel.compat import AxisType, mesh_from_devices

rng = bench_rng("bench_distributed", 0)

def mesh_for(p):
    return mesh_from_devices(np.array(jax.devices()[:p]), ("d",),
                             axis_types=(AxisType.Auto,))

def row(name, t, derived=""):
    print("ROW,%s,%.1f,%s" % (name, t * 1e6, derived))

# --- small-block regime: every merge strategy (take is O(B^2), so only here)
N = 1 << 12
x = jnp.asarray(rng.integers(0, 2**31, N).astype(np.int32))
for p in (2, 4, 8):
    mesh = mesh_for(p)
    for merge in ("resort", "bitonic", "take"):
        t = timeit(lambda v: distributed_sort(v, mesh, axis="d",
                                              engine="odd_even", merge=merge),
                   x, iters=3)
        row("distributed/odd_even-%s/P%d/n%d" % (merge, p, N), t,
            "rounds=%d" % p)
    t = timeit(lambda v: distributed_sort(v, mesh, axis="d",
                                          engine="sample"), x, iters=3)
    row("distributed/sample/P%d/n%d" % (p, N), t, "rounds=1")

# --- key-only + 4-lane lex crossover sweep
N = 1 << 15
x = jnp.asarray(rng.integers(0, 2**31, N).astype(np.int32))
lanes = [jnp.asarray(rng.integers(0, 2**31, N).astype(np.uint32))
         for _ in range(4)]
t_base = timeit(jax.jit(jnp.sort), x, iters=5)
row("distributed/jnp_sort_1dev/n%d" % N, t_base)
ratios = {}
for p in (2, 4, 8):
    mesh = mesh_for(p)
    for kind in ("key", "lex4"):
        if kind == "key":
            oe = lambda v: distributed_sort(v, mesh, axis="d",
                                            engine="odd_even", merge="resort")
            sa = lambda v: distributed_sort(v, mesh, axis="d",
                                            engine="sample")
            args = (x,)
        else:
            oe = lambda *ls: distributed_sort_lex(list(ls), mesh, axis="d",
                                                  engine="odd_even",
                                                  merge="resort")
            sa = lambda *ls: distributed_sort_lex(list(ls), mesh, axis="d",
                                                  engine="sample")
            args = tuple(lanes)
        t_oe = timeit(oe, *args, iters=5)
        t_sa = timeit(sa, *args, iters=5)
        ratios[(kind, p)] = t_oe / t_sa
        row("distributed/odd_even-resort-%s/P%d/n%d" % (kind, p, N), t_oe,
            "rounds=%d;bytes_per_dev=%d" % (p, 2 * p * (N // p) * 4))
        row("distributed/sample-%s/P%d/n%d" % (kind, p, N), t_sa,
            "rounds=1;bytes_per_dev=%d;vs_odd_even=%.2fx"
            % (3 * (N // p) * 4, t_oe / t_sa))

# --- the crossover record: modeled ICI bytes cross at P=3 (2PB vs 3B ->
# choose_engine's P<=2 boundary); measured wall-clock ratios per P alongside
trend = ";".join("%s_P%d=%.2f" % (k, p, r)
                 for (k, p), r in sorted(ratios.items()))
crossed = [p for (k, p), r in ratios.items() if r >= 1.0]
row("distributed/crossover/n%d" % N, 0.0,
    "model_bytes_cross_P=3;measured_ratio{%s};measured_cross_P=%s"
    % (trend, min(crossed) if crossed else ">8(cpu_collective_jitter)"))
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200, cwd=root)
    if out.returncode != 0:
        raise RuntimeError(f"bench_distributed subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    for line in out.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            emit(name, float(us), derived)


if __name__ == "__main__":
    main()
