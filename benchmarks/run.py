"""Benchmark harness: one module per paper table + framework benches.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""

import argparse
import sys
import traceback

MODULES = [
    "table1_preprocessing",
    "table2_3_datastructure",
    "table4_scaling",
    "bench_kernels",
    "bench_moe_dispatch",
    "bench_serving",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    # roofline table from dry-run artifacts, when present
    try:
        from benchmarks import roofline
        print("# --- roofline (from dry-run artifacts) ---", flush=True)
        sys.argv = ["roofline", "--csv"]
        roofline.main()
    except Exception:
        traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == '__main__':
    main()
