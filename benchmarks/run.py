"""Benchmark harness: one module per paper table + framework benches.
Prints ``name,us_per_call,derived`` CSV rows and appends every run's rows to
``BENCH_kernels.json`` (a trajectory file: one entry per invocation, so PRs
can be compared for regressions).

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
"""

import argparse
import json
import os
import sys
import time
import traceback

_TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_kernels.json")

MODULES = [
    "table1_preprocessing",
    "table2_3_datastructure",
    "table4_scaling",
    "bench_kernels",
    "bench_merge",
    "bench_pipeline",
    "bench_distributed",
    "bench_moe_dispatch",
    "bench_serving",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = []
    ran = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        ran.append(name)
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    # roofline table from dry-run artifacts, when present
    try:
        from benchmarks import roofline
        print("# --- roofline (from dry-run artifacts) ---", flush=True)
        sys.argv = ["roofline", "--csv"]
        roofline.main()
    except Exception:
        traceback.print_exc()
    _write_trajectory(ran, failures)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


def _write_trajectory(modules, failures) -> None:
    """Append this run's emit() records to BENCH_kernels.json."""
    from benchmarks.common import RECORDS
    if not RECORDS:
        return
    history = []
    if os.path.exists(_TRAJECTORY):
        try:
            with open(_TRAJECTORY) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    # record which modules ran so partial (--only / failed) runs are
    # distinguishable from full sweeps when comparing entries across PRs
    history.append({
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "modules": list(modules),
        "failures": list(failures),
        "records": list(RECORDS),
    })
    with open(_TRAJECTORY, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# wrote {len(RECORDS)} records to {_TRAJECTORY}", flush=True)


if __name__ == '__main__':
    main()
