"""Benchmark harness: one module per paper table + framework benches.
Prints ``name,us_per_call,derived`` CSV rows and appends every run's rows
(with execution provenance) to ``BENCH_kernels.json`` — a trajectory file,
one entry per invocation, so PRs can be compared for regressions
(``benchmarks/gate.py`` is the comparator).

    PYTHONPATH=src python -m benchmarks.run [--only tableX]
        [--trajectory PATH]

Exits nonzero when any module (or the roofline report) fails — a bench
sweep that prints tracebacks but reports success is how regressions ship;
``tests/test_bench_run_exit.py`` pins this via the ``BENCH_INJECT_FAILURE``
environment knob (set it to a module name to fault that module without
running it).
"""

import argparse
import json
import os
import sys
import time
import traceback

_TRAJECTORY = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "BENCH_kernels.json")

MODULES = [
    "table1_preprocessing",
    "table2_3_datastructure",
    "table4_scaling",
    "bench_kernels",
    "bench_merge",
    "bench_pipeline",
    "bench_distributed",
    "bench_moe_dispatch",
    "bench_serving",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--trajectory", default=_TRAJECTORY,
                    help="trajectory JSON to append to (tests point this "
                         "at a scratch file so real history stays clean)")
    args = ap.parse_args()

    failures = []
    ran = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        ran.append(name)
        print(f"# --- {name} ---", flush=True)
        try:
            if os.environ.get("BENCH_INJECT_FAILURE") == name:
                raise RuntimeError(
                    f"injected failure in {name} (BENCH_INJECT_FAILURE)")
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    # roofline table from dry-run artifacts, when present; absence is fine
    # (it prints a hint) but an exception is a failure like any module's
    try:
        from benchmarks import roofline
        print("# --- roofline (from dry-run artifacts) ---", flush=True)
        sys.argv = ["roofline", "--csv"]
        roofline.main()
    except Exception:
        failures.append("roofline")
        traceback.print_exc()
    _write_trajectory(args.trajectory, ran, failures)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


def _write_trajectory(path, modules, failures) -> None:
    """Append this run's emit() records to the trajectory file."""
    from benchmarks.common import RECORDS, provenance
    if not RECORDS:
        return
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    # record which modules ran so partial (--only / failed) runs are
    # distinguishable from full sweeps when comparing entries across PRs
    history.append({
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "modules": list(modules),
        "failures": list(failures),
        "provenance": provenance(),
        "records": list(RECORDS),
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# wrote {len(RECORDS)} records to {path}", flush=True)


if __name__ == '__main__':
    main()
