"""Roofline report: reads the dry-run artifacts and prints the three-term
table per (arch x shape x mesh) — the §Roofline source of truth.

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*", "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    t = r["roofline"]
    dom = t["bottleneck"].replace("_s", "")
    frac = None
    total = t["compute_s"] + t["memory_s"] + t["collective_s"]
    if total > 0:
        frac = t["compute_s"] / max(t["compute_s"], t["memory_s"], t["collective_s"])
    return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{t['compute_s']:.3e} {t['memory_s']:.3e} {t['collective_s']:.3e} "
            f"{dom:10s} "
            f"{(r.get('useful_flops_ratio') or 0):.2f} "
            f"{frac if frac is not None else 0:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print("no artifacts; run: python -m repro.launch.dryrun")
        return
    if args.csv:
        for r in recs:
            t = r["roofline"]
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{max(t['compute_s'], t['memory_s'], t['collective_s']) * 1e6:.1f},"
                  f"bottleneck={t['bottleneck']}")
        return
    print(f"{'arch':24s} {'shape':12s} {'mesh':8s} "
          f"{'compute_s':>9s} {'memory_s':>9s} {'collect_s':>9s} {'dominant':10s} "
          f"{'useful':>6s} {'c/max':>5s}")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
