"""Kernel microbenchmarks: comparator-network sorts vs XLA sort at the
row-bucket granularity the MoE dispatch and serving admission use.

On this CPU container the Pallas kernels run in interpret mode (Python), so
the *timed* comparison uses the traced jnp implementations of the identical
networks; the Pallas kernels themselves are validated for correctness in
tests/test_kernels.py and their TPU cost is derived in the roofline."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitonic import bitonic_sort
from repro.core.oets import oets_sort

from .common import emit, timeit


def main():
    rng = np.random.default_rng(0)
    for rows, cols in [(8, 128), (32, 256), (64, 512)]:
        x = jnp.asarray(rng.integers(0, 2**31, (rows, cols)).astype(np.int32))

        oets = jax.jit(jax.vmap(oets_sort))
        bit = jax.jit(jax.vmap(bitonic_sort))
        xla = jax.jit(lambda v: jnp.sort(v, axis=-1))

        t_oets = timeit(oets, x)
        t_bit = timeit(bit, x)
        t_xla = timeit(xla, x)
        n_phase_oets = cols
        n_phase_bit = int(np.log2(cols) * (np.log2(cols) + 1) / 2)
        emit(f"kernels/oets/{rows}x{cols}", t_oets * 1e6, f"phases={n_phase_oets}")
        emit(f"kernels/bitonic/{rows}x{cols}", t_bit * 1e6,
             f"phases={n_phase_bit};vs_oets={t_oets / t_bit:.2f}x")
        emit(f"kernels/xla_sort/{rows}x{cols}", t_xla * 1e6,
             f"vs_bitonic={t_bit / t_xla:.2f}x")


if __name__ == "__main__":
    main()
