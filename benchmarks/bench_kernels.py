"""Kernel microbenchmarks: comparator-network sorts vs XLA sort at the
row-bucket granularity the MoE dispatch and serving admission use, plus the
single-block vs multi-block (blocksort) scaling sweep.

On this CPU container the Pallas kernels run in interpret mode, so two
regimes are reported: the *traced* jnp implementations of the identical
networks (the historical rows below) and the interpret-mode wall clock of
the Pallas paths themselves (the sweep), which is what the blocksort
acceptance tracks. TPU cost is derived in the roofline."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitonic import bitonic_sort
from repro.core.blocksort import default_block_size
from repro.core.oets import oets_sort
from repro.kernels import choose_plan, sort, sort_lex, sort_rows

from .common import emit, rng as bench_rng, timeit

# Interpret-mode OETS over a single padded block is O(n) phases of O(n) work;
# past this it stops being measurable in reasonable wall clock (the point of
# the sweep), so the single-block column is reported as absent beyond it.
_OETS_MAX_N = 16_384
_SWEEP_NS = [1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20]


def traced_networks():
    rng = bench_rng("bench_kernels", 0)
    for rows, cols in [(8, 128), (32, 256), (64, 512)]:
        x = jnp.asarray(rng.integers(0, 2**31, (rows, cols)).astype(np.int32))

        oets = jax.jit(jax.vmap(oets_sort))
        bit = jax.jit(jax.vmap(bitonic_sort))
        xla = jax.jit(lambda v: jnp.sort(v, axis=-1))

        t_oets = timeit(oets, x)
        t_bit = timeit(bit, x)
        t_xla = timeit(xla, x)
        n_phase_oets = cols
        n_phase_bit = int(np.log2(cols) * (np.log2(cols) + 1) / 2)
        emit(f"kernels/oets/{rows}x{cols}", t_oets * 1e6, f"phases={n_phase_oets}")
        emit(f"kernels/bitonic/{rows}x{cols}", t_bit * 1e6,
             f"phases={n_phase_bit};vs_oets={t_oets / t_bit:.2f}x")
        emit(f"kernels/xla_sort/{rows}x{cols}", t_xla * 1e6,
             f"vs_bitonic={t_bit / t_xla:.2f}x")


def blocksort_sweep():
    """Single-block padded OETS vs the hierarchical blocksort engine on 1-D
    inputs up to 2^20, interpret-mode wall clock."""
    rng = bench_rng("bench_kernels", 1)
    for n in _SWEEP_NS:
        x = jnp.asarray(rng.integers(0, 2**31, n).astype(np.int32))
        iters = 3 if n <= (1 << 14) else 1

        block = default_block_size(n)
        nb = -(-n // block)
        t_blk = timeit(lambda v: sort(v, algorithm="blocksort"), x, iters=iters)

        if n <= _OETS_MAX_N:
            t_oets = timeit(lambda v: sort_rows(v[None, :], algorithm="oets"),
                            x, iters=iters)
            speedup = f";vs_singleblock_oets={t_oets / t_blk:.1f}x"
            emit(f"kernels/oets_singleblock/n{n}", t_oets * 1e6, "phases=n")
        else:
            speedup = ";vs_singleblock_oets=n/a(too_slow)"
        emit(f"kernels/blocksort/n{n}", t_blk * 1e6,
             f"block={block};nb={nb}{speedup}")


def lex_lanes_sweep():
    """Variadic lex engine cost vs lane count (the paper's multi-character
    words pack 4 chars per uint32 lane): rows of 8 buckets x 128 slots,
    lanes in {1, 2, 4, 8}, against the XLA variadic-sort oracle. Lane 0 is
    drawn from a tiny alphabet so the deeper lanes actually break ties.
    cols=128 keeps the interpret-mode compile inside one lane tile — the
    lane-count scaling is the measurement, not the width."""
    rng = bench_rng("bench_kernels", 2)
    rows, cols = 8, 128
    engine = choose_plan(cols)[0]
    for n_lanes in (1, 2, 4, 8):
        lanes = [jnp.asarray(rng.integers(0, 4 if l == 0 else 2**32,
                                          (rows, cols), dtype=np.uint64)
                             .astype(np.uint32))
                 for l in range(n_lanes)]

        t_lex = timeit(lambda *ls: sort_lex(list(ls)), *lanes, iters=3)

        def xla_oracle(*ls):
            return jax.lax.sort(list(ls), num_keys=len(ls))

        t_xla = timeit(jax.jit(xla_oracle), *lanes, iters=3)
        # vs_X follows the file's other-over-self convention: >1 means the
        # lex engine beats the oracle (interpret mode on CPU stays < 1; the
        # TPU cost is modelled in the roofline)
        emit(f"kernels/sort_lex/lanes{n_lanes}/{rows}x{cols}", t_lex * 1e6,
             f"engine={engine};vs_xla={t_xla / t_lex:.2f}x")


def float_lane_engines():
    """Packed vs lane-wise float sort_lex: a (float32, int16, int16) tuple
    fits the 64-bit rank-key budget in 2 packed lanes, so the packed engine
    ranks on concatenated order bits and gathers the originals through the
    permutation, while 'lanes' pays the per-lane compare chain. The entry
    the PR-8 routing change is gated on: float lanes may now route packed."""
    rng = bench_rng("bench_kernels", 3)
    rows, cols = 8, 128
    lanes = [jnp.asarray(rng.normal(scale=10, size=(rows, cols))
                         .astype(np.float32)),
             jnp.asarray(rng.integers(-2**15, 2**15, (rows, cols))
                         .astype(np.int16)),
             jnp.asarray(rng.integers(-2**15, 2**15, (rows, cols))
                         .astype(np.int16))]
    times = {engine: timeit(lambda *ls, e=engine: sort_lex(list(ls), engine=e),
                            *lanes, iters=3)
             for engine in ("packed", "lanes")}
    for engine in ("packed", "lanes"):
        other = "lanes" if engine == "packed" else "packed"
        emit(f"kernels/sort_lex_float/{engine}/{rows}x{cols}",
             times[engine] * 1e6,
             f"f32+2xi16;vs_{other}={times[other] / times[engine]:.2f}x")


def float_nan_smoke():
    """Tiny NaN-mix sort smoke for the CI bench gate: times one 8x128
    float32 sort whose rows carry NaNs/±inf/±0.0, and asserts the
    jnp.sort-equivalent contract (bit multiset conserved, NaNs at the tail)
    before emitting — a perf record that doubles as a liveness check of
    the total-order key plane."""
    rng = bench_rng("bench_kernels", 4)
    rows, cols = 8, 128
    x = rng.normal(scale=10, size=(rows, cols)).astype(np.float32)
    x[rng.random((rows, cols)) < 0.15] = np.nan
    x[rng.random((rows, cols)) < 0.05] = np.inf
    x[rng.random((rows, cols)) < 0.05] = np.float32(-0.0)
    xj = jnp.asarray(x)
    t = timeit(lambda v: sort(v), xj, iters=3)
    out = np.asarray(sort(xj))
    for r in range(rows):
        assert (sorted(out[r].view(np.uint32).tolist())
                == sorted(x[r].view(np.uint32).tolist())), "bit multiset lost"
        k = int(np.isnan(x[r]).sum())
        assert np.isnan(out[r, cols - k:]).all(), "NaNs not at the tail"
        pre = out[r, :cols - k]
        # pairwise >=, not np.diff: inf - inf is NaN, not zero
        assert np.all(pre[1:] >= pre[:-1]), "prefix unsorted"
    emit(f"kernels/sort_float_nan/{rows}x{cols}", t * 1e6,
         "nan_mix=15%;contract=jnp.sort-equivalent")


def main():
    # BENCH_KERNELS_SMOKE=1: only the tiny float-lane entries — the CI
    # bench-gate job's budget (the full sweeps take minutes in interpret
    # mode; trend tracking for them runs out of band)
    if os.environ.get("BENCH_KERNELS_SMOKE"):
        float_lane_engines()
        float_nan_smoke()
        return
    traced_networks()
    blocksort_sweep()
    lex_lanes_sweep()
    float_lane_engines()
    float_nan_smoke()


if __name__ == "__main__":
    main()
